//! Ablation: the auth hot path under account churn — userpass login and
//! token validation throughput at 1k / 100k provisioned accounts, and
//! the `(identity, auth_type)` secondary-index lookup against the O(n)
//! full-table scan it replaced.
//!
//! The property under test is *flatness*: with the index, the per-login
//! cost must not grow with the account population (within 2x from 1k to
//! 100k accounts), while the scan baseline degrades linearly and shows
//! why the index exists. Results are written as
//! `BENCH_abl_auth_churn.json` for the CI artifact upload.
//!
//! Sizes shrink under `RUCIO_BENCH_SMOKE` (harness check only — the
//! numbers are meaningless there and the assertions are skipped).

use rucio::benchkit::{bench_indexed, section, smoke_mode, BenchResult};
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::types::{AccountType, AuthType};
use rucio::core::Catalog;
use rucio::jsonx::Json;

/// A catalog with `n` user accounts, each carrying a userpass identity
/// (`u<i>` / password `pw-<i>`).
fn rig(n: usize) -> Catalog {
    let cat = Catalog::new(Clock::sim_at(1_600_000_000_000), Config::new());
    for i in 0..n {
        let name = format!("u{i:06}");
        cat.add_account(&name, AccountType::User, "").unwrap();
        cat.add_identity(&name, AuthType::UserPass, &name, Some(&format!("pw-{i}")))
            .unwrap();
    }
    cat
}

/// The pre-index login lookup: a full scan of the identities table for
/// the `(identity, auth_type)` pair. Kept here (not in the core) purely
/// as the ablation baseline.
fn scan_lookup(cat: &Catalog, identity: &str, account: &str) -> bool {
    cat.identities
        .filter_map(|row| {
            (row.identity == identity && row.auth_type == AuthType::UserPass)
                .then(|| row.account.clone())
        })
        .iter()
        .any(|a| a == account)
}

fn main() {
    section("Ablation: auth churn — login/validate throughput vs account count");
    let sizes: Vec<usize> = if smoke_mode() { vec![100, 400] } else { vec![1_000, 100_000] };
    let (warmup, iters) = (50, 1_000);

    let mut results = Json::obj().with("bench", "abl_auth_churn");
    let mut logins: Vec<(usize, BenchResult)> = Vec::new();

    for &n in &sizes {
        let cat = rig(n);
        let names: Vec<String> = (0..n).map(|i| format!("u{i:06}")).collect();

        // --- login: credential check + token issue (indexed path) -----
        let login = bench_indexed(&format!("login ({n} accounts)"), warmup, iters, |i| {
            let k = i % n;
            cat.auth_userpass(&names[k], &names[k], &format!("pw-{k}")).unwrap();
        });
        results.set(&format!("login_{n}_per_op_ns"), login.mean_ns);
        results.set(&format!("login_{n}_ops_per_sec"), login.ops_per_sec());

        // --- validate: the per-request hot path ------------------------
        let tokens: Vec<String> = (0..256)
            .map(|i| {
                let k = i % n;
                cat.auth_userpass(&names[k], &names[k], &format!("pw-{k}")).unwrap().token
            })
            .collect();
        let validate = bench_indexed(&format!("validate ({n} accounts)"), warmup, iters, |i| {
            cat.validate_token(&tokens[i % tokens.len()]).unwrap();
        });
        results.set(&format!("validate_{n}_per_op_ns"), validate.mean_ns);
        results.set(&format!("validate_{n}_ops_per_sec"), validate.ops_per_sec());

        // --- identity lookup: secondary index vs O(n) scan -------------
        let indexed = bench_indexed(&format!("lookup indexed ({n})"), warmup, iters, |i| {
            let k = i % n;
            let hit = cat
                .identities_by_key
                .get(&(names[k].clone(), AuthType::UserPass))
                .iter()
                .any(|(_, _, a)| a == &names[k]);
            assert!(hit);
        });
        let scan_iters = iters.min(200);
        let scan = bench_indexed(&format!("lookup scan ({n})"), 5, scan_iters, |i| {
            let k = i % n;
            assert!(scan_lookup(&cat, &names[k], &names[k]));
        });
        results.set(&format!("lookup_indexed_{n}_per_op_ns"), indexed.mean_ns);
        results.set(&format!("lookup_scan_{n}_per_op_ns"), scan.mean_ns);

        if !smoke_mode() {
            assert!(
                indexed.mean_ns < scan.mean_ns,
                "index lookup must beat the full scan at {n} accounts \
                 ({:.0} vs {:.0} ns/op)",
                indexed.mean_ns,
                scan.mean_ns
            );
        }
        logins.push((n, login));
        println!();
    }

    // Flatness: login cost must not follow the account population.
    let (n0, small) = &logins[0];
    let (n1, large) = &logins[logins.len() - 1];
    let growth = large.mean_ns / small.mean_ns.max(1e-9);
    println!(
        "login cost {n0} → {n1} accounts: {growth:.2}x \
         ({:.0} vs {:.0} logins/s)",
        small.ops_per_sec(),
        large.ops_per_sec()
    );
    if !smoke_mode() {
        assert!(
            growth < 2.0,
            "indexed login must stay flat (within 2x) from {n0} to {n1} accounts \
             (got {growth:.2}x)"
        );
    }

    std::fs::write("BENCH_abl_auth_churn.json", results.to_string()).unwrap();
    println!("abl_auth_churn bench OK (BENCH_abl_auth_churn.json written)");
}
