//! Ablation: the §3.6 bulk mutation path vs row-at-a-time writes.
//!
//! Registers replicas through `Catalog::add_replica` one row at a time and
//! through a single `Catalog::add_replicas_bulk` batch (≥10k replicas per
//! call), then drives bulk rule creation over a large dataset (locks +
//! transfer requests land as one batched commit per table). Reports
//! per-op figures for each path; the batch path amortizes one
//! all-shard lock acquisition over the whole call instead of paying a
//! lock round-trip (plus index/history bookkeeping locks) per row.

use rucio::benchkit::{bench_throughput, section};
use rucio::core::replicas_api::ReplicaSpec;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::core::Catalog;

const N_REPLICAS: usize = 10_000;
const N_RULE_FILES: usize = 5_000;

fn catalog() -> Catalog {
    let c = Catalog::new_for_tests();
    let now = c.now();
    c.add_scope("bench", "root").unwrap();
    for name in ["BULK-A", "BULK-B"] {
        c.add_rse(rucio::core::rse::Rse::new(name, now)).unwrap();
    }
    c
}

fn add_files(c: &Catalog, prefix: &str, n: usize) -> Vec<DidKey> {
    (0..n)
        .map(|i| {
            let name = format!("{prefix}.{i:06}");
            c.add_file("bench", &name, "root", 1_000, "aabbccdd", None).unwrap();
            DidKey::new("bench", &name)
        })
        .collect()
}

fn main() {
    section("Ablation: bulk mutation path (db batches) vs row-at-a-time");

    // --- replica registration -----------------------------------------
    let c = catalog();
    let row_dids = add_files(&c, "row", N_REPLICAS);
    let row = bench_throughput("replicas: row-at-a-time add_replica", N_REPLICAS, || {
        for did in &row_dids {
            c.add_replica("BULK-A", did, ReplicaState::Available, None).unwrap();
        }
    });

    let bulk_dids = add_files(&c, "bulk", N_REPLICAS);
    let specs: Vec<ReplicaSpec> = bulk_dids
        .iter()
        .map(|d| ReplicaSpec::new(d.clone(), ReplicaState::Available))
        .collect();
    let bulk = bench_throughput("replicas: one add_replicas_bulk call", N_REPLICAS, || {
        let added = c.add_replicas_bulk("BULK-A", &specs).unwrap();
        assert_eq!(added, N_REPLICAS, "batch path must insert the whole call");
    });
    assert_eq!(c.replicas.len(), 2 * N_REPLICAS);

    // --- rule creation over a big dataset ------------------------------
    // Locks + transfer requests for all files land as batched commits.
    let files = add_files(&c, "ds", N_RULE_FILES);
    c.add_dataset("bench", "bigds", "root").unwrap();
    let ds = DidKey::new("bench", "bigds");
    for f in &files {
        c.attach(&ds, f).unwrap();
    }
    let rule = bench_throughput(
        "rule over 5k-file dataset (batched locks+requests)",
        N_RULE_FILES,
        || {
            c.add_rule(RuleSpec::new("root", ds.clone(), "BULK-B", 1)).unwrap();
        },
    );
    assert_eq!(c.locks.len(), N_RULE_FILES);
    assert_eq!(c.requests.len(), N_RULE_FILES);

    let speedup = row.mean_ns / bulk.mean_ns;
    println!(
        "\nbulk-vs-row replica registration: {speedup:.1}x per-op \
         ({:.0} vs {:.0} rows/s); rule fan-out {:.0} locks/s",
        bulk.ops_per_sec(),
        row.ops_per_sec(),
        rule.ops_per_sec()
    );
    assert!(
        speedup > 0.5,
        "bulk path must not regress vs row-at-a-time (got {speedup:.2}x)"
    );
    println!("abl_bulk_mutation bench OK");
}
