//! Ablation: campaign-scale catalog operations — the two hot paths a
//! planned-load campaign leans on.
//!
//! 1. **Reprocessing rule injection** — `add_rules_bulk` in campaign-
//!    sized batches over a grid catalog of datasets whose replicas
//!    already satisfy the destination (pure rule+lock materialization,
//!    the §3 bulk-API throughput the paper's end-of-year reprocessing
//!    depends on).
//! 2. **Deletion rate** — a mass-deletion sweep end to end: bulk expiry
//!    (`set_rule_expiration_bulk`), judge processing of the expired
//!    rules, then greedy reaper sweeps until the storage is clean —
//!    files/s and bytes/s against the paper's §4.3 deletion-rate tables.
//!
//! Full mode: 2000 datasets x 10 files (smoke: 60 x 5). Results are
//! written to `BENCH_abl_campaign.json` for artifact upload.

use rucio::benchkit::{bench_throughput, section, smoke_mode};
use rucio::common::clock::{Clock, HOUR_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::daemons::{reaper::Reaper, Daemon};
use rucio::jsonx::Json;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::storagesim::synthetic_adler32_for;

const RSE: &str = "DE-T1-DISK";

fn main() {
    let (datasets, files_per, batch) =
        if smoke_mode() { (60usize, 5usize, 20usize) } else { (2_000usize, 10usize, 100usize) };
    let total_files = datasets * files_per;
    let file_bytes = 1_000_000u64;
    let mut results = Json::obj()
        .with("bench", "abl_campaign")
        .with("datasets", datasets as u64)
        .with("files_per_dataset", files_per as u64)
        .with("batch", batch as u64);

    section(&format!(
        "Ablation: campaign ops at {datasets} datasets x {files_per} files (batch {batch})"
    ));

    let mut cfg = Config::new();
    cfg.set("common", "seed", "11");
    cfg.set("reaper", "tombstone_grace", "1h");
    let ctx = build_grid(
        &GridSpec { t2_per_region: 1, seed: 11, ..Default::default() },
        Clock::sim_at(1_514_764_800_000),
        cfg,
    );
    let cat = ctx.catalog.clone();
    let sys = ctx.fleet.get(RSE).expect("grid RSE");

    // -- corpus: datasets with replicas already resident on the target --
    let now = cat.now();
    let mut ds_keys: Vec<DidKey> = Vec::with_capacity(datasets);
    for d in 0..datasets {
        let ds = format!("repro.{d:05}");
        cat.add_dataset("data18", &ds, "prod").unwrap();
        let ds_key = DidKey::new("data18", &ds);
        for f in 0..files_per {
            let name = format!("repro.{d:05}.f{f}");
            let adler = synthetic_adler32_for(&name, file_bytes);
            cat.add_file("data18", &name, "prod", file_bytes, &adler, None).unwrap();
            let key = DidKey::new("data18", &name);
            cat.attach(&ds_key, &key).unwrap();
            let rep = cat.add_replica(RSE, &key, ReplicaState::Available, None).unwrap();
            sys.put(&rep.pfn, file_bytes, now).unwrap();
        }
        ds_keys.push(ds_key);
    }
    println!("corpus: {datasets} datasets, {total_files} files on {RSE}");

    // -- 1. reprocessing rule injection --------------------------------
    section("Reprocessing: bulk rule injection");
    let mut rule_ids: Vec<u64> = Vec::with_capacity(datasets);
    // the corpus satisfies every rule, so injection is pure rule+lock
    // materialization — no transfer machinery on the timed path
    let r = bench_throughput("add_rules_bulk (campaign batches)", datasets, || {
        for chunk in ds_keys.chunks(batch) {
            let specs: Vec<RuleSpec> = chunk
                .iter()
                .map(|k| RuleSpec::new("prod", k.clone(), RSE, 1).with_activity("Reprocessing"))
                .collect();
            rule_ids.extend(cat.add_rules_bulk(specs).unwrap());
        }
    });
    results.set("rule_inject_rules_per_sec", r.ops_per_sec());
    let locks: usize = rule_ids.iter().map(|id| cat.locks_by_rule.count(id)).sum();
    assert_eq!(rule_ids.len(), datasets);
    assert_eq!(locks, total_files, "one lock per file per rule");
    results.set("locks_created", locks as u64);
    println!("locks materialized: {locks}");

    // -- 2. mass deletion: expiry -> judge -> reaper -------------------
    section("Mass deletion: bulk expiry, judge, reaper sweeps");
    let t_expire = cat.now() - 1;
    let r = bench_throughput("set_rule_expiration_bulk", rule_ids.len(), || {
        let n = cat.set_rule_expiration_bulk(&rule_ids, Some(t_expire));
        assert_eq!(n, rule_ids.len());
    });
    results.set("expiry_bulk_rules_per_sec", r.ops_per_sec());

    let t0 = std::time::Instant::now();
    let mut judged = 0usize;
    loop {
        let n = cat.process_expired_rules(1_000);
        if n == 0 {
            break;
        }
        judged += n;
    }
    let judge_secs = t0.elapsed().as_secs_f64();
    assert_eq!(judged, rule_ids.len(), "every expired rule judged away");
    results.set("judge_rules_per_sec", judged as f64 / judge_secs.max(1e-9));
    println!("judge: {judged} expired rules in {judge_secs:.3}s");

    // past the tombstone grace, then sweep until the storage is clean
    if let Clock::Sim(s) = &cat.clock {
        s.advance(2 * HOUR_MS);
    }
    let mut reaper = Reaper::new(ctx.clone(), "bench-1");
    let t0 = std::time::Instant::now();
    let mut deleted = 0usize;
    while deleted < total_files {
        let now = cat.now();
        let n = reaper.tick(now);
        if n == 0 {
            if let Clock::Sim(s) = &cat.clock {
                s.advance(30_000);
            }
            continue;
        }
        deleted += n;
    }
    let reap_secs = t0.elapsed().as_secs_f64();
    let files_per_sec = deleted as f64 / reap_secs.max(1e-9);
    assert_eq!(sys.file_count(), 0, "storage fully reaped");
    assert_eq!(cat.metrics.counter("reaper.deleted"), total_files as u64);
    results.set("deletion_files_per_sec", files_per_sec);
    results.set(
        "deletion_bytes_per_sec",
        cat.metrics.counter("reaper.deleted_bytes") as f64 / reap_secs.max(1e-9),
    );
    results.set("deleted_files", deleted as u64);
    println!(
        "reaper: {deleted} files ({:.1} MB) in {reap_secs:.3}s = {files_per_sec:.0} files/s",
        cat.metrics.counter("reaper.deleted_bytes") as f64 / 1e6
    );

    std::fs::write("BENCH_abl_campaign.json", results.to_string()).unwrap();
    println!("\nabl_campaign bench OK (BENCH_abl_campaign.json written)");
}
