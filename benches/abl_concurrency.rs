//! Ablation: the concurrent runtime (ISSUE PR 6) — how the three
//! contention fixes scale under real OS threads:
//!
//! 1. **Shard grid** — 1 vs 8 vs 32 table shards under 16 concurrent
//!    writers (no WAL, isolating the shard `RwLock`s). Full mode asserts
//!    32 shards strictly outperform the single global lock.
//! 2. **WAL commit path** — legacy global-mutex commits vs leader-based
//!    group commit (`WalOptions::leader`) under the same 16 writers.
//!    Leader mode amortizes frame IO across a commit window; the printed
//!    mean window size (from the new `WalStats` flush counters) shows
//!    how many commits each leader drained.
//! 3. **REST + fleet** — end-to-end req/s against the real thread-pooled
//!    server with the full daemon fleet live on a durable group-commit
//!    catalog, 1 worker vs 8 workers (clients == workers: a keep-alive
//!    connection pins its worker). Full mode asserts ≥ 2x scaling.
//!
//! Results are also written as `BENCH_abl_concurrency.json` in the
//! working directory so CI can archive the perf trajectory.
//!
//! Under `RUCIO_BENCH_SMOKE` the sizes shrink to a harness check and
//! all ratio assertions are skipped (timings are meaningless there).

use rucio::benchkit::{section, smoke_mode};
use rucio::client::RucioClient;
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::types::AuthType;
use rucio::daemons::{FleetHandle, Paced};
use rucio::db::{Durable, Row, Table, WalOptions};
use rucio::jsonx::Json;
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::{Result, RucioError};

#[derive(Clone, Debug)]
struct BenchRow {
    id: u64,
    payload: String,
}

impl Row for BenchRow {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl Durable for BenchRow {
    fn row_to_json(&self) -> Json {
        Json::obj().with("id", self.id).with("payload", self.payload.as_str())
    }
    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(BenchRow { id: j.req_u64("id")?, payload: j.req_str("payload")?.to_string() })
    }
    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }
    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| RucioError::JsonError("bad key".into()))
    }
}

fn row(id: u64) -> BenchRow {
    BenchRow { id, payload: format!("replica-{id:012}-state-AVAILABLE") }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("rucio-abl-conc-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// `writers` threads upsert `per_writer` disjoint rows each; returns
/// aggregate upserts/sec.
fn run_writers(t: &Table<BenchRow>, writers: usize, per_writer: usize) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for w in 0..writers {
            let t = &*t;
            s.spawn(move || {
                let base = (w * per_writer) as u64;
                for i in 0..per_writer as u64 {
                    t.upsert(row(base + i), 0);
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    assert_eq!(t.len(), writers * per_writer, "every upsert applied");
    (writers * per_writer) as f64 / elapsed.max(1e-9)
}

/// Ablation 1: shard count under 16 concurrent writers, no WAL.
fn shard_grid(writers: usize, per_writer: usize, out: &mut Json) -> (f64, f64) {
    section(&format!("Ablation: table shards under {writers} concurrent writers"));
    let mut first = 0.0;
    let mut last = 0.0;
    for shards in [1usize, 8, 32] {
        let t: Table<BenchRow> = Table::new("bench").with_shards(shards);
        let rate = run_writers(&t, writers, per_writer);
        println!("{shards:>3} shards: {rate:>12.0} upserts/s");
        out.set(&format!("shards_{shards}_ops_per_sec"), rate);
        if shards == 1 {
            first = rate;
        }
        last = rate;
    }
    (first, last)
}

/// Ablation 2: WAL legacy global-mutex commits vs leader group commit,
/// same 16 writers (every upsert is one WAL commit).
fn wal_grid(writers: usize, per_writer: usize, out: &mut Json) {
    section(&format!("Ablation: WAL commit path under {writers} concurrent writers"));
    for (name, leader) in [("global-mutex", false), ("leader group commit", true)] {
        let dir = temp_dir(if leader { "leader" } else { "mutex" });
        let t: Table<BenchRow> = Table::new("bench").with_shards(32);
        t.attach_wal(&dir, WalOptions { fsync: false, group_commit: true, leader }).unwrap();
        let rate = run_writers(&t, writers, per_writer);
        let stats = t.wal_stats().unwrap();
        let mean_window = stats.flushed_frames as f64 / stats.flush_windows.max(1) as f64;
        println!(
            "{name:>20}: {rate:>12.0} upserts/s | {} windows, mean {:.1} frames/window, max {}",
            stats.flush_windows, mean_window, stats.max_window_frames
        );
        let key = if leader { "wal_leader" } else { "wal_mutex" };
        out.set(&format!("{key}_ops_per_sec"), rate);
        out.set(&format!("{key}_mean_window_frames"), mean_window);

        // durability sanity under contention: the log replays in full
        let r: Table<BenchRow> = Table::new("bench").with_shards(32);
        r.recover_from_dir(&dir).unwrap();
        assert_eq!(r.len(), writers * per_writer, "recovery replays every commit");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Ablation 3: REST req/s with the daemon fleet live, 1 vs 8 workers.
fn rest_fleet(reqs_per_client: usize, out: &mut Json) -> (f64, f64) {
    section("Ablation: REST + live fleet, 1 vs 8 server workers");
    let mut rates = Vec::new();
    for workers in [1usize, 8] {
        let dir = temp_dir(&format!("rest-{workers}"));
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        cfg.set("db", "shards", "32");
        // Real clock: daemons and HTTP run on wall time here.
        let spec = GridSpec { t2_per_region: 1, fts_servers: 1, ..GridSpec::default() };
        let ctx = build_grid(&spec, Clock::Real, cfg);
        ctx.catalog
            .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
            .unwrap();
        let mut fleet = FleetHandle::spawn(Paced::fleet(Driver::standard_daemons(&ctx), 100));
        let server = rucio::server::serve(
            ctx.catalog.clone(),
            ctx.broker.clone(),
            "127.0.0.1:0",
            workers,
        )
        .unwrap();
        let url = server.url();

        let t0 = std::time::Instant::now();
        std::thread::scope(|s| {
            for c in 0..workers {
                let url = url.clone();
                s.spawn(move || {
                    let client = RucioClient::connect(&url, "alice", "alice", "pw").unwrap();
                    for i in 0..reqs_per_client {
                        let name = format!("bench-w{workers}-c{c}-i{i}");
                        match i % 4 {
                            // mixed mix: writes (durable WAL commits) + reads
                            0 => client.add_file("data18", &name, 1_000, "aabbccdd").unwrap(),
                            1 => {
                                client
                                    .register_replica("CERN-PROD", "data18", &prev(&name), None)
                                    .map(|_| ())
                                    .unwrap();
                            }
                            2 => {
                                client.get_did("data18", &prev(&name)).map(|_| ()).unwrap();
                            }
                            _ => {
                                client.ping().map(|_| ()).unwrap();
                            }
                        }
                    }
                });
            }
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let total = workers * reqs_per_client;
        let rate = total as f64 / elapsed.max(1e-9);
        println!("{workers} worker(s) × {reqs_per_client} reqs/client: {rate:>10.0} req/s");
        out.set(&format!("rest_{workers}_workers_req_per_sec"), rate);
        rates.push(rate);

        drop(server);
        fleet.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
    (rates[0], rates[1])
}

/// The file the previous loop step (`i % 4 == 0`) created: replica
/// registration and reads always target an existing DID.
fn prev(name: &str) -> String {
    let (head, i) = name.rsplit_once("-i").unwrap();
    let i: usize = i.parse().unwrap();
    format!("{head}-i{}", i - (i % 4))
}

fn main() {
    let (writers, per_writer, reqs_per_client) =
        if smoke_mode() { (16, 50, 40) } else { (16, 10_000, 1_200) };

    let mut results = Json::obj().with("bench", "abl_concurrency");
    let (shard1, shard32) = shard_grid(writers, per_writer, &mut results);
    wal_grid(writers, per_writer, &mut results);
    let (rest1, rest8) = rest_fleet(reqs_per_client, &mut results);

    println!(
        "\nshards 1→32: {:.2}x | REST workers 1→8: {:.2}x\n",
        shard32 / shard1,
        rest8 / rest1
    );
    if !smoke_mode() {
        assert!(
            shard32 > shard1,
            "32 shards must beat 1 shard under {writers} writers \
             ({shard32:.0} vs {shard1:.0} upserts/s)"
        );
        assert!(
            rest8 >= 2.0 * rest1,
            "8 REST workers must give >= 2x the 1-worker rate with the fleet live \
             ({rest8:.0} vs {rest1:.0} req/s)"
        );
    }

    std::fs::write("BENCH_abl_concurrency.json", results.to_string()).unwrap();
    println!("abl_concurrency bench OK (BENCH_abl_concurrency.json written)");
}
