//! Ablation: `meta-expr` DID filtering through the inverted-index
//! planner vs the full scope scan, at 10k and 100k DIDs.
//!
//! The acceptance bar for the metadata query subsystem: an indexed
//! equality filter over a 100k-DID namespace answers without a full
//! scan — the planner picks the index and the bench shows ≥10x over the
//! scan executor (it is typically orders of magnitude). Both executors
//! run the same expression and must return identical rows.

use rucio::benchkit::{bench, section, smoke_mode};
use rucio::core::metaexpr::{parse, MetaValue};
use rucio::core::types::DidKey;
use rucio::core::Catalog;

const SIZES: [usize; 2] = [10_000, 100_000];

/// A namespace of `n` file DIDs with production-shaped metadata:
/// `datatype` (10% RAW / 60% AOD / 30% HITS), a monotone `run` number
/// (unique), and a rotating `stream`.
fn build_namespace(n: usize) -> Catalog {
    let c = Catalog::new_for_tests();
    c.add_scope("bench", "root").unwrap();
    for i in 0..n {
        let name = format!("f.{i:07}");
        c.add_file("bench", &name, "root", 1_000, "aabbccdd", None).unwrap();
        let key = DidKey::new("bench", &name);
        let datatype = match i % 10 {
            0 => "RAW",
            1..=6 => "AOD",
            _ => "HITS",
        };
        c.set_metadata_bulk(
            &key,
            vec![
                ("datatype".into(), MetaValue::Str(datatype.into())),
                ("run".into(), MetaValue::Int(358_000 + i as i64)),
                ("stream".into(), MetaValue::Str(format!("stream{}", i % 3))),
            ],
        )
        .unwrap();
    }
    c
}

fn main() {
    section("Ablation: meta-expr filter — inverted index vs scope scan");
    let mut speedup_at_100k = f64::INFINITY;

    for n in SIZES {
        let n = if smoke_mode() { n / 20 } else { n };
        let c = build_namespace(n);

        // one specific run number: selectivity 1/n
        let eq = parse(&format!("run={}", 358_000 + n as i64 / 2)).unwrap();
        // RAW datasets in the newest 5% of runs: conjunctive eq + range
        let range = parse(&format!("datatype=RAW AND run>={}", 358_000 + n as i64 * 95 / 100))
            .unwrap();

        for (label, expr, expect) in [
            ("run equality", &eq, 1usize),
            ("RAW + run range", &range, n / 10 / 20),
        ] {
            // the planner must answer from the index, not the scan
            let plan = c.plan_dids_query("bench", expr);
            assert!(plan.is_indexed(), "{label}: planner fell back to scan: {plan:?}");

            // both executors agree before we time anything
            let indexed_rows = c.query_dids("bench", expr, false);
            let scanned_rows = c.query_dids_scan("bench", expr, false);
            assert_eq!(indexed_rows, scanned_rows, "{label}: executors diverge");
            assert!(
                indexed_rows.len().abs_diff(expect) <= 1,
                "{label}: selectivity sanity ({} rows, expected ~{expect})",
                indexed_rows.len()
            );

            let iters = if n >= 100_000 { 20 } else { 50 };
            let ix = bench(&format!("{n:>6} DIDs  indexed  {label}"), 3, iters, || {
                std::hint::black_box(c.query_dids("bench", expr, false));
            });
            let sc = bench(&format!("{n:>6} DIDs  scan     {label}"), 1, iters / 4, || {
                std::hint::black_box(c.query_dids_scan("bench", expr, false));
            });
            let speedup = sc.mean_ns / ix.mean_ns;
            println!("        -> speedup {speedup:.1}x (scan {:.2} ms)", sc.mean_ns / 1e6);
            if n >= 100_000 && label == "run equality" {
                speedup_at_100k = speedup;
            }
        }
    }

    // Smoke mode shrinks the namespace and iteration counts to prove the
    // harness still runs; timing claims only bind on the full run.
    if !smoke_mode() {
        assert!(
            speedup_at_100k >= 10.0,
            "indexed equality at 100k DIDs must beat the scan by >=10x \
             (got {speedup_at_100k:.1}x)"
        );
    }
    println!("abl_did_filter bench OK");
}
