//! Ablation of §2.4's dynamic distance re-evaluation: "periodic
//! re-evaluation of the collected average throughput of file transfers
//! between two RSEs helps to dynamically adjust and update the distances
//! ... and eventually improve source selection."
//!
//! Setup: a file has two candidate sources for transfers to a destination;
//! the nominally-near source sits behind a degraded (slow) link. Without
//! updates the conveyor keeps picking the stale-near source; with the
//! DistanceUpdater folding observed throughput back into the distance
//! table, selection flips to the actually-fast source.

use rucio::benchkit::{section, Table};
use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rse::Rse;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::daemons::conveyor::{Poller, Submitter};
use rucio::daemons::tracer::DistanceUpdater;
use rucio::daemons::{Ctx, Daemon};
use rucio::ftssim::FtsServer;
use rucio::mq::Broker;
use rucio::netsim::{Link, Network};
use rucio::storagesim::{synthetic_adler32_for, Fleet, StorageKind, StorageSystem};
use std::sync::Arc;

fn rig() -> Ctx {
    let catalog = Arc::new(rucio::core::Catalog::new(Clock::sim_at(0), Config::new()));
    catalog.add_scope("data18", "root").unwrap();
    let fleet = Arc::new(Fleet::new());
    let net = Arc::new(Network::new());
    for name in ["NEAR-SRC", "FAR-SRC", "DST"] {
        catalog
            .add_rse(Rse::new(name, 0).with_attr("site", name))
            .unwrap();
        fleet.add(StorageSystem::new(name, StorageKind::Disk, u64::MAX));
    }
    // NEAR-SRC is nominally close (distance 1) but its link degraded to
    // 1 MB/s; FAR-SRC is nominally farther (distance 3) on a 100 MB/s link.
    catalog.set_distance("NEAR-SRC", "DST", 1).unwrap();
    catalog.set_distance("FAR-SRC", "DST", 3).unwrap();
    net.set_link("NEAR-SRC", "DST", Link::new(1_000_000, 5, 1.0));
    net.set_link("FAR-SRC", "DST", Link::new(100_000_000, 5, 1.0));
    let broker = Broker::new();
    let fts = vec![Arc::new(FtsServer::new("fts1", net.clone(), fleet.clone(), Some(broker.clone())))];
    Ctx::new(catalog, fleet, net, fts, broker)
}

/// Run `n` sequential single-file transfers; returns (mean duration ms,
/// final fraction sourced from FAR-SRC).
fn run(ctx: &Ctx, n: usize, with_updates: bool) -> (f64, f64) {
    let cat = ctx.catalog.clone();
    let sim = match &cat.clock {
        Clock::Sim(s) => s.clone(),
        _ => unreachable!(),
    };
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    let mut updater = DistanceUpdater { ctx: ctx.clone() };
    let mut durations = Vec::new();
    let mut from_far = 0usize;
    for i in 0..n {
        let name = format!("d{with_updates}{i:04}");
        let bytes = 60_000_000u64; // 60 MB: 60s near vs 0.6s far
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        for src in ["NEAR-SRC", "FAR-SRC"] {
            let rep = cat.add_replica(src, &key, ReplicaState::Available, None).unwrap();
            ctx.fleet.get(src).unwrap().put(&rep.pfn, bytes, cat.now()).unwrap();
        }
        cat.add_rule(RuleSpec::new("root", key.clone(), "DST", 1)).unwrap();
        let t0 = cat.now();
        let mut guard = 0;
        loop {
            let now = cat.now();
            submitter.tick(now);
            for f in &ctx.fts {
                f.advance(now);
            }
            sim.advance(MINUTE_MS / 6); // 10s steps
            for f in &ctx.fts {
                f.advance(cat.now());
            }
            poller.tick(cat.now());
            if cat.get_replica("DST", &key).map(|r| r.state == ReplicaState::Available).unwrap_or(false)
            {
                break;
            }
            guard += 1;
            assert!(guard < 1000, "transfer stuck");
        }
        durations.push((cat.now() - t0) as f64);
        let req = cat
            .requests
            .scan(|r| r.did == key)
            .into_iter()
            .next()
            .unwrap();
        if req.src_rse.as_deref() == Some("FAR-SRC") {
            from_far += 1;
        }
        if with_updates {
            updater.tick(cat.now());
        }
    }
    let mean = durations.iter().sum::<f64>() / n as f64;
    (mean, from_far as f64 / n as f64)
}

fn main() {
    section("Ablation: dynamic distance re-evaluation (paper §2.4)");
    let n = 20;
    let (mean_off, far_off) = run(&rig(), n, false);
    let (mean_on, far_on) = run(&rig(), n, true);

    let mut table = Table::new(
        "source selection with a degraded 'near' link",
        &["distance updates", "mean transfer time", "% from fast source"],
    );
    table.row(&[
        "OFF (static)".into(),
        format!("{:.0} s", mean_off / 1000.0),
        format!("{:.0}%", far_off * 100.0),
    ]);
    table.row(&[
        "ON (throughput EWMA)".into(),
        format!("{:.0} s", mean_on / 1000.0),
        format!("{:.0}%", far_on * 100.0),
    ]);
    table.print();

    assert!(far_on > far_off, "updates must shift selection to the fast source");
    assert!(
        mean_on < mean_off * 0.8,
        "updates must cut mean transfer time: {mean_on:.0} vs {mean_off:.0}"
    );
    println!("abl_distance_update bench OK");
}
