//! Ablation of §4.2's hash path algorithm: "due to the characteristics of
//! hash functions the files are distributed evenly over the directories,
//! which is beneficial for the majority of filesystems". We compare
//! directory fan-out balance (max files per directory) of the md5-hash
//! layout vs a naive run-number layout, plus lfn2pfn throughput.

use std::collections::BTreeMap;

use rucio::benchkit::{bench_throughput, section, Table};
use rucio::core::rse::hash_pfn;

fn main() {
    section("Ablation: hash lfn2pfn directory balance vs naive layout");
    let n = 100_000usize;
    // realistic ATLAS-ish names cluster by run number
    let names: Vec<String> = (0..n)
        .map(|i| format!("data18.{:08}.physics_Main.RAW._lb{:04}", 358_000 + i / 1000, i % 1000))
        .collect();

    // hash layout
    let mut hash_dirs: BTreeMap<String, usize> = BTreeMap::new();
    for name in &names {
        let pfn = hash_pfn("data18", name);
        let dir: String = pfn.rsplitn(2, '/').nth(1).unwrap().to_string();
        *hash_dirs.entry(dir).or_insert(0) += 1;
    }
    // naive layout: /scope/<run>/name
    let mut naive_dirs: BTreeMap<String, usize> = BTreeMap::new();
    for name in &names {
        let run = name.split('.').nth(1).unwrap();
        *naive_dirs.entry(format!("/data18/{run}")).or_insert(0) += 1;
    }

    let stats = |dirs: &BTreeMap<String, usize>| {
        let max = *dirs.values().max().unwrap();
        let mean = n as f64 / dirs.len() as f64;
        (dirs.len(), max, mean)
    };
    let (hd, hmax, hmean) = stats(&hash_dirs);
    let (nd, nmax, nmean) = stats(&naive_dirs);

    let mut table = Table::new(
        "directory fan-out over 100k files",
        &["layout", "dirs", "max files/dir", "mean files/dir", "max/mean"],
    );
    table.row(&[
        "hash (md5/2-level)".into(),
        hd.to_string(),
        hmax.to_string(),
        format!("{hmean:.1}"),
        format!("{:.1}", hmax as f64 / hmean),
    ]);
    table.row(&[
        "naive (by run)".into(),
        nd.to_string(),
        nmax.to_string(),
        format!("{nmean:.1}"),
        format!("{:.1}", nmax as f64 / nmean),
    ]);
    let _ = nmean;
    table.print();

    // Poisson balls-in-bins: with ~2 files/dir the expected max is ~10;
    // the signal is the *hot-directory* contrast vs the clustered layout.
    assert!(
        hmax * 10 < nmax,
        "hash hot dir ({hmax}) must be >=10x cooler than naive ({nmax})"
    );
    let _ = (hmean, nmean);

    println!();
    bench_throughput("hash_pfn computations", n, || {
        for name in &names {
            std::hint::black_box(hash_pfn("data18", name));
        }
    });
    println!("abl_lfn2pfn bench OK");
}
