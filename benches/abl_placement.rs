//! Ablation: heat-driven dynamic placement vs a static catalog.
//!
//! A flash-crowd workload reads one dataset that starts with a single
//! replica at a T1. With the heat-driven C3PO daemon enabled, the decayed
//! heat signal crosses the placement threshold within a few access
//! windows and a cache replica appears near the crowd; with placement
//! disabled every read keeps paying the wide-area transfer. Two runs of
//! the identical driver (only `[c3po] enabled` differs) measure
//!
//! 1. **time to first local replica** — sim-ms from the first read until
//!    a read is served by a non-origin replica (static: never), and
//! 2. **transfer bytes saved** — WAN read bytes avoided, net of the
//!    bytes spent creating the cache replica itself.
//!
//! Full mode: 3 days, 8 files x 256 MB (smoke: 1 day, 4 files). Results
//! are written to `BENCH_abl_placement.json` for artifact upload.

use rucio::benchkit::{section, smoke_mode};
use rucio::common::clock::{DAY_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::jsonx::Json;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;
use rucio::storagesim::synthetic_adler32_for;

/// The dataset's only replica lives here at t0.
const SRC: &str = "DE-T1-DISK";

struct RunOut {
    remote_reads: u64,
    local_reads: u64,
    wan_bytes: u64,
    /// Sim-ms from the window start to the first locally-served read.
    ttfl_ms: Option<i64>,
    /// Bytes moved by the transfer machinery (cache creation cost).
    transfer_bytes: u64,
    placements: u64,
}

#[allow(clippy::too_many_arguments)]
fn run(
    heat_on: bool,
    window_days: i64,
    tick_ms: i64,
    obs_ms: i64,
    reads_per_obs: usize,
    files_per: usize,
    file_bytes: u64,
) -> RunOut {
    let mut cfg = Config::new();
    cfg.set("common", "seed", "7");
    cfg.set("c3po", "enabled", if heat_on { "true" } else { "false" });
    let workload = WorkloadSpec {
        raw_datasets_per_day: 0,
        derivations_per_day: 0,
        analysis_accesses_per_day: 0,
        discovery_queries_per_day: 0,
        seed: 7,
        ..Default::default()
    };
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.0, seed: 7, ..Default::default() },
        workload,
        cfg,
    );
    let ctx = driver.ctx.clone();
    let cat = ctx.catalog.clone();
    let sys = ctx.fleet.get(SRC).expect("grid RSE");

    // -- corpus: one dataset, resident only at the origin, pinned there --
    let now = cat.now();
    cat.add_dataset("data18", "crowd.ds", "prod").unwrap();
    let ds = DidKey::new("data18", "crowd.ds");
    let mut files: Vec<DidKey> = Vec::with_capacity(files_per);
    for f in 0..files_per {
        let name = format!("crowd.f{f}");
        let adler = synthetic_adler32_for(&name, file_bytes);
        cat.add_file("data18", &name, "prod", file_bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        cat.attach(&ds, &key).unwrap();
        let rep = cat.add_replica(SRC, &key, ReplicaState::Available, None).unwrap();
        sys.put(&rep.pfn, file_bytes, now).unwrap();
        files.push(key);
    }
    cat.add_rule(RuleSpec::new("prod", ds.clone(), SRC, 1)).unwrap();

    // -- the crowd: round-robin reads on the driver's observation cadence
    let t0 = cat.now();
    let (mut remote_reads, mut local_reads, mut wan_bytes) = (0u64, 0u64, 0u64);
    let mut ttfl_ms: Option<i64> = None;
    let mut idx = 0usize;
    let stats = driver.run_span(window_days * DAY_MS, tick_ms, obs_ms, |c| {
        let cat = &c.catalog;
        let read_now = cat.now();
        for _ in 0..reads_per_obs {
            let key = &files[idx % files.len()];
            idx += 1;
            match cat.available_replicas(key).into_iter().find(|r| r.rse != SRC) {
                Some(cached) => {
                    local_reads += 1;
                    ttfl_ms.get_or_insert(read_now - t0);
                    cat.touch_replica(&cached.rse, key);
                }
                None => {
                    remote_reads += 1;
                    wan_bytes += file_bytes;
                    cat.touch_replica(SRC, key);
                }
            }
        }
    });

    RunOut {
        remote_reads,
        local_reads,
        wan_bytes,
        ttfl_ms,
        transfer_bytes: stats.bytes_transferred,
        placements: cat.metrics.counter("c3po.placements"),
    }
}

fn main() {
    let (days, tick_ms, files_per, reads_per_obs) = if smoke_mode() {
        (1i64, 10 * MINUTE_MS, 4usize, 4usize)
    } else {
        (3i64, 5 * MINUTE_MS, 8usize, 6usize)
    };
    let file_bytes = 256_000_000u64;
    let obs_ms = 30 * MINUTE_MS;
    let window_ms = days * DAY_MS;

    section(&format!(
        "Ablation: heat-driven placement vs static, {days}d window, {files_per} x 256 MB"
    ));
    let stat = run(false, days, tick_ms, obs_ms, reads_per_obs, files_per, file_bytes);
    println!(
        "static:      {} remote reads, {:.1} GB over the WAN, local replica: never",
        stat.remote_reads,
        stat.wan_bytes as f64 / 1e9
    );
    let heat = run(true, days, tick_ms, obs_ms, reads_per_obs, files_per, file_bytes);
    println!(
        "heat-driven: {} remote / {} local reads, {:.1} GB WAN + {:.1} GB cache fill, \
         first local read after {:.1}h",
        heat.remote_reads,
        heat.local_reads,
        heat.wan_bytes as f64 / 1e9,
        heat.transfer_bytes as f64 / 1e9,
        heat.ttfl_ms.unwrap_or(window_ms) as f64 / 3_600_000.0
    );

    // net savings: WAN reads avoided minus the cache-fill cost
    let static_total = stat.wan_bytes + stat.transfer_bytes;
    let heat_total = heat.wan_bytes + heat.transfer_bytes;
    let saved = static_total as i64 - heat_total as i64;
    println!("transfer bytes saved: {:.2} GB", saved as f64 / 1e9);

    assert_eq!(stat.local_reads, 0, "static run must never see a cache replica");
    assert!(stat.ttfl_ms.is_none());
    assert!(heat.placements >= 1, "heat daemon placed at least one cache replica");
    assert!(heat.ttfl_ms.is_some(), "crowd reads went local within the window");
    assert!(heat.local_reads > 0);
    assert!(saved > 0, "heat-driven placement must save transfer bytes net of cache fill");

    let results = Json::obj()
        .with("bench", "abl_placement")
        .with("window_ms", window_ms)
        .with("files", files_per as u64)
        .with("file_bytes", file_bytes)
        .with("static_remote_reads", stat.remote_reads)
        .with("static_wan_bytes", stat.wan_bytes)
        .with("heat_remote_reads", heat.remote_reads)
        .with("heat_local_reads", heat.local_reads)
        .with("heat_wan_bytes", heat.wan_bytes)
        .with("heat_cache_fill_bytes", heat.transfer_bytes)
        .with("heat_placements", heat.placements)
        .with("time_to_first_local_ms", heat.ttfl_ms.unwrap_or(window_ms))
        .with("static_time_to_first_local_ms", window_ms)
        .with("static_ever_local", false)
        .with("bytes_saved", saved);
    std::fs::write("BENCH_abl_placement.json", results.to_string()).unwrap();
    println!("\nabl_placement bench OK (BENCH_abl_placement.json written)");
}
