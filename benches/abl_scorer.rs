//! Ablation: Pallas/PJRT placement scorer vs the pure-Rust reference.
//! Checks (a) decision agreement on identical inputs and (b) throughput
//! (scorings/second) — the PJRT path pays artifact-execution overhead at
//! this tiny shape on CPU, which is the documented trade-off (on real TPU
//! hardware the roles invert at scale; DESIGN.md §7).

use rucio::benchkit::{bench, section};
use rucio::placement::DEFAULT_WEIGHTS;
use rucio::runtime::{artifacts_available, ref_placement_score, Runtime};

fn main() {
    section("Ablation: PJRT (Pallas) scorer vs pure-Rust reference");
    if !artifacts_available() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::load_default().unwrap();
    let d = rt.manifest.n_features;
    let n = 64usize;
    let features: Vec<f32> = (0..n * d).map(|i| ((i * 31 % 17) as f32 - 8.0) / 5.0).collect();
    let mask: Vec<f32> = (0..n).map(|i| if i % 5 == 0 { 0.0 } else { 1.0 }).collect();
    let weights = DEFAULT_WEIGHTS.to_vec();

    // agreement
    let (s_ref, p_ref) = ref_placement_score(&features, &weights, &mask);
    let (s_pjrt, p_pjrt) = rt.placement_score(&features, &weights, &mask).unwrap();
    let argmax = |v: &[f32]| {
        v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    };
    assert_eq!(argmax(&p_ref), argmax(&p_pjrt), "identical decisions");
    let max_delta = s_ref
        .iter()
        .zip(&s_pjrt)
        .filter(|(r, _)| **r > -1e29)
        .map(|(r, p)| (r - p).abs())
        .fold(0f32, f32::max);
    println!("max |score delta| on valid rows: {max_delta:.2e}\n");

    // throughput
    let r_ref = bench("rust reference scorer (64 cand)", 20, 200, || {
        std::hint::black_box(ref_placement_score(&features, &weights, &mask));
    });
    let r_pjrt = bench("PJRT Pallas scorer     (64 cand)", 20, 200, || {
        std::hint::black_box(rt.placement_score(&features, &weights, &mask).unwrap());
    });
    println!(
        "\nPJRT/ref time ratio: {:.1}x (CPU interpret path; structure, not wallclock, is the TPU signal)",
        r_pjrt.mean_ns / r_ref.mean_ns
    );
    println!("abl_scorer bench OK");
}
