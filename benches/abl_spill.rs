//! Ablation: spill-to-disk tables (paged mode) — a DID-shaped namespace
//! under a fixed hot-row budget vs the unbounded in-memory baseline.
//!
//! Full mode builds a 1M-row namespace over 64 shards and pins the
//! paged table to a 200k hot-row budget (smoke: 20k rows / 16 shards /
//! 5k budget); eviction is driven the way the checkpointer drives it —
//! an `enforce_budget` sweep after load and after each maintenance
//! round, not per write. Measured phases:
//!
//! 1. **Load** — bulk inserts, baseline vs paged (identical until the
//!    eviction sweep runs).
//! 2. **Point queries** — LCG-scattered gets; the paged table serves
//!    cold shards straight from their spill files.
//! 3. **Range queries** — cursor pagination over the global key order,
//!    which overlays cold shards on the fly.
//! 4. **Sustained overwrite churn** — repeated single-row upserts over
//!    a small key set with incremental checkpoints, WAL compaction, and
//!    budget sweeps interleaved; hot rows must stay under budget and
//!    the folded WAL must stay small after every maintenance round.
//! 5. **Crash recovery** — cold boot from manifest + shard files + WAL
//!    suffix into a fresh table.
//!
//! The hot-row budget assertion (`spill_stats().hot_rows <= budget`
//! after each sweep) runs in BOTH modes — it is the CI smoke guard that
//! paged mode actually bounds memory. Results are written to
//! `BENCH_abl_spill.json` for artifact upload.

use rucio::benchkit::{bench, bench_indexed, bench_throughput, section, smoke_mode};
use rucio::db::{Durable, Row, Table, WalOptions};
use rucio::jsonx::Json;
use rucio::{Result, RucioError};

/// A DID-shaped row: scope:name identity, size, checksum, state.
#[derive(Clone, Debug)]
struct BenchDid {
    id: u64,
    name: String,
    bytes: u64,
    adler32: String,
    state: &'static str,
}

impl Row for BenchDid {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl Durable for BenchDid {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id)
            .with("name", self.name.as_str())
            .with("bytes", self.bytes)
            .with("adler32", self.adler32.as_str())
            .with("state", self.state)
    }
    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(BenchDid {
            id: j.req_u64("id")?,
            name: j.req_str("name")?.to_string(),
            bytes: j.req_u64("bytes")?,
            adler32: j.req_str("adler32")?.to_string(),
            state: if j.req_str("state")? == "AVAILABLE" { "AVAILABLE" } else { "COPYING" },
        })
    }
    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }
    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| RucioError::JsonError("bad key".into()))
    }
}

fn did(id: u64) -> BenchDid {
    BenchDid {
        id,
        name: format!("data18_13TeV.{id:010}.AOD.pool.root"),
        bytes: 1_000_000 + (id % 7) * 333_333,
        adler32: format!("{:08x}", id ^ 0x5A5A_5A5A),
        state: "AVAILABLE",
    }
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("rucio-abl-spill-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Deterministic LCG over `[0, n)` for scattered query keys.
fn lcg_ids(n: u64, count: usize) -> Vec<u64> {
    let mut x = 0x9E37_79B9_7F4A_7C15u64;
    (0..count)
        .map(|_| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x % n
        })
        .collect()
}

fn load(t: &Table<BenchDid>, n: u64, batch: usize) {
    let mut rows = Vec::with_capacity(batch);
    for id in 0..n {
        rows.push(did(id));
        if rows.len() == batch {
            t.insert_bulk(std::mem::take(&mut rows), 0).unwrap();
            rows.reserve(batch);
        }
    }
    if !rows.is_empty() {
        t.insert_bulk(rows, 0).unwrap();
    }
}

/// The smoke guard: after a budget sweep the hot set fits the budget.
fn assert_under_budget(t: &Table<BenchDid>, what: &str) {
    let s = t.spill_stats();
    assert!(
        s.hot_rows <= s.budget,
        "{what}: paged table over budget ({} hot > {} budget)",
        s.hot_rows,
        s.budget
    );
}

fn main() {
    let (n, shards, budget, batch) = if smoke_mode() {
        (20_000u64, 16usize, 5_000usize, 2_000usize)
    } else {
        (1_000_000u64, 64usize, 200_000usize, 10_000usize)
    };
    let opts = WalOptions { fsync: false, group_commit: true, leader: true };
    let mut results = Json::obj()
        .with("bench", "abl_spill")
        .with("rows", n)
        .with("shards", shards)
        .with("budget", budget);

    section(&format!("Ablation: spill-to-disk at {n} DIDs, {shards} shards, budget {budget}"));

    // -- load ---------------------------------------------------------
    let dir_base = temp_dir("baseline");
    let baseline: Table<BenchDid> = Table::new("dids").with_shards(shards);
    baseline.attach_wal(&dir_base, opts).unwrap();
    let r = bench_throughput("load: in-memory baseline", n as usize, || {
        load(&baseline, n, batch);
    });
    results.set("load_baseline_ops_per_sec", r.ops_per_sec());
    assert_eq!(baseline.len(), n as usize);

    let dir_spill = temp_dir("spill");
    let spill: Table<BenchDid> = Table::new("dids").with_shards(shards);
    spill.attach_wal(&dir_spill, opts).unwrap();
    let r = bench_throughput("load: paged table", n as usize, || {
        load(&spill, n, batch);
    });
    results.set("load_spill_ops_per_sec", r.ops_per_sec());
    spill.set_memory_budget(budget);
    let r = bench_throughput("eviction sweep to budget", n as usize, || {
        spill.enforce_budget().unwrap();
    });
    results.set("eviction_sweep_rows_per_sec", r.ops_per_sec());
    assert_under_budget(&spill, "after load sweep");
    let s = spill.spill_stats();
    assert!(s.cold_shards > 0, "the sweep must actually spill shards: {s:?}");
    println!(
        "paged shape: {}/{} shards cold, {} hot + {} cold rows, {} evictions",
        s.cold_shards, s.shard_count, s.hot_rows, s.cold_rows, s.evictions
    );

    // first checkpoints: the paged one skips cold shards
    let ck_b = baseline.checkpoint().unwrap();
    let ck_s = spill.checkpoint().unwrap();
    println!(
        "checkpoint: baseline wrote {}/{} shards | paged wrote {}/{} (cold skipped)",
        ck_b.shards_written,
        ck_b.shards_written + ck_b.shards_skipped,
        ck_s.shards_written,
        ck_s.shards_written + ck_s.shards_skipped
    );
    assert!(ck_s.shards_skipped >= s.cold_shards, "cold shards skipped by the checkpoint");

    // -- point queries ------------------------------------------------
    section("Point gets (LCG-scattered keys)");
    let (warm, iters) = (20usize, 200usize);
    let ids = lcg_ids(n, warm + iters);
    let r = bench_indexed("get: baseline (all hot)", warm, iters, |i| {
        assert!(baseline.get(&ids[i]).is_some());
    });
    results.set("point_get_baseline_ns", r.p50_ns);
    let reads_before = spill.spill_stats().disk_reads;
    let r = bench_indexed("get: paged (mostly cold)", warm, iters, |i| {
        let row = spill.get(&ids[i]).unwrap();
        assert_eq!(row.adler32, format!("{:08x}", ids[i] ^ 0x5A5A_5A5A));
    });
    results.set("point_get_spill_ns", r.p50_ns);
    let disk_reads = spill.spill_stats().disk_reads - reads_before;
    results.set("point_get_disk_reads", disk_reads);
    println!("{disk_reads} of {} paged gets came from spill files", warm + iters);
    assert_under_budget(&spill, "after point gets");

    // -- range queries ------------------------------------------------
    section("Range pagination (3 pages x 2000 rows)");
    let walk = |t: &Table<BenchDid>| {
        let mut cursor: Option<u64> = None;
        let mut seen = 0usize;
        for _ in 0..3 {
            let page = t.scan_page(cursor.as_ref(), 2_000);
            seen += page.rows.len();
            match page.next_cursor {
                Some(c) => cursor = Some(c),
                None => break,
            }
        }
        assert_eq!(seen, 6_000.min(t.len()));
    };
    let r = bench("range walk: baseline", 1, 3, || walk(&baseline));
    results.set("range_walk_baseline_ns", r.p50_ns);
    let r = bench("range walk: paged", 1, 3, || walk(&spill));
    results.set("range_walk_spill_ns", r.p50_ns);
    assert_under_budget(&spill, "after range walks");

    // -- sustained overwrite churn + maintenance ---------------------
    section("Sustained overwrites with incremental checkpoints + compaction");
    let (rounds, churn, keyspace) =
        if smoke_mode() { (2usize, 2_000u64, 500u64) } else { (4usize, 25_000u64, 5_000u64) };
    let mut max_wal_bytes = 0u64;
    let t0 = std::time::Instant::now();
    for round in 0..rounds {
        for i in 0..churn {
            // row-at-a-time: each upsert is one WAL record, so the
            // compaction rounds have real folding to do
            spill.upsert(did((i * 31 + round as u64) % keyspace), round as i64);
        }
        if round % 2 == 0 {
            let cs = spill.compact_wal().unwrap();
            assert!(
                cs.records_after < cs.records_before,
                "churn over {keyspace} keys must fold: {cs:?}"
            );
        } else {
            spill.checkpoint().unwrap();
        }
        spill.enforce_budget().unwrap();
        assert_under_budget(&spill, "after maintenance round");
        max_wal_bytes = max_wal_bytes.max(spill.wal_stats().unwrap().bytes);
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let rate = (rounds as u64 * churn) as f64 / elapsed.max(1e-9);
    println!(
        "{} overwrites in {rounds} rounds: {rate:.0} op/s, max WAL {} bytes after maintenance",
        rounds as u64 * churn,
        max_wal_bytes
    );
    results.set("overwrite_ops_per_sec", rate);
    results.set("max_wal_bytes_after_maintenance", max_wal_bytes);

    // -- crash recovery ----------------------------------------------
    section("Crash recovery (manifest + shard files + WAL suffix)");
    let recovered: Table<BenchDid> = Table::new("dids").with_shards(shards);
    let r = bench_throughput("cold boot", n as usize, || {
        recovered.recover_from_dir(&dir_spill).unwrap();
    });
    results.set("recovery_rows_per_sec", r.ops_per_sec());
    assert_eq!(recovered.len(), n as usize, "every row survives the crash");
    for id in lcg_ids(n, 50) {
        assert_eq!(recovered.get(&id).map(|r| r.id), Some(id));
    }
    // post-boot budget enforcement bounds the recovered RSS too
    recovered.set_memory_budget(budget);
    recovered.enforce_budget().unwrap();
    assert_under_budget(&recovered, "after recovery sweep");

    let s = spill.spill_stats();
    results.set("final_cold_shards", s.cold_shards);
    results.set("final_evictions", s.evictions);
    results.set("final_fault_ins", s.fault_ins);
    results.set("final_disk_reads", s.disk_reads);

    std::fs::remove_dir_all(&dir_base).ok();
    std::fs::remove_dir_all(&dir_spill).ok();
    std::fs::write("BENCH_abl_spill.json", results.to_string()).unwrap();
    println!("\nabl_spill bench OK (BENCH_abl_spill.json written)");
}
