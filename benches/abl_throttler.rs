//! Ablation: submission throughput with the transfer throttler ON vs
//! OFF, at 10k/100k queued requests (2k in `RUCIO_BENCH_SMOKE` mode).
//!
//! OFF: rule creation queues every request directly and one submitter
//! tick drives the whole backlog to SUBMITTED. ON: requests are born
//! WAITING; a throttler tick (deficit-round-robin admission over the
//! estimated links, cap lifted so admission itself is what's measured)
//! releases them and the submitter drains as before. The assertion
//! bounds the admission overhead on the hottest path in the system —
//! the request state machine.

use std::sync::Arc;

use rucio::benchkit::{bench_throughput, section, smoke_mode};
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::rse::Rse;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RequestState};
use rucio::core::Catalog;
use rucio::daemons::conveyor::Submitter;
use rucio::daemons::throttler::Throttler;
use rucio::daemons::{Ctx, Daemon};
use rucio::ftssim::FtsServer;
use rucio::mq::Broker;
use rucio::netsim::{Link, Network};
use rucio::storagesim::{Fleet, StorageKind, StorageSystem};

fn rig(throttled: bool, n: usize) -> (Ctx, Arc<Catalog>) {
    let mut cfg = Config::new();
    cfg.set("conveyor", "bulk", n.to_string());
    if throttled {
        cfg.set("throttler", "enabled", "true");
        // lift the cap: the bench measures admission machinery, not the
        // (intentional) pacing a production cap applies
        cfg.set("throttler", "max_per_link", "1000000000");
        cfg.set("throttler", "bulk", n.to_string());
    }
    let catalog = Arc::new(Catalog::new(Clock::sim_at(1_600_000_000_000), cfg));
    let now = catalog.now();
    catalog.add_scope("bench", "root").unwrap();
    let fleet = Arc::new(Fleet::new());
    let net = Arc::new(Network::new());
    for name in ["SRC", "DST"] {
        catalog
            .add_rse(Rse::new(name, now).with_attr("site", name))
            .unwrap();
        fleet.add(StorageSystem::new(name, StorageKind::Disk, u64::MAX));
    }
    net.set_link_bidir("SRC", "DST", Link::new(100_000_000, 5, 1.0));
    let broker = Broker::new();
    let fts = vec![Arc::new(FtsServer::new(
        "fts1",
        net.clone(),
        fleet.clone(),
        Some(broker.clone()),
    ))];
    let ctx = Ctx::new(catalog.clone(), fleet, net, fts, broker);
    (ctx, catalog)
}

/// One rule over an n-file dataset → n transfer requests through the
/// batched path; every file has a source replica so ranking works.
fn seed_backlog(cat: &Catalog, n: usize) {
    cat.add_dataset("bench", "ds", "root").unwrap();
    let ds = DidKey::new("bench", "ds");
    for i in 0..n {
        let name = format!("f{i:06}");
        cat.add_file("bench", &name, "root", 1_000, "aabbccdd", None).unwrap();
        let key = DidKey::new("bench", &name);
        cat.add_replica("SRC", &key, ReplicaState::Available, None).unwrap();
        cat.attach(&ds, &key).unwrap();
    }
    cat.add_rule(RuleSpec::new("root", ds, "DST", 1)).unwrap();
}

fn main() {
    section("Ablation: throttler admission ON vs OFF (submission throughput)");
    let sizes: Vec<usize> = if smoke_mode() { vec![2_000] } else { vec![10_000, 100_000] };

    for n in sizes {
        // --- throttler OFF: rule → QUEUED → one submitter drain -------
        let (ctx, cat) = rig(false, n);
        seed_backlog(&cat, n);
        assert_eq!(cat.requests_by_state.count(&RequestState::Queued), n);
        let mut submitter = Submitter::new(ctx.clone(), "s1");
        let off = bench_throughput(&format!("{n} requests, throttler OFF"), n, || {
            submitter.tick(cat.now());
        });
        assert_eq!(
            cat.requests_by_state.count(&RequestState::Submitted),
            n,
            "direct path submits the whole backlog"
        );

        // --- throttler ON: rule → WAITING → admit → drain -------------
        let (ctx, cat) = rig(true, n);
        seed_backlog(&cat, n);
        assert_eq!(cat.requests_by_state.count(&RequestState::Waiting), n);
        let mut throttler = Throttler::new(ctx.clone(), "t1");
        let mut submitter = Submitter::new(ctx.clone(), "s1");
        let on = bench_throughput(&format!("{n} requests, throttler ON"), n, || {
            throttler.tick(cat.now());
            submitter.tick(cat.now());
        });
        assert_eq!(
            cat.requests_by_state.count(&RequestState::Submitted),
            n,
            "admitted path submits the whole backlog"
        );

        let overhead = on.mean_ns / off.mean_ns;
        println!(
            "\n{n}: admission overhead {overhead:.2}x \
             ({:.0} vs {:.0} requests/s)\n",
            on.ops_per_sec(),
            off.ops_per_sec()
        );
        assert!(
            overhead < 10.0,
            "throttler admission must stay within 10x of direct submission \
             (got {overhead:.2}x at {n})"
        );
    }
    println!("abl_throttler bench OK");
}
