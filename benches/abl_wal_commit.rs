//! Ablation: WAL commit strategies — group commit ON vs OFF crossed
//! with fsync ON vs OFF, against a no-WAL baseline, at 100k mutations
//! in 1k batches (1k mutations in 100-row batches under
//! `RUCIO_BENCH_SMOKE`).
//!
//! Group commit writes one checksummed frame (and issues at most one
//! fsync) per *table commit* — a bulk batch of 1 000 rows costs one
//! write syscall — while the OFF baseline frames and fsyncs every
//! record individually, which is how the PR 1 bulk mutation path would
//! behave with a naive per-row log. The headline number is the
//! group-vs-per-record ratio under fsync: the durability tax the
//! batched path avoids. Asserted ≥ 5x in full mode (CI runs smoke mode,
//! where timings are meaningless; the run still proves the four
//! configurations execute and recover).

use rucio::benchkit::{bench_throughput, section, smoke_mode, BenchResult};
use rucio::db::{Durable, Row, Table, WalOptions};
use rucio::jsonx::Json;
use rucio::{Result, RucioError};

#[derive(Clone, Debug)]
struct BenchRow {
    id: u64,
    payload: String,
}

impl Row for BenchRow {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl Durable for BenchRow {
    fn row_to_json(&self) -> Json {
        Json::obj().with("id", self.id).with("payload", self.payload.as_str())
    }
    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(BenchRow { id: j.req_u64("id")?, payload: j.req_str("payload")?.to_string() })
    }
    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }
    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| RucioError::JsonError("bad key".into()))
    }
}

fn rows(n: usize) -> Vec<BenchRow> {
    (0..n as u64)
        .map(|id| BenchRow {
            id,
            payload: format!("replica-{id:012}-adler32-{:08x}-state-AVAILABLE", id ^ 0xA5A5),
        })
        .collect()
}

/// Run `n` upserts in batches of `batch` against a table with the given
/// WAL configuration (`None` = no WAL attached). Returns per-op stats.
fn run(name: &str, n: usize, batch: usize, opts: Option<WalOptions>) -> BenchResult {
    static DIR_N: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "rucio-abl-wal-{}-{}",
        std::process::id(),
        DIR_N.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let t: Table<BenchRow> = Table::new("bench").with_shards(8);
    if let Some(o) = opts {
        t.attach_wal(&dir, o).unwrap();
    }
    let data = rows(n);
    let result = bench_throughput(name, n, || {
        for chunk in data.chunks(batch) {
            t.upsert_bulk(chunk.to_vec(), 0);
        }
    });
    assert_eq!(t.len(), n, "every mutation applied");
    if opts.is_some() {
        // durability sanity: the log replays back to the same table
        let r: Table<BenchRow> = Table::new("bench").with_shards(8);
        r.recover_from_dir(&dir).unwrap();
        assert_eq!(r.len(), n, "recovery replays the full log");
    }
    std::fs::remove_dir_all(&dir).ok();
    result
}

fn main() {
    section("Ablation: WAL group commit × fsync (100k upserts in 1k batches)");
    let (n, batch) = if smoke_mode() { (1_000, 100) } else { (100_000, 1_000) };

    let baseline = run(&format!("{n} upserts, no WAL"), n, batch, None);
    let group = run(
        &format!("{n} upserts, group commit, no fsync"),
        n,
        batch,
        Some(WalOptions { fsync: false, group_commit: true, leader: true }),
    );
    let per_record = run(
        &format!("{n} upserts, per-record, no fsync"),
        n,
        batch,
        Some(WalOptions { fsync: false, group_commit: false, leader: true }),
    );
    let group_fsync = run(
        &format!("{n} upserts, group commit + fsync"),
        n,
        batch,
        Some(WalOptions { fsync: true, group_commit: true, leader: true }),
    );
    let per_record_fsync = run(
        &format!("{n} upserts, per-record + fsync"),
        n,
        batch,
        Some(WalOptions { fsync: true, group_commit: false, leader: true }),
    );

    let wal_tax = group.mean_ns / baseline.mean_ns;
    let frame_ratio = per_record.mean_ns / group.mean_ns;
    let fsync_ratio = per_record_fsync.mean_ns / group_fsync.mean_ns;
    println!(
        "\n{n}: WAL tax {wal_tax:.2}x over no-WAL | per-record framing {frame_ratio:.2}x \
         over group | per-record fsync {fsync_ratio:.2}x over group-commit fsync\n"
    );
    if !smoke_mode() {
        assert!(
            fsync_ratio >= 5.0,
            "group commit must beat per-record fsync by >= 5x at {n} mutations \
             (got {fsync_ratio:.2}x)"
        );
    }
    println!("abl_wal_commit bench OK");
}
