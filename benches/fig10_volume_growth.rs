//! Fig 10 reproduction: total managed volume over time — the paper shows
//! near-linear growth "both during and between data taking periods",
//! approaching 450 PB at the end of 2018. Shape check: monotone growth
//! with a roughly constant daily increment once deletion reaches steady
//! state.

use rucio::benchkit::{section, Table};
use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::common::units::fmt_bytes;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

fn main() {
    section("Fig 10: total managed volume over time");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec::default(),
        Config::new(),
    );
    let days = 14;
    driver.run_days(days, 10 * MINUTE_MS);

    let mut table = Table::new("managed volume by day", &["day", "volume", "files", "replicas"]);
    for d in &driver.days {
        table.row(&[
            d.day.to_string(),
            fmt_bytes(d.bytes_managed),
            d.files.to_string(),
            d.replicas.to_string(),
        ]);
    }
    table.print();

    // shape: strictly growing in the accumulation phase
    let vols: Vec<u64> = driver.days.iter().map(|d| d.bytes_managed).collect();
    let grew = vols.windows(2).filter(|w| w[1] > w[0]).count();
    println!(
        "\ngrowth days: {grew}/{} | first={} last={}",
        vols.len() - 1,
        fmt_bytes(vols[0]),
        fmt_bytes(*vols.last().unwrap())
    );
    assert!(
        grew as f64 >= (vols.len() - 1) as f64 * 0.8,
        "volume must grow on >=80% of days (linear growth shape)"
    );
    // roughly linear: second-half increment within 3x of first-half
    let mid = vols.len() / 2;
    let inc1 = vols[mid].saturating_sub(vols[0]).max(1);
    let inc2 = vols.last().unwrap().saturating_sub(vols[mid]).max(1);
    let ratio = inc2 as f64 / inc1 as f64;
    println!("half-to-half increment ratio: {ratio:.2} (1.0 = perfectly linear)");
    assert!((0.3..3.0).contains(&ratio), "growth should be near-linear");
    println!("fig10 bench OK");
}
