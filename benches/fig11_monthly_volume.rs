//! Fig 11 reproduction: volume transferred per month, split by destination
//! region, with a conference-season burst ("peaking at a record 55
//! Petabytes in November"). We simulate 3 compressed months (10 days
//! each), the last with an analysis burst, and check: steady baseline
//! months + a visibly higher burst month, with every region receiving.

use rucio::benchkit::{section, Table};
use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::common::units::fmt_bytes;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

fn main() {
    section("Fig 11: transfer volume per month by destination region");
    let month_days = 10u32;
    let months = 3u32;
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec {
            // burst in the last "month" (the November analog)
            burst: Some((month_days * 2, month_days * 3, 3.0)),
            analysis_accesses_per_day: 150,
            ..Default::default()
        },
        Config::new(),
    );
    // C3PO converts the analysis burst into placement transfers (the
    // paper's November surge is analysis-season dataflow).
    let mut c3po = rucio::placement::C3po::new(driver.ctx.clone(), Box::new(rucio::placement::RefScorer));
    c3po.threshold = 3;
    for _ in 0..months * month_days {
        driver.run_days(1, 10 * MINUTE_MS);
        rucio::daemons::Daemon::tick(&mut c3po, driver.ctx.catalog.now());
    }

    let mut monthly: Vec<(u64, std::collections::BTreeMap<String, u64>)> = Vec::new();
    for m in 0..months {
        let mut total = 0u64;
        let mut by_region = std::collections::BTreeMap::new();
        for d in driver
            .days
            .iter()
            .skip((m * month_days) as usize)
            .take(month_days as usize)
        {
            total += d.bytes_transferred;
            for (r, b) in &d.bytes_by_dst_region {
                *by_region.entry(r.clone()).or_insert(0) += b;
            }
        }
        monthly.push((total, by_region));
    }

    let mut table = Table::new("monthly transferred volume", &["month", "total", "top regions"]);
    for (m, (total, by_region)) in monthly.iter().enumerate() {
        let mut regions: Vec<(&String, &u64)> = by_region.iter().collect();
        regions.sort_by(|a, b| b.1.cmp(a.1));
        let top: Vec<String> = regions
            .iter()
            .take(4)
            .map(|(r, b)| format!("{r}={}", fmt_bytes(**b)))
            .collect();
        table.row(&[m.to_string(), fmt_bytes(*total), top.join(" ")]);
    }
    table.print();

    // shape checks
    let burst = monthly[2].0;
    let base = monthly[1].0.max(1);
    println!("\nburst month / baseline month = {:.2}x", burst as f64 / base as f64);
    assert!(burst as f64 > base as f64 * 1.1, "burst month must stand out");
    assert!(
        monthly[1].1.len() >= 8,
        "most regions receive data: {}",
        monthly[1].1.len()
    );
    println!("fig11 bench OK");
}
