//! Fig 6 reproduction: "number of requests submitted to FTS split by
//! activity over time". Expected shape: T0 Export (subscriptions) and
//! Production (consolidation) dominate steadily; Staging appears in
//! recall campaigns; Dynamic Placement/Rebalancing stay small.

use rucio::benchkit::{section, Table};
use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

fn main() {
    section("Fig 6: FTS submissions by activity over time");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec::default(),
        Config::new(),
    );
    let days = 10;
    driver.run_days(days, 10 * MINUTE_MS);

    let mut activities: Vec<String> = driver
        .days
        .iter()
        .flat_map(|d| d.submissions_by_activity.keys().cloned())
        .collect();
    activities.sort();
    activities.dedup();

    let headers: Vec<&str> = std::iter::once("day")
        .chain(activities.iter().map(|s| s.as_str()))
        .collect();
    let mut table = Table::new("FTS submissions / day by activity", &headers);
    for d in &driver.days {
        let mut row = vec![d.day.to_string()];
        for act in &activities {
            row.push(d.submissions_by_activity.get(act).copied().unwrap_or(0).to_string());
        }
        table.row(&row);
    }
    table.print();

    // shape assertions
    let total_t0: u64 = driver
        .days
        .iter()
        .filter_map(|d| d.submissions_by_activity.get("T0 Export"))
        .sum();
    let total_prod: u64 = driver
        .days
        .iter()
        .filter_map(|d| d.submissions_by_activity.get("Production"))
        .sum();
    println!("\ntotals: T0 Export={total_t0}  Production={total_prod}");
    assert!(total_t0 > 0 && total_prod > 0, "both major activities present");
    println!("fig6 bench OK");
}
