//! Fig 8 reproduction: the region×region transfer-efficiency matrix.
//! We do not match absolute cells (our substrate is a simulator); the
//! *structure* must hold: CERN/CA/ND/RU rows strong, DE/ES/US rows weak,
//! overall efficiencies in the 40–100% band the paper shows.

use rucio::benchkit::section;
use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::{GridSpec, REGIONS};
use rucio::sim::workload::WorkloadSpec;

fn main() {
    section("Fig 8: transfer efficiency matrix (src region x dst region)");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, ..Default::default() },
        WorkloadSpec { analysis_accesses_per_day: 200, ..Default::default() },
        Config::new(),
    );
    driver.run_days(12, 10 * MINUTE_MS);
    let matrix = driver.efficiency_matrix();

    print!("{:>6}", "");
    for dst in REGIONS {
        print!("{dst:>6}");
    }
    println!();
    let mut row_means: Vec<(String, f64)> = Vec::new();
    for src in REGIONS {
        print!("{src:>6}");
        let mut sum = 0.0;
        let mut n = 0;
        for dst in REGIONS {
            match matrix.get(&(src.to_string(), dst.to_string())) {
                Some(eff) => {
                    print!("{:>5.0}%", eff * 100.0);
                    sum += eff;
                    n += 1;
                }
                None => print!("{:>6}", "-"),
            }
        }
        println!();
        if n > 0 {
            row_means.push((src.to_string(), sum / n as f64));
        }
    }

    println!("\nrow means (source reliability ordering):");
    let mut sorted = row_means.clone();
    sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (r, m) in &sorted {
        println!("  {r:>5}: {:.0}%", m * 100.0);
    }
    // structural checks: CERN among the best rows, DE/ES/US in lower half
    let mean_of = |r: &str| row_means.iter().find(|(x, _)| x == r).map(|(_, m)| *m);
    if let (Some(cern), Some(de)) = (mean_of("CERN"), mean_of("DE")) {
        assert!(cern > de, "CERN row ({cern:.2}) must beat DE row ({de:.2})");
    }
    for (_, m) in &row_means {
        assert!(*m > 0.3 && *m <= 1.0, "efficiencies in the paper's band");
    }
    println!("fig8 bench OK");
}
