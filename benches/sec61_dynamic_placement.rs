//! §6.1 reproduction: dynamic data placement effectiveness. The paper
//! reports "on average 60 percent of these newly created replicas were
//! quickly used again ... within two weeks" and "half of accessed
//! datasets are accessed more than once". We run the workload with C3PO
//! enabled and measure both statistics, plus a no-placement baseline for
//! the replica-count contrast.

use rucio::benchkit::{section, Table};
use rucio::common::clock::{DAY_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::placement::{C3po, RefScorer};
use rucio::sim::driver::{standard_driver, Driver};
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;
use rucio::daemons::Daemon;

fn workload() -> WorkloadSpec {
    WorkloadSpec {
        analysis_accesses_per_day: 400, // hot analysis season
        derivations_per_day: 6,
        ..Default::default()
    }
}

fn main() {
    section("§6.1: dynamic data placement (C3PO)");
    let days = 16u32;

    // --- with C3PO
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 2, ..Default::default() },
        workload(),
        Config::new(),
    );
    let ctx = driver.ctx.clone();
    let mut c3po = C3po::new(ctx.clone(), Box::new(RefScorer));
    c3po.threshold = 3;
    for _ in 0..days {
        driver.run_days(1, 10 * MINUTE_MS);
        c3po.tick(ctx.catalog.now());
    }
    let cat = ctx.catalog.clone();
    let now = cat.now();

    // reuse within two weeks of the placement decision
    let placements = c3po.decisions.len();
    let reused = c3po
        .decisions
        .iter()
        .filter(|d| {
            cat.popularity
                .get(&d.dataset)
                .map(|p| p.last_access > d.at && p.last_access - d.at <= 14 * DAY_MS)
                .unwrap_or(false)
        })
        .count();
    let reuse_pct = 100.0 * reused as f64 / placements.max(1) as f64;

    // "half of accessed datasets are accessed more than once"
    let mut accessed = 0u64;
    let mut multi = 0u64;
    cat.popularity.for_each(|p| {
        if cat
            .get_did(&p.did)
            .map(|d| d.did_type == rucio::core::types::DidType::Dataset)
            .unwrap_or(false)
        {
            accessed += 1;
            if p.accesses > 1 {
                multi += 1;
            }
        }
    });
    let multi_pct = 100.0 * multi as f64 / accessed.max(1) as f64;

    let mut table = Table::new("§6.1 statistics", &["metric", "measured", "paper"]);
    table.row(&["dynamic placements".into(), placements.to_string(), "-".into()]);
    table.row(&[
        "reused within 2 weeks".into(),
        format!("{reuse_pct:.0}%"),
        "~60%".into(),
    ]);
    table.row(&[
        "accessed datasets hit >1x".into(),
        format!("{multi_pct:.0}%"),
        "~50%".into(),
    ]);
    table.print();

    let _ = now;
    assert!(placements > 0, "C3PO placed replicas");
    assert!(
        reuse_pct >= 40.0,
        "reuse should land in the paper's band (got {reuse_pct:.0}%)"
    );
    assert!(
        multi_pct >= 30.0,
        "repeat-access fraction in the paper's band (got {multi_pct:.0}%)"
    );
    println!("sec61 bench OK");
}
