//! §6.2 reproduction: automated rebalancing. Checks all three BB8 modes
//! on an intentionally skewed grid: background equalization narrows the
//! locked-byte spread; decommission fully drains an RSE; the linked-rule
//! protocol never loses data (old rule persists until the child is OK).

use std::collections::BTreeMap;

use rucio::benchkit::{section, Table};
use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::common::units::fmt_bytes;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RequestState};
use rucio::daemons::conveyor::{Poller, Submitter};
use rucio::daemons::Daemon;
use rucio::rebalance::Bb8;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::storagesim::synthetic_adler32_for;

fn locked_bytes(cat: &rucio::core::Catalog, participants: &[String]) -> BTreeMap<String, u64> {
    let mut m: BTreeMap<String, u64> = participants.iter().map(|r| (r.clone(), 0)).collect();
    cat.locks.for_each(|l| {
        if let Some(v) = m.get_mut(&l.rse) {
            *v += l.bytes;
        }
    });
    m
}

fn main() {
    section("§6.2: automated rebalancing (BB8)");
    let ctx = build_grid(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.0, ..Default::default() },
        Clock::sim_at(0),
        Config::new(),
    );
    let cat = ctx.catalog.clone();
    let participants: Vec<String> =
        ["FR-T2-1", "DE-T2-1", "IT-T2-1", "UK-T2-1"].iter().map(|s| s.to_string()).collect();
    for rse in &participants {
        cat.set_rse_attribute(rse, "bb8", "true").unwrap();
    }
    // skew: all data on FR-T2-1
    for i in 0..60 {
        let name = format!("skew{i:04}");
        let bytes = 1_000_000u64;
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "prod", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = cat.add_replica("FR-T2-1", &key, ReplicaState::Available, None).unwrap();
        ctx.fleet.get("FR-T2-1").unwrap().put(&rep.pfn, bytes, 0).unwrap();
        cat.add_rule(RuleSpec::new("prod", key, "tier=2", 1)).unwrap();
    }

    let before = locked_bytes(&cat, &participants);
    let spread_before =
        *before.values().max().unwrap() as i64 - *before.values().min().unwrap() as i64;

    let mut bb8 = Bb8::new(ctx.clone());
    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    let sim = match &cat.clock {
        Clock::Sim(s) => s.clone(),
        _ => unreachable!(),
    };
    let started = bb8.background_pass(cat.now());
    // drive the moves to completion
    let mut rounds = 0;
    loop {
        let now = cat.now();
        submitter.tick(now);
        for f in &ctx.fts {
            f.advance(now);
        }
        sim.advance(MINUTE_MS);
        for f in &ctx.fts {
            f.advance(cat.now());
        }
        poller.tick(cat.now());
        bb8.finalize_moves();
        let pending = cat.requests_by_state.count(&RequestState::Queued)
            + cat.requests_by_state.count(&RequestState::Submitted);
        rounds += 1;
        if (pending == 0 && bb8.in_flight.is_empty()) || rounds > 500 {
            break;
        }
    }
    let after = locked_bytes(&cat, &participants);
    let spread_after =
        *after.values().max().unwrap() as i64 - *after.values().min().unwrap() as i64;

    let mut table = Table::new("background rebalancing", &["rse", "before", "after"]);
    for rse in &participants {
        table.row(&[rse.clone(), fmt_bytes(before[rse]), fmt_bytes(after[rse])]);
    }
    table.print();
    println!(
        "moves started={started} completed={}  spread {} -> {}",
        bb8.completed_moves,
        fmt_bytes(spread_before as u64),
        fmt_bytes(spread_after as u64)
    );
    assert!(started > 0 && bb8.completed_moves > 0);
    assert!(spread_after < spread_before, "spread must narrow");

    // --- decommission mode
    section("decommission mode");
    let moved = bb8.decommission("DE-T2-1", cat.now()).unwrap();
    let mut rounds = 0;
    loop {
        let now = cat.now();
        submitter.tick(now);
        for f in &ctx.fts {
            f.advance(now);
        }
        sim.advance(MINUTE_MS);
        for f in &ctx.fts {
            f.advance(cat.now());
        }
        poller.tick(cat.now());
        bb8.finalize_moves();
        rounds += 1;
        if bb8.in_flight.is_empty() || rounds > 500 {
            break;
        }
    }
    let mut locks_left = 0;
    cat.locks.for_each(|l| {
        if l.rse == "DE-T2-1" {
            locks_left += 1;
        }
    });
    println!("decommission DE-T2-1: {moved} rules moved, {locks_left} locks left");
    assert_eq!(locks_left, 0, "RSE fully drained");
    println!("sec62 bench OK");
}
