//! §6.3 reproduction: T³C transfer-time prediction. The paper's extension
//! "allows use of simultaneous models and features the ability to easily
//! compare their performance" — we train the MLP (AOT Pallas artifact,
//! online SGD in Rust), the linear baseline, and the naive mean on
//! transfer telemetry from a contended grid, then compare holdout MAE on
//! log-durations. Expected ordering: learned models beat the naive mean
//! (durations vary with size, link, and queue depth).
//!
//! Setup: three links of very different bandwidth, log-normal file sizes,
//! submissions in concurrent waves so fair-share contention and queue
//! waits spread the durations continuously.

use std::sync::Arc;

use rucio::benchkit::{section, Table};
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::common::prng::Prng;
use rucio::core::rse::Rse;
use rucio::core::Catalog;
use rucio::daemons::Ctx;
use rucio::ftssim::{FtsServer, TransferJob, TransferState};
use rucio::mq::Broker;
use rucio::netsim::{Link, Network};
use rucio::storagesim::{synthetic_adler32_for, Fleet, StorageKind, StorageSystem};
use rucio::daemons::Daemon;
use rucio::t3c::{features, Sample, T3c};

fn main() {
    section("§6.3: T3C transfer-time prediction model comparison");
    // --- contended rig
    let catalog = Arc::new(Catalog::new(Clock::sim_at(0), Config::new()));
    catalog.add_scope("data18", "root").unwrap();
    let fleet = Arc::new(Fleet::new());
    let net = Arc::new(Network::new());
    let dsts = ["FAST-DST", "MID-DST", "SLOW-DST"];
    let bws: [u64; 3] = [200_000_000, 20_000_000, 2_000_000]; // B/s
    catalog.add_rse(Rse::new("SRC", 0).with_attr("site", "SRC")).unwrap();
    fleet.add(StorageSystem::new("SRC", StorageKind::Disk, u64::MAX));
    for (d, bw) in dsts.iter().zip(bws) {
        catalog.add_rse(Rse::new(d, 0).with_attr("site", d)).unwrap();
        fleet.add(StorageSystem::new(d, StorageKind::Disk, u64::MAX));
        net.set_link("SRC", d, Link::new(bw, 10, 1.0));
        catalog.set_distance("SRC", d, 2).unwrap();
    }
    let broker = Broker::new();
    let fts = Arc::new(FtsServer::new("fts1", net.clone(), fleet.clone(), Some(broker.clone())));
    let ctx = Ctx::new(catalog.clone(), fleet.clone(), net, vec![fts.clone()], broker.clone());

    let mut t3c = T3c::new(ctx.clone());
    if t3c.mlp.runtime.is_none() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let sim = match &catalog.clock {
        Clock::Sim(s) => s.clone(),
        _ => unreachable!(),
    };

    // --- generate waves of concurrent transfers with varied sizes
    let mut rng = Prng::new(63);
    let mut submit_wave = |wave: usize, n: usize| -> Vec<u64> {
        let now = catalog.now();
        let mut jobs = Vec::new();
        for i in 0..n {
            let bytes = rng.lognormal(50_000_000.0, 1.0) as u64; // ~50 MB median
            let name = format!("w{wave}f{i}");
            let pfn = format!("/src/{name}");
            fleet.get("SRC").unwrap().put(&pfn, bytes, now).unwrap();
            let dst = dsts[rng.range_usize(0, 3)];
            jobs.push(TransferJob {
                request_id: (wave * 1000 + i) as u64,
                src_rse: "SRC".into(),
                dst_rse: dst.to_string(),
                src_site: "SRC".into(),
                dst_site: dst.to_string(),
                src_pfn: pfn.clone(),
                dst_pfn: format!("/dst/{name}"),
                bytes,
                adler32: synthetic_adler32_for(&name, bytes),
                activity: "Production".into(),
                priority: 3,
            });
        }
        fts.submit(jobs, now)
    };
    let mut drive_until_done = |ids: &[u64]| {
        let mut guard = 0;
        loop {
            fts.advance(catalog.now());
            sim.advance(5_000); // 5 s resolution
            fts.advance(catalog.now());
            let done = fts
                .poll(ids)
                .iter()
                .filter(|t| matches!(t.state, TransferState::Done | TransferState::Failed))
                .count();
            guard += 1;
            if done == ids.len() || guard > 20_000 {
                break;
            }
        }
    };

    // training waves (varied concurrency → varied queue pressure)
    for wave in 0..20 {
        let n = 5 + (wave % 4) * 10;
        let ids = submit_wave(wave, n);
        drive_until_done(&ids);
        t3c.tick(catalog.now());
    }
    println!(
        "training: {} samples, {} MLP steps, last loss {:.3}",
        t3c.samples_seen, t3c.mlp.steps, t3c.mlp.last_loss
    );
    assert!(t3c.mlp.steps >= 5, "enough online training happened");

    // holdout waves: harvest without training
    let holdout_sub = broker.subscribe("transfer.fts", Some("transfer-done"));
    for wave in 20..26 {
        let n = 5 + (wave % 4) * 10;
        let ids = submit_wave(wave, n);
        drive_until_done(&ids);
    }
    let mut holdout: Vec<Sample> = Vec::new();
    loop {
        let msgs = broker.poll("transfer.fts", holdout_sub, 1000);
        if msgs.is_empty() {
            break;
        }
        for m in msgs {
            let (Some(bytes), Some(sub), Some(fin), Some(src), Some(dst)) = (
                m.payload.opt_u64("bytes"),
                m.payload.opt_i64("submitted_at"),
                m.payload.opt_i64("finished_at"),
                m.payload.opt_str("src_rse"),
                m.payload.opt_str("dst_rse"),
            ) else {
                continue;
            };
            let x = features(&ctx, bytes, Some(src), dst, "Production", fin);
            let y = (((fin - sub).max(1) as f32) / 1000.0 + 1.0).ln();
            holdout.push(Sample { x, y });
        }
    }
    println!("holdout: {} samples", holdout.len());
    assert!(holdout.len() > 30, "need a meaningful holdout");
    // sanity: durations actually vary
    let ys: Vec<f32> = holdout.iter().map(|s| s.y).collect();
    let mean_y = ys.iter().sum::<f32>() / ys.len() as f32;
    let var_y = ys.iter().map(|y| (y - mean_y).powi(2)).sum::<f32>() / ys.len() as f32;
    println!("holdout log-duration variance: {var_y:.3}");
    assert!(var_y > 0.05, "durations must vary for prediction to mean anything");

    let mae = |pred: &dyn Fn(&Sample) -> f32| -> f64 {
        holdout.iter().map(|s| (pred(s) - s.y).abs() as f64).sum::<f64>() / holdout.len() as f64
    };
    let mlp_mae = mae(&|s| t3c.mlp.predict(&s.x));
    let lin_mae = mae(&|s| t3c.linear.predict(&s.x));
    let naive_mae = mae(&|s| t3c.naive.predict(&s.x));

    let mut table = Table::new(
        "holdout MAE on log-duration (lower = better)",
        &["model", "MAE", "vs naive"],
    );
    for (name, v) in
        [("MLP (Pallas/PJRT)", mlp_mae), ("linear SGD", lin_mae), ("naive mean", naive_mae)]
    {
        table.row(&[name.into(), format!("{v:.3}"), format!("{:.2}x", v / naive_mae)]);
    }
    table.print();

    assert!(
        mlp_mae < naive_mae,
        "the learned model must beat the naive mean ({mlp_mae:.3} vs {naive_mae:.3})"
    );
    println!("sec63 bench OK");
}
