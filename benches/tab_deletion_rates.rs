//! §5.3 deletion table: "up to 100 million files commonly deleted per
//! month, amounting to 30 Petabytes and more, with an error rate of 10 to
//! 20 million per month". We measure reaper throughput in greedy mode,
//! the error-rate behaviour under flaky storage, and the non-greedy
//! (cache/LRU) ablation.

use rucio::benchkit::{bench_throughput, section};
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::daemons::reaper::Reaper;
use rucio::daemons::Daemon;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::storagesim::synthetic_adler32_for;

fn seed(ctx: &rucio::daemons::Ctx, rse: &str, n: usize, prefix: &str) {
    let cat = &ctx.catalog;
    let sys = ctx.fleet.get(rse).unwrap();
    for i in 0..n {
        let name = format!("{prefix}{i:06}");
        let adler = synthetic_adler32_for(&name, 1_000);
        cat.add_file("data18", &name, "prod", 1_000, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = cat.add_replica(rse, &key, ReplicaState::Available, None).unwrap();
        // retry writes (flaky grids inject write failures)
        for _ in 0..50 {
            if sys.put(&rep.pfn, 1_000, 0).is_ok() {
                break;
            }
        }
        // unprotected → tombstoned at birth → reaper-eligible
    }
}

fn main() {
    section("Tab §5.3: deletion throughput (reaper)");
    let ctx = build_grid(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.0, ..Default::default() },
        Clock::sim_at(0),
        Config::new(),
    );
    let n = 50_000usize;
    seed(&ctx, "FR-T1-DISK", n, "del");
    let mut reaper = Reaper::new(ctx.clone(), "r1");
    reaper.bulk = 10_000;
    // past the 24h birth-grace window (cache semantics, §4.3)
    if let Clock::Sim(s) = &ctx.catalog.clock {
        s.advance(25 * 3_600_000);
    }
    bench_throughput("greedy deletion", n, || {
        let mut guard = 0;
        while ctx.catalog.deletable_replicas("FR-T1-DISK", ctx.catalog.now(), 1).len() > 0 {
            reaper.tick(ctx.catalog.now());
            guard += 1;
            assert!(guard < 100, "reaper stuck");
        }
    });
    let deleted = ctx.catalog.metrics.counter("reaper.deleted");
    println!("deleted={deleted} errors={}", ctx.catalog.metrics.counter("reaper.errors"));
    assert_eq!(deleted as usize, n);

    // error-rate shape under flaky storage (paper: 10-20% deletion errors)
    section("deletion under flaky storage (error-rate shape)");
    let flaky = build_grid(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.075, ..Default::default() }, // delete_fail = 15%
        Clock::sim_at(0),
        Config::new(),
    );
    seed(&flaky, "DE-T1-DISK", 5_000, "flk");
    let mut reaper2 = Reaper::new(flaky.clone(), "r2");
    reaper2.bulk = 10_000;
    if let Clock::Sim(s) = &flaky.catalog.clock {
        s.advance(25 * 3_600_000);
    }
    reaper2.tick(flaky.catalog.now());
    let del = flaky.catalog.metrics.counter("reaper.deleted");
    let err = flaky.catalog.metrics.counter("reaper.errors");
    let rate = err as f64 / (del + err).max(1) as f64;
    println!("first pass: deleted={del} errors={err} ({:.0}% error rate; paper: 10-20%)", rate * 100.0);
    assert!((0.05..0.30).contains(&rate), "error rate in the paper's band");
    // retries eventually clear the backlog
    let mut guard = 0;
    while flaky.catalog.deletable_replicas("DE-T1-DISK", flaky.catalog.now(), 1).len() > 0 {
        reaper2.tick(flaky.catalog.now());
        guard += 1;
        assert!(guard < 200, "retries must converge");
    }
    println!("backlog cleared after {guard} retry sweeps");
    println!("tab_deletion_rates bench OK");
}
