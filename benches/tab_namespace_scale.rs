//! §5.3 namespace-scale table: the paper reports 25M containers / 13M
//! datasets / 960M files / 1.2B replicas and ~3000 DB transactions per
//! second. We measure catalog operation throughput (registration, lookup,
//! rule-covered listing) at a scaled-down population and check the
//! ops/sec analog clears the paper's transaction rate by a wide margin.

use rucio::benchkit::{bench_throughput, section};
use rucio::core::rse::Rse;
use rucio::core::types::{DidKey, ReplicaState};
use rucio::core::Catalog;
use rucio::storagesim::synthetic_adler32_for;

fn main() {
    section("Tab §5.3: namespace scale + catalog op throughput");
    let cat = Catalog::new_for_tests();
    cat.add_scope("data18", "root").unwrap();
    for i in 0..20 {
        cat.add_rse(Rse::new(&format!("RSE-{i:02}", ), cat.now())).unwrap();
    }

    let n_files = 200_000usize;
    let r1 = bench_throughput("register file DIDs", n_files, || {
        for i in 0..n_files {
            let name = format!("f{i:07}");
            cat.add_file("data18", &name, "root", 1000, &synthetic_adler32_for(&name, 1000), None)
                .unwrap();
        }
    });
    let r2 = bench_throughput("register replicas", n_files, || {
        for i in 0..n_files {
            let key = DidKey::new("data18", &format!("f{i:07}"));
            cat.add_replica(&format!("RSE-{:02}", i % 20), &key, ReplicaState::Available, None)
                .unwrap();
        }
    });
    let r3 = bench_throughput("DID point lookups", n_files, || {
        for i in 0..n_files {
            let key = DidKey::new("data18", &format!("f{i:07}"));
            std::hint::black_box(cat.get_did(&key).unwrap());
        }
    });
    let r4 = bench_throughput("replica lookups by DID", n_files, || {
        for i in 0..n_files {
            let key = DidKey::new("data18", &format!("f{i:07}"));
            std::hint::black_box(cat.list_replicas(&key));
        }
    });

    let ns = cat.namespace_stats();
    println!(
        "\npopulation: files={} replicas={} (paper: 960M / 1.2B at full scale)",
        ns.files, ns.replicas
    );
    // Paper: ~3000 transactions/s on the Oracle backend.
    for (name, r) in [("insert", &r1), ("replica", &r2), ("lookup", &r3), ("list", &r4)] {
        println!("{name}: {:.0} ops/s", r.ops_per_sec());
        assert!(
            r.ops_per_sec() > 3000.0,
            "{name} must clear the paper's 3000 tx/s analog"
        );
    }
    println!("tab_namespace_scale bench OK");
}
