//! §5.3 server-performance table: the paper reports a 250 Hz average
//! interaction rate (spikes 400–500 Hz) with <50 ms average response on
//! 15 four-core nodes. We drive the REST server over loopback with
//! concurrent clients and report rate + latency percentiles; the p50
//! target is the paper's 50 ms bound, the rate target is 500 Hz on one
//! node (the paper's fleet is ~10x over-provisioned, §5.3).

use std::sync::Arc;

use rucio::benchkit::{fmt_ns, section};
use rucio::client::RucioClient;
use rucio::core::types::{AccountType, AuthType};
use rucio::core::Catalog;
use rucio::mq::Broker;

fn main() {
    section("Tab §5.3: REST server interaction rate + latency");
    let catalog = Arc::new(Catalog::new_for_tests());
    catalog.add_account("alice", AccountType::User, "a@x").unwrap();
    catalog
        .add_identity("alice", AuthType::UserPass, "alice", Some("pw"))
        .unwrap();
    catalog.add_scope("data18", "root").unwrap();
    for i in 0..500 {
        catalog
            .add_file("data18", &format!("f{i:05}"), "root", 1000, "aabbccdd", None)
            .unwrap();
    }
    let server =
        rucio::server::serve(catalog.clone(), Broker::new(), "127.0.0.1:0", 8).unwrap();
    let url = server.url();

    let n_clients = 8;
    let reqs_per_client = 500;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let url = url.clone();
        handles.push(std::thread::spawn(move || {
            let client = RucioClient::connect(&url, "alice", "alice", "pw").unwrap();
            let mut lat_ns: Vec<f64> = Vec::with_capacity(reqs_per_client);
            for i in 0..reqs_per_client {
                let t = std::time::Instant::now();
                match (c + i) % 3 {
                    0 => {
                        client.ping().unwrap();
                    }
                    1 => {
                        client.get_did("data18", &format!("f{:05}", i % 500)).unwrap();
                    }
                    _ => {
                        client.list_replicas("data18", &format!("f{:05}", i % 500)).unwrap();
                    }
                }
                lat_ns.push(t.elapsed().as_nanos() as f64);
            }
            lat_ns
        }));
    }
    let mut all: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let elapsed = t0.elapsed().as_secs_f64();
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = all.len();
    let rate = total as f64 / elapsed;
    let pct = |p: f64| all[((p * (total - 1) as f64) as usize).min(total - 1)];

    println!("\nrequests: {total} over {elapsed:.2}s from {n_clients} concurrent clients");
    println!("interaction rate: {rate:.0} Hz (paper: 250 Hz avg, 400-500 Hz spikes)");
    println!(
        "latency: p50 {}  p95 {}  p99 {}",
        fmt_ns(pct(0.5)),
        fmt_ns(pct(0.95)),
        fmt_ns(pct(0.99))
    );
    assert!(rate > 500.0, "must sustain a paper-spike-level 500 Hz");
    assert!(pct(0.5) < 50e6, "p50 under the paper's 50 ms bound");
    println!("tab_server_rate bench OK");
}
