//! §5.3 tape-recall table: "per month, ATLAS recalled about 1 Petabyte
//! with fewer than 1 million files and with less than 10 percent recall
//! issues that required recall retries ... these can be staged from tape
//! efficiently". We measure the stage→submit→complete path and the
//! retry fraction.

use rucio::benchkit::{section, Table};
use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{DidKey, ReplicaState, RuleState};
use rucio::daemons::conveyor::{Poller, Submitter};
use rucio::daemons::Daemon;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::storagesim::synthetic_adler32_for;

fn main() {
    section("Tab §5.3: tape recall (staging latency + retries)");
    let ctx = build_grid(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.01, ..Default::default() },
        Clock::sim_at(0),
        Config::new(),
    );
    let cat = ctx.catalog.clone();
    let sim = match &cat.clock {
        Clock::Sim(s) => s.clone(),
        _ => unreachable!(),
    };

    // archive n files on CERN tape (cold), then request disk copies
    let n = 200usize;
    for i in 0..n {
        let name = format!("cold{i:05}");
        let bytes = 1_000_000u64;
        let adler = synthetic_adler32_for(&name, bytes);
        cat.add_file("data18", &name, "prod", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", &name);
        let rep = cat.add_replica("CERN-TAPE", &key, ReplicaState::Available, None).unwrap();
        ctx.fleet.get("CERN-TAPE").unwrap().put(&rep.pfn, bytes, 0).unwrap();
        cat.add_rule(RuleSpec::new("prod", key, "FR-T1-DISK", 1).with_activity("Staging"))
            .unwrap();
    }

    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    let t_start = cat.now();
    let mut first_done: Option<i64> = None;
    let mut rounds = 0;
    loop {
        let now = cat.now();
        submitter.tick(now);
        ctx.fleet.tick(now); // tape robot staging progress
        for f in &ctx.fts {
            f.advance(now);
        }
        sim.advance(MINUTE_MS);
        ctx.fleet.tick(cat.now());
        for f in &ctx.fts {
            f.advance(cat.now());
        }
        poller.tick(cat.now());
        let ok = cat.rules_by_state.count(&RuleState::Ok);
        if ok > 0 && first_done.is_none() {
            first_done = Some(cat.now() - t_start);
        }
        rounds += 1;
        if ok >= (n as f64 * 0.95) as usize || rounds > 3000 {
            break;
        }
        if rounds % 20 == 0 {
            for req in cat.requests.scan(|r| r.state == rucio::core::types::RequestState::Retry) {
                cat.requests.update(&req.id, cat.now(), |r| r.retry_after = Some(cat.now()));
            }
        }
    }

    let ok = cat.rules_by_state.count(&RuleState::Ok);
    let retried = cat.metrics.counter("transfers.retried");
    let done = cat.metrics.counter("transfers.done");
    let recall_min = (cat.now() - t_start) / 60_000;
    let mut table = Table::new("tape recall results", &["metric", "value", "paper analog"]);
    table.row(&["files recalled".into(), ok.to_string(), "<1M files/month".into()]);
    table.row(&[
        "first-file latency".into(),
        format!("{} min", first_done.unwrap_or(-1) / 60_000),
        "robot mount+seek".into(),
    ]);
    table.row(&["campaign duration".into(), format!("{recall_min} min"), "efficient staging".into()]);
    table.row(&[
        "retry fraction".into(),
        format!("{:.1}%", 100.0 * retried as f64 / done.max(1) as f64),
        "<10%".into(),
    ]);
    table.print();

    assert!(ok as f64 >= n as f64 * 0.95, "95% of recalls complete: {ok}/{n}");
    assert!(
        first_done.unwrap_or(i64::MAX) >= 4 * 60_000,
        "tape latency includes the robot mount (>=4 min)"
    );
    assert!(
        (retried as f64) < done as f64 * 0.25,
        "retry fraction in a sane band"
    );
    println!("tab_tape_recall bench OK");
}
