//! §5.3 transfer-rate table: "on average 50 to 70 million files are
//! transferred between data centres per month, with a transfer failure
//! rate of roughly 10 million per month ... automatically recovered".
//! We measure conveyor pipeline throughput (rule → request → submit →
//! complete → rule OK) and the automatic failure-recovery fraction.

use rucio::benchkit::{bench_throughput, section};
use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::rules_api::RuleSpec;
use rucio::core::types::{RequestState, RuleState};
use rucio::daemons::conveyor::{Poller, Submitter};
use rucio::daemons::Daemon;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::storagesim::synthetic_adler32_for;

fn main() {
    section("Tab §5.3: conveyor transfer throughput + failure recovery");
    let ctx = build_grid(
        &GridSpec { t2_per_region: 1, storage_flakiness: 0.05, ..Default::default() },
        Clock::sim_at(0),
        Config::new(),
    );
    let cat = ctx.catalog.clone();

    // seed N files at CERN and rule them to FR T1
    let n = 2_000usize;
    for i in 0..n {
        let name = format!("x{i:06}");
        let adler = synthetic_adler32_for(&name, 100_000);
        cat.add_file("data18", &name, "prod", 100_000, &adler, None).unwrap();
        let key = rucio::core::types::DidKey::new("data18", &name);
        let rep = cat
            .add_replica("CERN-PROD", &key, rucio::core::types::ReplicaState::Available, None)
            .unwrap();
        // retry against the injected 5% write-failure rate
        let sys = ctx.fleet.get("CERN-PROD").unwrap();
        for _ in 0..50 {
            if sys.put(&rep.pfn, 100_000, 0).is_ok() {
                break;
            }
        }
        cat.add_rule(RuleSpec::new("prod", key, "FR-T1-DISK", 1).with_activity("Production"))
            .unwrap();
    }

    let mut submitter = Submitter::new(ctx.clone(), "s1");
    let mut poller = Poller::new(ctx.clone(), "p1");
    let sim = match &cat.clock {
        Clock::Sim(s) => s.clone(),
        _ => unreachable!(),
    };
    bench_throughput("rule->transfer->OK pipeline", n, || {
        let mut rounds = 0;
        loop {
            let now = cat.now();
            submitter.tick(now);
            for f in &ctx.fts {
                f.advance(now);
            }
            sim.advance(MINUTE_MS);
            for f in &ctx.fts {
                f.advance(cat.now());
            }
            poller.tick(cat.now());
            let pending = cat.requests_by_state.count(&RequestState::Queued)
                + cat.requests_by_state.count(&RequestState::Submitted)
                + cat.requests_by_state.count(&RequestState::Retry);
            rounds += 1;
            if pending == 0 || rounds > 500 {
                break;
            }
            if rounds % 10 == 0 {
                // promote retries quickly for the bench
                for req in cat.requests.scan(|r| r.state == RequestState::Retry) {
                    cat.requests.update(&req.id, cat.now(), |r| {
                        r.retry_after = Some(cat.now());
                    });
                }
            }
        }
    });

    let done = cat.metrics.counter("transfers.done");
    let failed = cat.metrics.counter("transfers.failed");
    let retried = cat.metrics.counter("transfers.retried");
    let ok_rules = cat.rules_by_state.count(&RuleState::Ok);
    println!("\ntransfers: done={done} failure-events={retried}+{failed} (retry+terminal)");
    println!(
        "rules OK: {ok_rules}/{n} ({:.1}%)  — failures auto-recovered by retry/repair",
        100.0 * ok_rules as f64 / n as f64
    );
    // Paper shape: ~10-20% failure events, almost all recovered.
    assert!(ok_rules as f64 > n as f64 * 0.9, "90%+ rules converge");
    assert!(retried > 0, "retry path exercised");
    println!("tab_transfer_rates bench OK");
}
