//! Campaign season: three planned-load operations run back to back on a
//! live grid — an end-of-year **reprocessing** (bulk rules over every
//! RAW dataset), a **mass deletion** (lifetime-expiry sweep of the AOD
//! derivations), and a **tape carousel** (staged recall waves of the
//! RAW archive through the tape systems) — with the background workload
//! still running, the throttler pacing the stage-in flood, and the
//! system-invariant checker on a 30-virtual-minute cadence throughout.
//!
//! Prints one summary row per campaign (time-to-complete, deletion
//! rate, peak backlog, recall-wave depth, per-link peak vs cap) and the
//! invariant verdict; exits non-zero if a campaign failed to converge,
//! any FTS link ever exceeded its cap, or an invariant was violated.
//!
//! Run: `cargo run --release --example campaign_season`

use rucio::benchkit::Table;
use rucio::common::clock::MINUTE_MS;
use rucio::common::config::Config;
use rucio::sim::campaign::{run_season, CampaignSpec};
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::workload::WorkloadSpec;

fn main() {
    rucio::common::logx::init(0);
    let seed = 77;
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    // deletions become visible within the season, not a day later
    cfg.set("reaper", "tombstone_grace", "2h");
    // admission control on: the carousel's stage-in flood is paced by
    // the per-activity shares instead of slamming the links
    cfg.set("throttler", "enabled", "true");
    cfg.set("throttler", "share.Staging", "0.3");
    cfg.set("throttler", "share.Reprocessing", "0.3");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 5,
            files_per_dataset: 4,
            median_file_bytes: 600_000_000,
            derivations_per_day: 4,
            analysis_accesses_per_day: 40,
            seed: seed ^ 0xCA4,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(30 * MINUTE_MS);

    // Two quiet days first: the workload lands RAW datasets, the standing
    // subscription archives them to tape + Tier-1 disk, derivations make
    // the AODs the deletion campaign will sweep.
    driver.run_days(2, 10 * MINUTE_MS);

    let season = [
        CampaignSpec::reprocessing("reprocess-raw", "data18", "datatype=RAW", "tier=2")
            .with_budget_hours(72),
        CampaignSpec::mass_deletion("sweep-aod", "mc20", "datatype=AOD").with_budget_hours(48),
        CampaignSpec::tape_carousel("carousel-raw", "data18", "datatype=RAW", "region=DE&tier=2", 2)
            .with_budget_hours(96),
    ];
    let reports = run_season(&mut driver, &season).expect("campaign season runs");
    driver.check_invariants_now();

    let mut table = Table::new(
        "campaign season",
        &[
            "campaign",
            "kind",
            "datasets",
            "rules",
            "locks",
            "t-complete (h)",
            "deleted",
            "del/h",
            "peak backlog",
            "wave depth",
            "link peak/cap",
        ],
    );
    for r in &reports {
        table.row(&r.summary_row());
    }
    table.print();

    let cat = &driver.ctx.catalog;
    println!(
        "\nseason totals: {} rules injected | {} rules expired | {} files deleted | \
         {} recall waves | throttler released (Staging): {}",
        reports.iter().map(|r| r.rules_created).sum::<usize>(),
        reports.iter().map(|r| r.rules_expired).sum::<usize>(),
        reports.iter().map(|r| r.deleted_files).sum::<u64>(),
        reports.iter().map(|r| r.waves).sum::<usize>(),
        cat.metrics.counter("throttler.released.Staging"),
    );
    println!(
        "invariant checks: {} samples, {} violations",
        driver.samples.len(),
        driver.violations.len()
    );

    let mut failed = false;
    for r in &reports {
        if !r.completed {
            eprintln!("campaign {} did not converge within its budget", r.name);
            failed = true;
        }
        if r.link_cap_exceeded {
            eprintln!("campaign {} drove a link above the FTS cap", r.name);
            failed = true;
        }
    }
    if !driver.violations.is_empty() {
        for (t, v) in driver.violations.iter().take(10) {
            eprintln!("violation at t={t}: {v}");
        }
        failed = true;
    }
    if failed {
        eprintln!("campaign season FAILED");
        std::process::exit(1);
    }
    println!("campaign season complete: all three campaigns converged, links within caps.");
}
