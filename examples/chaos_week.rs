//! Chaos week: seven simulated days of grid operations under a rolling
//! sequence of incidents — a Tier-1 site outage, an inter-region network
//! partition, a corruption burst, an FTS server outage, a daemon crash,
//! a drain, a tape-recall storm, and (day 7) a full catalog process
//! crash recovered live from the write-ahead log + snapshots — with the
//! system-invariant checker running every 30 virtual minutes throughout.
//!
//! Durability is on for the whole week: every catalog mutation is
//! WAL-logged and the checkpointer daemon snapshots all tables every
//! few virtual hours, so the `ProcessCrash` event drops the in-memory
//! catalog and cold-boots it from disk mid-run.
//!
//! Prints the per-day stats, the per-incident recovery report, the
//! durability summary, and the invariant verdict; exits non-zero if any
//! invariant was ever violated (or the crash failed to recover).
//!
//! Run: `cargo run --release --example chaos_week`

use rucio::benchkit::Table;
use rucio::common::clock::{HOUR_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::types::RuleState;
use rucio::sim::driver::standard_driver;
use rucio::sim::grid::GridSpec;
use rucio::sim::scenario::{Event, Scenario};
use rucio::sim::workload::{MultiVoSpec, WorkloadSpec};

fn main() {
    rucio::common::logx::init(0);
    let seed = 2026;
    let wal_dir = std::env::temp_dir().join(format!("rucio-chaos-week-{}", std::process::id()));
    let mut cfg = Config::new();
    cfg.set("common", "seed", seed.to_string());
    cfg.set("reaper", "tombstone_grace", "2h");
    cfg.set("heartbeat", "ttl", "45m");
    // durability: WAL every mutation, checkpoint every 4 virtual hours
    cfg.set("db", "wal_dir", wal_dir.to_string_lossy().to_string());
    cfg.set("db", "checkpoint_interval", "4h");
    // three tenants share the instance; the throttler splits link slots
    // across them 3:2:1 before the per-activity shares apply
    cfg.set("throttler", "vo_share.atlas", "3");
    cfg.set("throttler", "vo_share.cms", "2");
    cfg.set("throttler", "vo_share.belle", "1");
    let mut driver = standard_driver(
        &GridSpec { t2_per_region: 1, seed, ..Default::default() },
        WorkloadSpec {
            raw_datasets_per_day: 6,
            files_per_dataset: 4,
            median_file_bytes: 800_000_000,
            derivations_per_day: 4,
            analysis_accesses_per_day: 60,
            multi_vo: Some(MultiVoSpec {
                vos: vec!["atlas".into(), "cms".into(), "belle".into()],
                accounts_per_vo: 400,
                rules_per_day: 48,
                logins_per_day: 96,
                zipf_theta: 1.2,
            }),
            seed: seed ^ 0xA0D,
            ..Default::default()
        },
        cfg,
    );
    driver.enable_invariant_checks(30 * MINUTE_MS);

    // The week of incidents (offsets in virtual hours from t0).
    let week = Scenario::new("chaos week")
        // day 1: a Tier-1 disk goes dark for 14 hours
        .at_hours(26, Event::RseDown { rse: "DE-T1-DISK".into() })
        .at_hours(40, Event::RseUp { rse: "DE-T1-DISK".into() })
        // day 2: FR↔IT partition for 12 hours
        .at_hours(50, Event::NetworkPartition { region_a: "FR".into(), region_b: "IT".into() })
        .at_hours(62, Event::NetworkRestore { region_a: "FR".into(), region_b: "IT".into() })
        // day 3: bit rot chews through files at a UK Tier-2
        .at_hours(74, Event::CorruptionBurst { rse: "UK-T2-1".into(), files: 25 })
        // day 4: one FTS server down for 8 hours (the conveyor reroutes)
        .at_hours(98, Event::FtsDown { index: 0 })
        .at_hours(106, Event::FtsUp { index: 0 })
        // day 5: the conveyor submitter crashes; heartbeat failover, then
        // an operator restarts it 3 hours later
        .at_hours(122, Event::DaemonCrash { daemon: "conveyor-submitter".into(), which: 0 })
        .at_hours(125, Event::DaemonRestart { daemon: "conveyor-submitter".into(), which: 0 })
        // day 6: drain a Canadian Tier-2, and a recall storm hits the tapes
        .at_hours(146, Event::RseDrain { rse: "CA-T2-1".into() })
        .at_hours(148, Event::TapeRecallStorm { datasets: 10 })
        // day 7: the catalog process dies; the driver cold-boots it from
        // WAL + snapshots and the fleet resumes against the recovered state
        .at_hours(158, Event::ProcessCrash);
    let t0 = driver.ctx.catalog.now();
    driver.schedule_scenario(&week);
    driver.run_days(7, 10 * MINUTE_MS);

    // ---- per-day stats
    let mut days = Table::new(
        "chaos week — per-day stats",
        &["day", "files", "replicas", "done", "failed", "deleted", "TB moved"],
    );
    for d in &driver.days {
        days.row(&[
            d.day.to_string(),
            d.files.to_string(),
            d.replicas.to_string(),
            d.transfers_done.to_string(),
            d.transfers_failed.to_string(),
            d.deletions.to_string(),
            format!("{:.2}", d.bytes_transferred as f64 / 1e12),
        ]);
    }
    days.print();

    // ---- per-incident recovery
    let mut rec = Table::new(
        "recovery report per incident",
        &["incident", "peak backlog", "peak stuck", "reconverged after (h)"],
    );
    let incidents: [(&str, i64, i64); 3] = [
        ("T1 outage (26h–40h)", 26, 40),
        ("FR/IT partition (50h–62h)", 50, 62),
        ("FTS outage (98h–106h)", 98, 106),
    ];
    for (name, start_h, end_h) in incidents {
        let r = driver.recovery_report(t0 + start_h * HOUR_MS, t0 + end_h * HOUR_MS);
        rec.row(&[
            name.to_string(),
            r.peak_backlog.to_string(),
            r.peak_stuck.to_string(),
            r.time_to_reconverge_ms
                .map(|ms| format!("{:.1}", ms as f64 / HOUR_MS as f64))
                .unwrap_or_else(|| "never".into()),
        ]);
    }
    rec.print();

    // ---- durability summary
    let cat = &driver.ctx.catalog;
    let wal_bytes: u64 = cat
        .registry
        .wal_stats()
        .values()
        .map(|s| s.bytes)
        .sum();
    println!(
        "\ndurability: {} process crash(es) recovered | {} rows from snapshots, \
         {} WAL ops replayed, {} ms recovery | {} checkpoints | {:.1} MB live WAL",
        driver.process_crashes,
        cat.metrics.gauge("db.recovered_rows"),
        cat.metrics.gauge("db.recovery_replayed_ops"),
        cat.metrics.gauge("db.recovery_ms"),
        cat.metrics.counter("checkpointer.runs"),
        wal_bytes as f64 / 1e6,
    );
    if driver.process_crashes != 1 {
        eprintln!("chaos week FAILED: ProcessCrash did not recover");
        std::process::exit(1);
    }

    // ---- verdict
    let total = cat.rules.len();
    let ok = cat.rules_by_state.count(&RuleState::Ok);
    println!(
        "\nrules: {ok}/{total} OK | lost files: {} | bad declared: {} | repairs: {}",
        cat.metrics.counter("necromancer.lost"),
        cat.metrics.counter("replicas.declared_bad"),
        cat.metrics.counter("rules.repaired"),
    );
    let roll = cat.vo_usage();
    let tenants: Vec<String> = roll
        .iter()
        .map(|(vo, (b, f))| format!("{vo}: {:.1} GB / {f} files", *b as f64 / 1e9))
        .collect();
    println!("per-VO usage: {}", tenants.join(" | "));
    println!(
        "invariant checks: {} samples, {} violations",
        driver.samples.len(),
        driver.violations.len()
    );
    std::fs::remove_dir_all(&wal_dir).ok();
    if driver.violations.is_empty() {
        println!("chaos week survived: all system invariants held throughout.");
    } else {
        for (t, v) in driver.violations.iter().take(10) {
            eprintln!("violation at t={t}: {v}");
        }
        eprintln!("chaos week FAILED: {} invariant violations", driver.violations.len());
        std::process::exit(1);
    }
}
