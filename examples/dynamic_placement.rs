//! §6 advanced features end-to-end: C3PO dynamic placement scoring
//! through the AOT-compiled Pallas kernel (PJRT), T³C transfer-time
//! prediction with online training through the exported jax.grad train
//! step, and a BB8 decommission — all with Python strictly off the
//! request path.
//!
//! Run: `make artifacts && cargo run --release --example dynamic_placement`

use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::daemons::Daemon;
use rucio::placement::{C3po, PjrtScorer, RefScorer, Scorer};
use rucio::rebalance::Bb8;
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::sim::workload::{Workload, WorkloadSpec};
use rucio::t3c::T3c;

fn main() {
    rucio::common::logx::init(0);
    let ctx = build_grid(&GridSpec::default(), Clock::sim_at(1_514_764_800_000), Config::new());
    let cat = ctx.catalog.clone();

    // scorer: PJRT if artifacts are built, else the Rust reference
    let scorer: Box<dyn Scorer> = match PjrtScorer::load_default() {
        Ok(s) => {
            println!("scorer: PJRT (Pallas placement_score artifact)");
            Box::new(s)
        }
        Err(e) => {
            println!("scorer: rust reference (artifacts unavailable: {e})");
            Box::new(RefScorer)
        }
    };

    // warm the grid with a week of workload + T³C learning
    let mut daemons = Driver::standard_daemons(&ctx);
    daemons.push(Box::new(T3c::new(ctx.clone())));
    let mut driver = Driver::new(
        ctx.clone(),
        Workload::new(WorkloadSpec {
            analysis_accesses_per_day: 300,
            ..Default::default()
        }),
        daemons,
    );
    let mut c3po = C3po::new(ctx.clone(), scorer);
    println!("running 7 simulated days of workload...");
    for _ in 0..7 {
        driver.run_days(1, 10 * MINUTE_MS);
        c3po.tick(cat.now());
    }

    println!("\nC3PO decisions ({}):", c3po.decisions.len());
    for d in c3po.decisions.iter().take(10) {
        println!(
            "  {} -> {} (p={:.2}, {} candidates)",
            d.dataset, d.chosen_rse, d.prob, d.candidates
        );
    }
    assert!(!c3po.decisions.is_empty(), "popular datasets triggered placement");

    // T³C: trained online from completed transfers; show an ETA
    let mut t3c = T3c::new(ctx.clone());
    // (the driver's T3c instance trained; this one shares the catalog and
    // re-harvests nothing — use it for feature extraction demo only)
    let queued = cat.requests.scan_limit(1, |r| {
        r.state == rucio::core::types::RequestState::Queued
            || r.state == rucio::core::types::RequestState::Submitted
    });
    if let Some(req) = queued.first() {
        let eta = t3c.predict_request(req, cat.now());
        println!(
            "\nT³C ETA for request {} ({} -> {}): {:.1}s",
            req.id,
            req.src_rse.as_deref().unwrap_or("?"),
            req.dst_rse,
            eta
        );
    }

    // BB8 decommission: drain a T2 and verify the linked-rule protocol
    let victim = "IT-T2-1";
    let mut bb8 = Bb8::new(ctx.clone());
    let moved = bb8.decommission(victim, cat.now()).unwrap();
    println!("\nBB8 decommission of {victim}: {moved} rules scheduled away");
    // let the conveyor+FTS drain it
    for _ in 0..3 {
        driver.run_days(1, 10 * MINUTE_MS);
        bb8.finalize_moves();
    }
    let mut locks_left = 0;
    cat.locks.for_each(|l| {
        if l.rse == victim {
            locks_left += 1;
        }
    });
    println!(
        "after 3 days: {} locks left on {victim}, {} moves completed",
        locks_left, bb8.completed_moves
    );

    println!("\ndynamic_placement OK");
}
