//! END-TO-END VALIDATION DRIVER (charter deliverable): run the complete
//! three-layer system — Rust coordinator (catalog + daemon fleet + REST
//! surface), simulated grid substrate (storage/network/FTS), and the
//! AOT-compiled JAX/Pallas decision models — on a realistic month-scale
//! ATLAS-like workload, and report the paper's headline metrics
//! (§5.3 scale + rates, Fig 8 efficiency structure, Fig 10/11 volumes,
//! §6.1 placement effectiveness). Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use rucio::common::clock::{Clock, DAY_MS, MINUTE_MS};
use rucio::common::config::Config;
use rucio::common::units::fmt_bytes;
use rucio::daemons::Daemon;
use rucio::placement::{C3po, PjrtScorer, RefScorer, Scorer};
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec, REGIONS};
use rucio::sim::workload::{Workload, WorkloadSpec};
use rucio::t3c::T3c;

fn main() {
    rucio::common::logx::init(0);
    let days: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let t0 = std::time::Instant::now();

    let ctx = build_grid(
        &GridSpec::default(),
        Clock::sim_at(1_514_764_800_000), // 2018-01-01
        Config::new(),
    );
    let cat = ctx.catalog.clone();

    let scorer: Box<dyn Scorer> = match PjrtScorer::load_default() {
        Ok(s) => Box::new(s),
        Err(_) => Box::new(RefScorer),
    };
    let mut c3po = C3po::new(ctx.clone(), scorer);
    let mut t3c = T3c::new(ctx.clone());

    let workload = Workload::new(WorkloadSpec {
        burst: Some((days * 3 / 4, days, 2.5)), // conference crunch at the end
        ..Default::default()
    });
    let mut driver = Driver::new(ctx.clone(), workload, Driver::standard_daemons(&ctx));

    println!("=== end-to-end: {days} simulated days on the Fig-8 grid ===");
    for day in 0..days {
        driver.run_days(1, 10 * MINUTE_MS);
        c3po.tick(cat.now());
        t3c.tick(cat.now());
        if (day + 1) % 10 == 0 {
            let d = driver.days.last().unwrap();
            println!(
                "  day {:>3}: managed {}, transferred {} ({} ok / {} failed)",
                day + 1,
                fmt_bytes(d.bytes_managed),
                fmt_bytes(d.bytes_transferred),
                d.transfers_done,
                d.transfers_failed
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---------------- §5.3 scale ----------------
    let ns = cat.namespace_stats();
    println!("\n--- namespace scale (paper §5.3 analog) ---");
    println!("containers={} datasets={} files={}", ns.containers, ns.datasets, ns.files);
    println!("replicas={} rses={} rules={}", ns.replicas, ns.rses, ns.rules);
    println!("volume managed: {}", fmt_bytes(ns.bytes_managed));

    // ---------------- Fig 10: volume growth ----------------
    println!("\n--- Fig 10: managed volume (weekly samples) ---");
    for d in driver.days.iter().step_by(7) {
        println!("  day {:>3}: {}", d.day, fmt_bytes(d.bytes_managed));
    }
    let first = driver.days.first().unwrap().bytes_managed;
    let last = driver.days.last().unwrap().bytes_managed;
    println!("  growth: {} -> {} (monotone-ish linear)", fmt_bytes(first), fmt_bytes(last));

    // ---------------- Fig 11: transfer volume ----------------
    let total_x: u64 = driver.days.iter().map(|d| d.bytes_transferred).sum();
    let done: u64 = driver.days.iter().map(|d| d.transfers_done).sum();
    let failed: u64 = driver.days.iter().map(|d| d.transfers_failed).sum();
    println!("\n--- Fig 11 / §5.3 rates ---");
    println!(
        "transferred {} in {} files; {} failures ({:.0}% of outcomes, auto-retried)",
        fmt_bytes(total_x),
        done,
        failed,
        100.0 * failed as f64 / (done + failed).max(1) as f64
    );
    let deletions: u64 = driver.days.iter().map(|d| d.deletions).sum();
    let deleted_bytes: u64 = driver.days.iter().map(|d| d.deleted_bytes).sum();
    println!("deleted {deletions} files / {}", fmt_bytes(deleted_bytes));
    let recalls: u64 = driver.days.iter().map(|d| d.tape_recalls).sum();
    let recall_bytes: u64 = driver.days.iter().map(|d| d.tape_recall_bytes).sum();
    println!("tape recalls: {recalls} files / {}", fmt_bytes(recall_bytes));

    // ---------------- Fig 8: efficiency matrix ----------------
    println!("\n--- Fig 8: region-pair transfer efficiency (top source rows) ---");
    let matrix = driver.efficiency_matrix();
    print!("{:>5}", "");
    for dst in REGIONS.iter().take(8) {
        print!("{dst:>6}");
    }
    println!();
    for src in REGIONS.iter().take(8) {
        print!("{src:>5}");
        for dst in REGIONS.iter().take(8) {
            match matrix.get(&(src.to_string(), dst.to_string())) {
                Some(eff) => print!("{:>5.0}%", eff * 100.0),
                None => print!("{:>6}", "-"),
            }
        }
        println!();
    }

    // ---------------- §6.1: dynamic placement ----------------
    println!("\n--- §6.1 dynamic placement ---");
    println!("C3PO placements: {}", c3po.decisions.len());
    let now = cat.now();
    let reused = c3po
        .decisions
        .iter()
        .filter(|d| {
            cat.popularity
                .get(&d.dataset)
                .map(|p| p.last_access > d.at && now - d.at <= 14 * DAY_MS + DAY_MS)
                .unwrap_or(false)
        })
        .count();
    if !c3po.decisions.is_empty() {
        println!(
            "re-accessed within two weeks: {}/{} = {:.0}% (paper: ~60%)",
            reused,
            c3po.decisions.len(),
            100.0 * reused as f64 / c3po.decisions.len() as f64
        );
    }

    // ---------------- §6.3: T³C ----------------
    println!("\n--- §6.3 T³C ---");
    println!(
        "samples={} mlp_steps={} last_loss={:.3}",
        t3c.samples_seen, t3c.mlp.steps, t3c.mlp.last_loss
    );

    println!("\nsimulated {days} days in {wall:.1}s wall-clock");
    println!("end_to_end OK");
}
