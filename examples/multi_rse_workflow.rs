//! Multi-RSE operations workflow (paper §2.5/§4.4): subscriptions route
//! fresh detector data to tape + T1 disk automatically; a corrupted
//! replica is detected and recovered by the necromancer from a surviving
//! copy; the auditor spots dark & lost files via the Fig-4 three-list
//! comparison. Runs entirely under virtual time.
//!
//! Run: `cargo run --release --example multi_rse_workflow`

use rucio::common::clock::{Clock, MINUTE_MS};
use rucio::common::config::Config;
use rucio::core::types::{DidKey, ReplicaState, RuleState};
use rucio::daemons::auditor::Auditor;
use rucio::daemons::Daemon;
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec};
use rucio::sim::workload::{Workload, WorkloadSpec};

fn main() {
    rucio::common::logx::init(0);
    let ctx = build_grid(&GridSpec::default(), Clock::sim_at(1_514_764_800_000), Config::new());
    let cat = ctx.catalog.clone();

    // --- 1. subscriptions in action: produce a RAW dataset; the
    // transmogrifier matches the standing "raw-tape-archival" subscription.
    let mut wl = Workload::new(WorkloadSpec { files_per_dataset: 4, ..Default::default() });
    let mut driver = Driver::new(ctx.clone(), wl, Driver::standard_daemons(&ctx));
    // seed one RAW dataset through the workload by running a short day
    driver.workload = Workload::new(WorkloadSpec {
        raw_datasets_per_day: 24, // ~1/hour
        derivations_per_day: 0,
        analysis_accesses_per_day: 0,
        files_per_dataset: 4,
        ..Default::default()
    });
    driver.run_days(1, 10 * MINUTE_MS);
    wl = std::mem::replace(&mut driver.workload, Workload::new(WorkloadSpec::default()));
    let _ = wl;

    let raw = cat
        .list_dids("data18", Some("raw.*"), Some(rucio::core::types::DidType::Dataset), false)
        .into_iter()
        .next()
        .expect("a RAW dataset exists");
    let rules = cat.list_rules_for_did(&raw.key);
    println!("RAW dataset {} has {} rules:", raw.key, rules.len());
    for r in &rules {
        println!("  rule {} -> {} [{}]", r.id, r.rse_expression, r.state.as_str());
    }
    assert!(
        rules.iter().any(|r| r.rse_expression == "tape"),
        "subscription created the tape-archival rule"
    );
    let ok_rules = rules.iter().filter(|r| r.state == RuleState::Ok).count();
    println!("  {ok_rules}/{} rules already satisfied", rules.len());

    // --- 2. corruption recovery: corrupt the T1 disk copy of a file that
    // has a second copy, declare it bad, let the necromancer recover.
    let file = cat
        .resolve_files(&raw.key)
        .into_iter()
        .find(|f| cat.available_replicas(&f.key).len() >= 2)
        .expect("a file with >= 2 replicas");
    let victim = cat
        .available_replicas(&file.key)
        .into_iter()
        .find(|r| !cat.get_rse(&r.rse).unwrap().is_tape)
        .unwrap();
    println!("\ncorrupting {} at {}", file.key, victim.rse);
    ctx.fleet.get(&victim.rse).unwrap().corrupt(&victim.pfn);
    cat.declare_bad(&victim.rse, &file.key, "checksum mismatch on download", "ops")
        .unwrap();
    let mut necro = rucio::daemons::necromancer::Necromancer::new(ctx.clone(), "n1");
    let handled = necro.tick(cat.now());
    assert_eq!(handled, 1);
    println!(
        "necromancer recovered: {} (queued a new transfer from the surviving copy)",
        cat.metrics.counter("necromancer.recovered") == 1
    );

    // --- 3. auditor: plant a dark file + vanish a catalog file, then run
    // three audit cycles (snapshot, dump, compare — Fig 4).
    let t1 = "FR-T1-DISK";
    let sys = ctx.fleet.get(t1).unwrap();
    let mut auditor = Auditor::new(ctx.clone(), "a1");
    auditor.tick(cat.now());
    sys.plant_dark("/unmanaged/stray.bin", 123, cat.now());
    // vanish one catalog-known file from storage
    let lost = cat
        .replicas
        .scan_limit(1, |r| r.rse == t1 && r.state == ReplicaState::Available)
        .into_iter()
        .next();
    if let Some(lost) = &lost {
        sys.vanish(&lost.pfn);
    }
    auditor.tick(cat.now());
    auditor.tick(cat.now());
    let report = &auditor.last_reports[t1];
    println!("\nauditor report for {t1}: {report:?}");
    assert!(report.dark >= 1, "dark file detected");
    if lost.is_some() {
        assert!(report.lost >= 1, "lost file flagged");
    }
    assert!(sys.stat("/unmanaged/stray.bin").is_err(), "dark file deleted");

    // --- 4. name immutability (§2.2): erase then try to reuse
    let probe = DidKey::new("data18", "immutable.probe");
    cat.add_file(&probe.scope, &probe.name, "prod", 1, "00000001", None).unwrap();
    cat.erase_did(&probe).unwrap();
    let reuse = cat.add_file(&probe.scope, &probe.name, "prod", 2, "00000002", None);
    assert!(reuse.is_err(), "names are forever");
    println!("\nname-reuse correctly rejected: {}", reuse.unwrap_err());

    println!("\nmulti_rse_workflow OK");
}
