//! Quickstart: boot an embedded Rucio (REST server + daemon fleet over a
//! simulated grid), then drive it purely through the client API:
//! create an account, register data, place a replication rule, watch the
//! daemons satisfy it, and check quota accounting.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use rucio::client::RucioClient;
use rucio::common::clock::Clock;
use rucio::common::config::Config;
use rucio::core::types::AuthType;
use rucio::sim::driver::Driver;
use rucio::sim::grid::{build_grid, GridSpec};

fn main() {
    rucio::common::logx::init(0);
    // 1. boot the deployment (real clock: daemons on threads)
    let ctx = build_grid(&GridSpec::default(), Clock::real(), Config::new());
    ctx.catalog
        .add_identity("root", AuthType::UserPass, "root", Some("secret"))
        .unwrap();
    let server = rucio::server::serve(ctx.catalog.clone(), ctx.broker.clone(), "127.0.0.1:0", 4)
        .expect("server start");
    let stop = Arc::new(AtomicBool::new(false));
    let daemons = Driver::standard_daemons(&ctx);
    let handles = rucio::daemons::run_threaded(daemons, stop.clone());
    println!("server: {}  daemons: {}", server.url(), handles.len());

    // FTS progression thread (the simulated middleware's own clock)
    let fts = ctx.fts.clone();
    let stop2 = stop.clone();
    let fts_thread = std::thread::spawn(move || {
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            let now = Clock::Real.now_ms();
            for f in &fts {
                f.advance(now);
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    });

    // 2. connect as root, set up alice
    let root = RucioClient::connect(&server.url(), "root", "root", "secret").unwrap();
    root.ping().unwrap();
    root.add_account("carol", "carolpw").unwrap();
    let alice = RucioClient::connect(&server.url(), "carol", "carol", "carolpw").unwrap();

    // 3. register a dataset with two files, upload them at CERN
    alice.add_dataset("user.carol", "myanalysis").unwrap();
    for (name, content) in [("hist1.root", b"histogram-data-1".as_ref()), ("hist2.root", b"xyz".as_ref())] {
        let adler = rucio::common::checksum::adler32_hex(content);
        alice
            .add_file("user.carol", name, content.len() as u64, &adler)
            .unwrap();
        let rep = alice
            .register_replica("CERN-PROD", "user.carol", name, None)
            .unwrap();
        let pfn = rep.req_str("pfn").unwrap();
        ctx.fleet
            .get("CERN-PROD")
            .unwrap()
            .put_bytes(pfn, content, ctx.catalog.now())
            .unwrap();
        alice.attach("user.carol", "myanalysis", "user.carol", name).unwrap();
        alice.send_trace("upload", "CERN-PROD", "user.carol", name).unwrap();
    }

    // 4. paper §2.5 example: "2 copies of user.carol:myanalysis at
    //    country=US with 48 hours of lifetime" — scaled to 1 copy here
    let rule_id = alice
        .add_rule("user.carol", "myanalysis", "region=US&type=disk", 1, Some(48 * 3_600_000))
        .unwrap();
    println!("rule {rule_id} placed: replicate to a US disk RSE");

    // 5. wait for the conveyor + FTS to satisfy it
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let rule = alice.get_rule(rule_id).unwrap();
        let state = rule.req_str("state").unwrap().to_string();
        println!(
            "  rule state: {state} (ok={}, replicating={})",
            rule.req_u64("locks_ok").unwrap(),
            rule.req_u64("locks_replicating").unwrap()
        );
        if state == "OK" {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "rule did not converge in time"
        );
        std::thread::sleep(std::time::Duration::from_secs(1));
    }

    // 6. replicas + usage
    for f in ["hist1.root", "hist2.root"] {
        let reps = alice.list_replicas("user.carol", f).unwrap();
        let rses: Vec<&str> = reps.iter().filter_map(|r| r.opt_str("rse")).collect();
        println!("  {f}: replicas at {rses:?}");
        assert_eq!(reps.len(), 2, "CERN + US copy");
    }
    let (bytes, files) = alice.usage("carol", "CERN-PROD").unwrap();
    println!("alice usage at CERN-PROD: {bytes} bytes, {files} files (rule-derived)");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    let _ = fts_thread.join();
    println!("quickstart OK");
}
