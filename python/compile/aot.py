"""AOT compilation: lower the L2 JAX models to HLO *text* artifacts the
Rust runtime loads via PJRT.

HLO text (NOT ``lowered.compiler_ir().serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts():
    """(name, lowered, manifest metadata) for every artifact."""
    n, d = model.PLACEMENT_N, model.N_FEATURES
    b, h = model.T3C_BATCH, model.T3C_HIDDEN

    arts = []

    lowered = jax.jit(model.placement_score).lower(f32(n, d), f32(d), f32(n))
    arts.append(
        (
            "placement_score",
            lowered,
            {
                "inputs": [[n, d], [d], [n]],
                "outputs": [[n], [n]],
                "doc": "masked scores + softmax probs over candidates",
            },
        )
    )

    lowered = jax.jit(model.t3c_predict).lower(
        f32(d, h), f32(h), f32(h, 1), f32(1), f32(b, d)
    )
    arts.append(
        (
            "t3c_predict",
            lowered,
            {
                "inputs": [[d, h], [h], [h, 1], [1], [b, d]],
                "outputs": [[b]],
                "doc": "T3C MLP forward: predicted log-duration per row",
            },
        )
    )

    lowered = jax.jit(model.t3c_train_step).lower(
        f32(d, h), f32(h), f32(h, 1), f32(1), f32(b, d), f32(b), f32(b), f32()
    )
    arts.append(
        (
            "t3c_train_step",
            lowered,
            {
                "inputs": [[d, h], [h], [h, 1], [1], [b, d], [b], [b], []],
                "outputs": [[], [d, h], [h], [h, 1], [1]],
                "doc": "one SGD step: loss + updated params (fwd/bwd via jax.grad)",
            },
        )
    )
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "placement_n": model.PLACEMENT_N,
        "n_features": model.N_FEATURES,
        "t3c_batch": model.T3C_BATCH,
        "t3c_hidden": model.T3C_HIDDEN,
        "artifacts": {},
    }
    for name, lowered, meta in build_artifacts():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = meta
        print(f"wrote {path} ({len(text)} chars)")

    # Initial t3c parameters, row-major little-endian f32, for Rust.
    import numpy as np

    params = model.t3c_init()
    flat = np.concatenate([np.asarray(p).ravel() for p in params]).astype("<f4")
    with open(os.path.join(args.out, "t3c_params.bin"), "wb") as fh:
        fh.write(flat.tobytes())
    manifest["t3c_params_layout"] = [list(np.asarray(p).shape) for p in params]

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
