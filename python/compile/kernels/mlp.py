"""L1 Pallas kernel: fused dense layer (matmul + bias + optional ReLU) for
the T³C transfer-time predictor (paper §6.3).

The paper's T³C models transfer-stage durations with offline Python
analytics; re-thought for the TPU execution model this is a small MLP
whose layers are fused matmul+bias+activation tiles: a (BLOCK_B x D_in)
activation tile and the full (D_in x D_out) weight panel sit in VMEM and
feed one MXU matmul per grid step — the Pallas analog of a tensor-core
GEMM epilogue fusion.

Autodiff: interpret-mode ``pallas_call`` has no built-in VJP, so ``dense``
carries a ``jax.custom_vjp`` — the activation gradient (``g @ W^T``)
re-enters the same Pallas tile (batch-tiled MXU matmul), while the weight
and bias gradients are batch reductions left to XLA fusion.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile (rows per grid step).
BLOCK_B = 32


def _dense_kernel(relu, x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]            # (BLOCK_B, D_in)
    w = w_ref[...]            # (D_in, D_out)
    b = b_ref[...]            # (1, D_out)
    # MXU matmul with f32 accumulation.
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _dense_impl(x, w, b, relu):
    bsz, d_in = x.shape
    d_in2, d_out = w.shape
    assert d_in == d_in2, f"shape mismatch {x.shape} @ {w.shape}"
    assert bsz % BLOCK_B == 0, f"B={bsz} must be a multiple of {BLOCK_B}"
    grid = (bsz // BLOCK_B,)
    kernel = functools.partial(_dense_kernel, relu)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, d_out), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, d_in), lambda i: (i, 0)),
            pl.BlockSpec((d_in, d_out), lambda i: (0, 0)),
            pl.BlockSpec((1, d_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, d_out), lambda i: (i, 0)),
        interpret=True,
    )(x, w, b.reshape(1, -1))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dense_vjp(x, w, b, relu):
    return _dense_impl(x, w, b, relu)


def _dense_fwd(x, w, b, relu):
    y = _dense_impl(x, w, b, relu)
    return y, (x, w, y)


def _dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0).astype(g.dtype)
    # Activation gradient re-enters the Pallas tile: dx = g @ W^T.
    zero_bias = jnp.zeros((w.shape[0],), dtype=g.dtype)
    dx = _dense_impl(g, w.T, zero_bias, False)
    # Weight/bias grads are batch reductions; XLA fuses these.
    dw = x.T @ g
    db = jnp.sum(g, axis=0)
    return dx, dw, db


_dense_vjp.defvjp(_dense_fwd, _dense_bwd)


def dense(x, w, b, relu=False):
    """Fused y = act(x @ w + b). ``x`` is [B, D_in] with B a multiple of
    BLOCK_B; ``w`` is [D_in, D_out]; ``b`` is [D_out]. Differentiable."""
    return _dense_vjp(x, w, b, relu)
