"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
the pytest/hypothesis suite checks against (charter deliverable c)."""

import jax.numpy as jnp

NEG_INF = -1e30


def placement_scores_ref(features, weights, mask):
    """Reference masked weighted-sum scoring."""
    s = features @ weights
    return jnp.where(mask > 0.5, s, NEG_INF)


def dense_ref(x, w, b, relu=False):
    """Reference dense layer."""
    y = x @ w + b
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def mlp_ref(params, x):
    """Reference 2-layer MLP (the T³C predictor)."""
    w1, b1, w2, b2 = params
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return (h @ w2 + b2)[:, 0]
