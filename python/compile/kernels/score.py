"""L1 Pallas kernel: masked placement scoring (paper §6.1).

The dynamic-data-placement daemon (C3PO) scores candidate RSEs for a
dataset replica: each candidate is a feature row (free space, source
bandwidth, queued files, recent-replica penalty, popularity, distance,
load, bias) and the score is a weighted sum with invalid candidates
masked to -inf.

TPU-shaped: candidates are tiled in blocks of ``BLOCK_N`` rows that live
in VMEM (BLOCK_N x D x 4 B = 4 KiB per tile at the default shape); the
row-reduction feeds the VPU/MXU-friendly dot. ``interpret=True`` is
mandatory on this CPU image (real-TPU lowering emits Mosaic custom calls
the CPU PJRT client cannot run — see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape: one VMEM-resident block of candidates.
BLOCK_N = 128
# Feature dimension (fixed across the stack; see rust/src/placement).
N_FEATURES = 8

NEG_INF = -1e30


def _score_kernel(f_ref, w_ref, m_ref, o_ref):
    """One block: scores = mask ? F @ w : -inf."""
    f = f_ref[...]          # (BLOCK_N, D)  VMEM
    w = w_ref[...]          # (1, D)        VMEM (broadcast row)
    m = m_ref[...]          # (BLOCK_N,)    VMEM
    # Weighted sum over features — a rank-1 matmul on the MXU.
    s = jnp.sum(f * w, axis=1)
    o_ref[...] = jnp.where(m > 0.5, s, NEG_INF)


@functools.partial(jax.jit, static_argnames=())
def placement_scores(features, weights, mask):
    """Score ``features`` [N, D] with ``weights`` [D], masking by ``mask``
    [N]. N must be a multiple of BLOCK_N (callers pad with mask=0 rows).
    """
    n, d = features.shape
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    assert d == N_FEATURES, f"D={d} != {N_FEATURES}"
    grid = (n // BLOCK_N,)
    return pl.pallas_call(
        _score_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        interpret=True,
    )(features, weights.reshape(1, -1), mask)
