"""L2 — JAX decision models for the paper's §6 advanced features, calling
the L1 Pallas kernels. Lowered once by ``aot.py``; never imported at
request time (the Rust coordinator executes the compiled artifacts via
PJRT).

Models:
* ``placement_score`` — C3PO dynamic-placement scoring (§6.1): masked
  weighted scores + a softmax distribution over candidates.
* ``t3c_predict`` — T³C transfer-time prediction MLP forward (§6.3).
* ``t3c_train_step`` — full fwd/bwd (jax.grad) + SGD update, exported so
  the Rust t3c daemon trains *online* from completed-transfer telemetry.
"""

import jax
import jax.numpy as jnp

from compile.kernels import mlp, score

# ---------------------------------------------------------------------
# shapes (fixed at AOT time; the Rust side pads to these)
# ---------------------------------------------------------------------

#: candidate rows for placement scoring (2 VMEM tiles of 128).
PLACEMENT_N = 256
#: shared feature dimension.
N_FEATURES = score.N_FEATURES
#: t3c batch rows (1 tile of 32) and hidden width.
T3C_BATCH = 32
T3C_HIDDEN = 32


def placement_score(features, weights, mask):
    """Masked scores + softmax selection distribution.

    Returns ``(scores [N], probs [N])``; invalid rows get -inf / 0.
    """
    s = score.placement_scores(features, weights, mask)
    # Numerically-stable masked softmax over the valid rows.
    m = jnp.max(s)
    e = jnp.where(mask > 0.5, jnp.exp(s - m), 0.0)
    z = jnp.sum(e) + 1e-30
    return s, e / z


def t3c_init(key=None):
    """Deterministic parameter init (He-ish) for the T³C MLP."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (N_FEATURES, T3C_HIDDEN)) * (2.0 / N_FEATURES) ** 0.5
    b1 = jnp.zeros((T3C_HIDDEN,))
    w2 = jax.random.normal(k2, (T3C_HIDDEN, 1)) * (2.0 / T3C_HIDDEN) ** 0.5
    b2 = jnp.zeros((1,))
    return (
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
    )


def t3c_predict(w1, b1, w2, b2, x):
    """Forward pass: predicted log-seconds-to-complete per row of ``x``."""
    h = mlp.dense(x, w1, b1, relu=True)
    y = mlp.dense(h, w2, b2, relu=False)
    return y[:, 0]


def t3c_loss(params, x, y, sample_mask):
    """Masked MSE on log-durations (padding rows carry mask 0)."""
    w1, b1, w2, b2 = params
    pred = t3c_predict(w1, b1, w2, b2, x)
    se = (pred - y) ** 2 * sample_mask
    return jnp.sum(se) / (jnp.sum(sample_mask) + 1e-9)


def t3c_train_step(w1, b1, w2, b2, x, y, sample_mask, lr):
    """One SGD step: returns (loss, new_w1, new_b1, new_w2, new_b2).

    ``jax.value_and_grad`` differentiates through the Pallas kernels —
    the paper-charter L2 fwd/bwd requirement.
    """
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(t3c_loss)(params, x, y, sample_mask)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (loss,) + new
