"""L1 correctness: Pallas kernels vs the pure-jnp oracle — the CORE
correctness signal of the compile path. Hypothesis sweeps shapes and data.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref, score


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------
# placement scoring kernel
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_score_matches_ref_over_shapes(tiles, seed):
    r = rng(seed)
    n = tiles * score.BLOCK_N
    f = r.normal(size=(n, score.N_FEATURES)).astype(np.float32)
    w = r.normal(size=(score.N_FEATURES,)).astype(np.float32)
    m = (r.random(n) > 0.3).astype(np.float32)
    got = score.placement_scores(jnp.array(f), jnp.array(w), jnp.array(m))
    want = ref.placement_scores_ref(f, w, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_score_masks_invalid_rows():
    n = score.BLOCK_N
    f = np.ones((n, score.N_FEATURES), dtype=np.float32)
    w = np.ones(score.N_FEATURES, dtype=np.float32)
    m = np.zeros(n, dtype=np.float32)
    m[3] = 1.0
    got = np.asarray(score.placement_scores(jnp.array(f), jnp.array(w), jnp.array(m)))
    assert got[3] == pytest.approx(score.N_FEATURES)
    assert (got[np.arange(n) != 3] <= ref.NEG_INF / 2).all()


def test_score_rejects_unpadded_shapes():
    f = np.zeros((100, score.N_FEATURES), dtype=np.float32)
    w = np.zeros(score.N_FEATURES, dtype=np.float32)
    m = np.zeros(100, dtype=np.float32)
    with pytest.raises(AssertionError):
        score.placement_scores(jnp.array(f), jnp.array(w), jnp.array(m))


# ---------------------------------------------------------------------
# fused dense kernel
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    btiles=st.integers(min_value=1, max_value=4),
    d_in=st.sampled_from([4, 8, 16]),
    d_out=st.sampled_from([1, 8, 32]),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dense_matches_ref_over_shapes(btiles, d_in, d_out, relu, seed):
    r = rng(seed)
    b = btiles * mlp.BLOCK_B
    x = r.normal(size=(b, d_in)).astype(np.float32)
    w = r.normal(size=(d_in, d_out)).astype(np.float32)
    bias = r.normal(size=(d_out,)).astype(np.float32)
    got = mlp.dense(jnp.array(x), jnp.array(w), jnp.array(bias), relu=relu)
    want = ref.dense_ref(x, w, bias, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_dense_relu_clamps_negative():
    b, d = mlp.BLOCK_B, 4
    x = -np.ones((b, d), dtype=np.float32)
    w = np.eye(d, dtype=np.float32)
    bias = np.zeros(d, dtype=np.float32)
    got = np.asarray(mlp.dense(jnp.array(x), jnp.array(w), jnp.array(bias), relu=True))
    assert (got == 0).all()


# ---------------------------------------------------------------------
# full MLP via kernels vs oracle
# ---------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_mlp_forward_matches_ref(seed):
    from compile import model

    r = rng(seed)
    params = model.t3c_init()
    x = r.normal(size=(model.T3C_BATCH, model.N_FEATURES)).astype(np.float32)
    got = model.t3c_predict(*params, jnp.array(x))
    want = ref.mlp_ref(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
