"""L2 model tests: placement softmax semantics, t3c training dynamics, and
AOT artifact generation (golden shape of the HLO text)."""

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_placement_softmax_is_distribution():
    n, d = model.PLACEMENT_N, model.N_FEATURES
    r = np.random.default_rng(1)
    f = r.normal(size=(n, d)).astype(np.float32)
    w = r.normal(size=(d,)).astype(np.float32)
    m = np.zeros(n, dtype=np.float32)
    m[:10] = 1.0
    scores, probs = model.placement_score(jnp.array(f), jnp.array(w), jnp.array(m))
    probs = np.asarray(probs)
    assert probs.shape == (n,)
    assert probs[10:].sum() == 0.0, "masked rows carry no probability"
    np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-5)
    # argmax of probs == argmax of scores among valid rows
    assert probs.argmax() == np.asarray(scores)[:10].argmax()


def test_placement_single_valid_row_gets_all_mass():
    n, d = model.PLACEMENT_N, model.N_FEATURES
    f = np.zeros((n, d), dtype=np.float32)
    w = np.ones(d, dtype=np.float32)
    m = np.zeros(n, dtype=np.float32)
    m[7] = 1.0
    _, probs = model.placement_score(jnp.array(f), jnp.array(w), jnp.array(m))
    np.testing.assert_allclose(np.asarray(probs)[7], 1.0, rtol=1e-6)


def test_t3c_training_reduces_loss():
    r = np.random.default_rng(2)
    params = model.t3c_init()
    b, d = model.T3C_BATCH, model.N_FEATURES
    # synthetic target: a fixed linear function of the features
    true_w = r.normal(size=(d,)).astype(np.float32)
    losses = []
    for step in range(60):
        x = r.normal(size=(b, d)).astype(np.float32)
        y = x @ true_w
        mask = np.ones(b, dtype=np.float32)
        out = model.t3c_train_step(
            *params, jnp.array(x), jnp.array(y), jnp.array(mask), jnp.float32(0.05)
        )
        losses.append(float(out[0]))
        params = tuple(out[1:])
    assert losses[-1] < losses[0] * 0.5, f"no learning: {losses[0]} -> {losses[-1]}"


def test_t3c_masked_rows_do_not_train():
    params = model.t3c_init()
    b, d = model.T3C_BATCH, model.N_FEATURES
    x = np.ones((b, d), dtype=np.float32)
    y = np.full(b, 100.0, dtype=np.float32)
    mask = np.zeros(b, dtype=np.float32)
    out = model.t3c_train_step(
        *params, jnp.array(x), jnp.array(y), jnp.array(mask), jnp.float32(0.1)
    )
    # zero mask → zero effective loss and unchanged params
    assert float(out[0]) == 0.0
    for p_old, p_new in zip(params, out[1:]):
        np.testing.assert_allclose(np.asarray(p_old), np.asarray(p_new))


def test_aot_artifacts_lower_to_hlo_text():
    for name, lowered, meta in aot.build_artifacts():
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, name
        assert len(meta["inputs"]) >= 1
        # fixed shapes show up in the module signature
        assert "f32[" in text
