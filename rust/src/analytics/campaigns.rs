//! Campaign analytics: the per-campaign report emitted by the
//! [`crate::sim::campaign`] engine — time-to-complete, backlog/lock-count
//! curves, per-link utilization, deletion rate, and recall-wave depth.
//! These are the quantities the paper reports for its planned-load
//! operations (end-of-year reprocessing, the §4.3 deletion-rate tables,
//! §1.3 tape recall waves), condensed the same way
//! [`crate::analytics::chaos`] condenses incident recovery.

use std::collections::BTreeMap;

use crate::analytics::chaos::BacklogSample;
use crate::common::clock::{EpochMs, HOUR_MS};

/// One point on a campaign's progress curves, captured by the driver's
/// `run_span` observe hook every sampling interval.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CampaignSample {
    pub t: EpochMs,
    /// The standard work-queue snapshot (waiting/queued/submitted/retry…).
    pub backlog: BacklogSample,
    /// Total lock rows in the catalog (the lock-count curve).
    pub locks_total: usize,
    /// Campaign rules not yet `Ok` (0 = converged).
    pub rules_pending: usize,
    /// Cumulative reaper deletions at this instant (files / bytes).
    pub deleted_files: u64,
    pub deleted_bytes: u64,
    /// Outstanding tape recall queue depth across the fleet.
    pub staging_depth: usize,
    /// Hottest single FTS link at this instant (active transfers).
    pub peak_link_active: usize,
}

/// The condensed outcome of one campaign run. `PartialEq` so fixed-seed
/// determinism can be asserted by comparing whole reports.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CampaignReport {
    pub name: String,
    /// "reprocessing" | "mass-deletion" | "tape-carousel".
    pub kind: String,
    pub started_at: EpochMs,
    pub finished_at: EpochMs,
    /// Did the campaign converge within its day budget?
    pub completed: bool,
    /// Virtual time from launch to convergence (`None` = never).
    pub time_to_complete_ms: Option<i64>,
    /// Rules injected by the campaign (reprocessing / carousel waves).
    pub rules_created: usize,
    /// Rule batches that failed outright (rolled back by
    /// `add_rules_bulk`) — non-zero means the catalog refused load.
    pub batches_failed: usize,
    /// Locks created for the campaign's rules.
    pub locks_created: usize,
    /// DIDs the campaign targeted (datasets matched by the filter).
    pub datasets_targeted: usize,
    /// Rules the campaign expired (mass deletion).
    pub rules_expired: usize,
    /// Reaper work attributed to the campaign window.
    pub deleted_files: u64,
    pub deleted_bytes: u64,
    /// Deletion throughput over the campaign window (files/hour).
    pub deletion_rate_per_hour: f64,
    /// Curve extremes.
    pub peak_backlog: usize,
    pub peak_locks: usize,
    /// Tape carousel: waves executed and the deepest recall queue seen.
    pub waves: usize,
    pub max_wave_depth: usize,
    /// Peak concurrent transfers observed per (src_site, dst_site) link.
    pub per_link_peak: BTreeMap<(String, String), usize>,
    /// The FTS per-link concurrency cap in force during the run.
    pub link_cap: usize,
    /// True if any sample saw a link above the cap (must stay false).
    pub link_cap_exceeded: bool,
    /// The full sampled curves.
    pub samples: Vec<CampaignSample>,
}

impl CampaignReport {
    /// Worst per-link concurrency across the whole run.
    pub fn peak_link_active(&self) -> usize {
        self.per_link_peak.values().copied().max().unwrap_or(0)
    }

    /// One summary row (shared layout with [`report_table`]).
    pub fn summary_row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.kind.clone(),
            self.datasets_targeted.to_string(),
            self.rules_created.to_string(),
            self.locks_created.to_string(),
            self.time_to_complete_ms
                .map(|ms| format!("{:.1}", ms as f64 / HOUR_MS as f64))
                .unwrap_or_else(|| "never".into()),
            self.deleted_files.to_string(),
            format!("{:.0}", self.deletion_rate_per_hour),
            self.peak_backlog.to_string(),
            self.max_wave_depth.to_string(),
            format!("{}/{}", self.peak_link_active(), self.link_cap),
        ]
    }

    /// The summary header matching [`CampaignReport::summary_row`].
    pub fn summary_header() -> Vec<&'static str> {
        vec![
            "campaign",
            "kind",
            "datasets",
            "rules",
            "locks",
            "t-complete (h)",
            "deleted",
            "del/h",
            "peak backlog",
            "wave depth",
            "link peak/cap",
        ]
    }
}

/// Season summary: one row per campaign (CSV-able like the §4.6 report
/// lists).
pub fn report_table(reports: &[CampaignReport]) -> Vec<Vec<String>> {
    let mut rows = vec![CampaignReport::summary_header()
        .into_iter()
        .map(String::from)
        .collect::<Vec<_>>()];
    for r in reports {
        rows.push(r.summary_row());
    }
    rows
}

/// A campaign's progress curves as CSV rows (plot source for the
/// backlog/lock-count/deletion-rate/wave-depth figures).
pub fn curves_csv(report: &CampaignReport) -> String {
    let mut out = String::from(
        "t_ms,backlog,locks_total,rules_pending,deleted_files,deleted_bytes,\
         staging_depth,peak_link_active\n",
    );
    for s in &report.samples {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{}\n",
            s.t - report.started_at,
            s.backlog.backlog(),
            s.locks_total,
            s.rules_pending,
            s.deleted_files,
            s.deleted_bytes,
            s.staging_depth,
            s.peak_link_active,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: EpochMs, locks: usize) -> CampaignSample {
        CampaignSample { t, locks_total: locks, ..Default::default() }
    }

    #[test]
    fn summary_and_curves_render() {
        let mut per_link_peak = BTreeMap::new();
        per_link_peak.insert(("A".to_string(), "B".to_string()), 7);
        let r = CampaignReport {
            name: "reprocess-raw".into(),
            kind: "reprocessing".into(),
            started_at: 1000,
            finished_at: 1000 + 2 * HOUR_MS,
            completed: true,
            time_to_complete_ms: Some(2 * HOUR_MS),
            rules_created: 40,
            locks_created: 320,
            datasets_targeted: 40,
            deletion_rate_per_hour: 12.5,
            per_link_peak,
            link_cap: 20,
            samples: vec![sample(1000, 10), sample(2000, 300)],
            ..Default::default()
        };
        assert_eq!(r.peak_link_active(), 7);
        let rows = report_table(std::slice::from_ref(&r));
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), rows[1].len(), "header and row widths match");
        assert_eq!(rows[1][5], "2.0", "time-to-complete in hours");
        let csv = curves_csv(&r);
        assert_eq!(csv.lines().count(), 3, "header + 2 samples");
        assert!(csv.lines().nth(2).unwrap().starts_with("1000,"), "t relative to start");
    }

    #[test]
    fn reports_compare_for_determinism() {
        let a = CampaignReport { name: "x".into(), rules_created: 5, ..Default::default() };
        let b = CampaignReport { name: "x".into(), rules_created: 5, ..Default::default() };
        assert_eq!(a, b);
        let c = CampaignReport { name: "x".into(), rules_created: 6, ..Default::default() };
        assert_ne!(a, c);
    }
}
