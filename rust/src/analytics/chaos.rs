//! Per-scenario recovery analytics: backlog sampling over a chaos run and
//! the recovery report (time-to-reconverge, backlog depth) the scenario
//! suite asserts on.
//!
//! The discrete-event driver captures a [`BacklogSample`] every invariant
//! cycle; [`recovery_report`] condenses the series into "how deep did the
//! backlog get, and how long after the fault cleared did the system
//! return to its pre-fault level" — the quantities the paper's daemons
//! (conveyor retries, judge repair, necromancer, reaper) exist to bound.

use crate::common::clock::EpochMs;
use crate::core::types::{RequestState, RuleState};
use crate::daemons::Ctx;

/// One point-in-time measurement of the work queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BacklogSample {
    pub t: EpochMs,
    /// Requests held back by the throttler's admission control.
    pub waiting: usize,
    /// Transfer requests waiting for submission.
    pub queued: usize,
    /// Requests in flight at FTS.
    pub submitted: usize,
    /// Requests in retry backoff.
    pub retry: usize,
    pub stuck_rules: usize,
    pub replicating_rules: usize,
    /// FTS-side queue depth (submitted but not yet active), all servers.
    pub fts_queue: usize,
    /// Bad replicas awaiting necromancer triage.
    pub unresolved_bad: usize,
}

impl BacklogSample {
    /// Total transfer backlog: everything not yet moved.
    pub fn backlog(&self) -> usize {
        self.waiting + self.queued + self.submitted + self.retry
    }

    /// Capture the current queue state of a deployment.
    pub fn capture(ctx: &Ctx) -> BacklogSample {
        let cat = &ctx.catalog;
        BacklogSample {
            t: cat.now(),
            waiting: cat.requests_by_state.count(&RequestState::Waiting),
            queued: cat.requests_by_state.count(&RequestState::Queued),
            submitted: cat.requests_by_state.count(&RequestState::Submitted),
            retry: cat.requests_by_state.count(&RequestState::Retry),
            stuck_rules: cat.rules_by_state.count(&RuleState::Stuck),
            replicating_rules: cat.rules_by_state.count(&RuleState::Replicating),
            fts_queue: ctx.fts.iter().map(|f| f.queue_depth()).sum(),
            unresolved_bad: cat.bad_replicas.count_where(|b| !b.resolved),
        }
    }
}

/// Condensed recovery behaviour of one chaos scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Backlog just before the fault hit (the reconvergence target).
    pub baseline_backlog: usize,
    pub baseline_stuck: usize,
    /// Worst backlog observed from fault injection onward.
    pub peak_backlog: usize,
    pub peak_stuck: usize,
    /// First sample time at/after `fault_cleared` where the system was
    /// back at (or below) its pre-fault level; `None` = never recovered
    /// within the run.
    pub reconverged_at: Option<EpochMs>,
    /// `reconverged_at - fault_cleared`.
    pub time_to_reconverge_ms: Option<i64>,
}

/// Build the report from a sample series and the fault window
/// `[fault_start, fault_cleared]` (virtual timestamps).
pub fn recovery_report(
    samples: &[BacklogSample],
    fault_start: EpochMs,
    fault_cleared: EpochMs,
) -> RecoveryReport {
    let baseline = samples
        .iter()
        .rfind(|s| s.t < fault_start)
        .copied()
        .unwrap_or_default();
    // A handful of in-flight transfers is steady-state noise, not backlog.
    let target_backlog = baseline.backlog().max(8);
    let target_stuck = baseline.stuck_rules;

    let mut peak_backlog = 0;
    let mut peak_stuck = 0;
    let mut reconverged_at = None;
    for s in samples.iter().filter(|s| s.t >= fault_start) {
        peak_backlog = peak_backlog.max(s.backlog());
        peak_stuck = peak_stuck.max(s.stuck_rules);
        if reconverged_at.is_none()
            && s.t >= fault_cleared
            && s.backlog() <= target_backlog
            && s.stuck_rules <= target_stuck
        {
            reconverged_at = Some(s.t);
        }
    }
    RecoveryReport {
        baseline_backlog: baseline.backlog(),
        baseline_stuck: baseline.stuck_rules,
        peak_backlog,
        peak_stuck,
        reconverged_at,
        time_to_reconverge_ms: reconverged_at.map(|t| t - fault_cleared),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: EpochMs, queued: usize, stuck: usize) -> BacklogSample {
        BacklogSample { t, queued, stuck_rules: stuck, ..Default::default() }
    }

    #[test]
    fn report_finds_peak_and_reconvergence() {
        let samples = vec![
            s(0, 2, 0),
            s(100, 3, 0), // baseline (last pre-fault)
            s(200, 40, 5),
            s(300, 80, 9), // peak during fault
            s(400, 30, 4), // fault cleared at 350; still draining
            s(500, 6, 0),  // back under max(baseline, 8)
            s(600, 2, 0),
        ];
        let r = recovery_report(&samples, 150, 350);
        assert_eq!(r.baseline_backlog, 3);
        assert_eq!(r.peak_backlog, 80);
        assert_eq!(r.peak_stuck, 9);
        assert_eq!(r.reconverged_at, Some(500));
        assert_eq!(r.time_to_reconverge_ms, Some(150));
    }

    #[test]
    fn unrecovered_run_reports_none() {
        let samples = vec![s(0, 1, 0), s(200, 50, 3), s(300, 45, 3)];
        let r = recovery_report(&samples, 100, 250);
        assert_eq!(r.reconverged_at, None);
        assert_eq!(r.time_to_reconverge_ms, None);
    }

    #[test]
    fn empty_series_is_benign() {
        let r = recovery_report(&[], 0, 0);
        assert_eq!(r.peak_backlog, 0);
        assert_eq!(r.reconverged_at, None);
    }
}
