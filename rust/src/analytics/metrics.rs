//! Internal metrics — the pystats/statsd/Graphite stand-in (paper §4.6:
//! counters and timers aggregated centrally, flushed periodically).
//!
//! Counters and gauges are plain named integers; timers keep reservoir
//! samples for percentile dashboards. Everything is cheap enough to call
//! from hot paths.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const RESERVOIR: usize = 4096;

#[derive(Default)]
struct TimerState {
    samples: Vec<f64>,
    count: u64,
    sum: f64,
}

/// The process-wide metric registry. Cheap to clone (shared state).
#[derive(Clone, Default)]
pub struct Metrics {
    counters: Arc<Mutex<BTreeMap<String, Arc<AtomicU64>>>>,
    gauges: Arc<Mutex<BTreeMap<String, Arc<AtomicU64>>>>,
    timers: Arc<Mutex<BTreeMap<String, TimerState>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn counter_handle(&self, name: &str) -> Arc<AtomicU64> {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    /// Increment a counter by `n`.
    pub fn incr(&self, name: &str, n: u64) {
        self.counter_handle(name).fetch_add(n, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Set a gauge to an absolute value (queue sizes, §4.6 probes).
    pub fn gauge_set(&self, name: &str, value: u64) {
        let mut map = self.gauges.lock().unwrap();
        map.entry(name.to_string())
            .or_default()
            .store(value, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a timing sample in milliseconds.
    pub fn time_ms(&self, name: &str, ms: f64) {
        let mut map = self.timers.lock().unwrap();
        let t = map.entry(name.to_string()).or_default();
        t.count += 1;
        t.sum += ms;
        if t.samples.len() < RESERVOIR {
            t.samples.push(ms);
        } else {
            // Reservoir sampling keeps percentiles unbiased.
            let idx = (t.count as usize * 2654435761) % t.count as usize;
            if idx < RESERVOIR {
                t.samples[idx] = ms;
            }
        }
    }

    /// (count, mean, p50, p95, p99) for a timer.
    pub fn timer_stats(&self, name: &str) -> Option<(u64, f64, f64, f64, f64)> {
        let map = self.timers.lock().unwrap();
        let t = map.get(name)?;
        if t.count == 0 {
            return None;
        }
        let mut s = t.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| s[((p * (s.len() - 1) as f64).round() as usize).min(s.len() - 1)];
        Some((t.count, t.sum / t.count as f64, pct(0.5), pct(0.95), pct(0.99)))
    }

    /// Flush-style snapshot of all counters and gauges.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        let mut out = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.insert(format!("counter.{k}"), v.load(Ordering::Relaxed));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.insert(format!("gauge.{k}"), v.load(Ordering::Relaxed));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("transfers.done", 1);
        m.incr("transfers.done", 4);
        assert_eq!(m.counter("transfers.done"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = Metrics::new();
        m.gauge_set("queue.depth", 10);
        m.gauge_set("queue.depth", 3);
        assert_eq!(m.gauge("queue.depth"), 3);
    }

    #[test]
    fn timer_percentiles_ordered() {
        let m = Metrics::new();
        for i in 0..1000 {
            m.time_ms("api.get", i as f64);
        }
        let (count, mean, p50, p95, p99) = m.timer_stats("api.get").unwrap();
        assert_eq!(count, 1000);
        assert!(mean > 0.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!((400.0..600.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn snapshot_includes_both() {
        let m = Metrics::new();
        m.incr("a", 1);
        m.gauge_set("b", 2);
        let s = m.snapshot();
        assert_eq!(s["counter.a"], 1);
        assert_eq!(s["gauge.b"], 2);
    }

    #[test]
    fn clones_share_state() {
        let m = Metrics::new();
        let m2 = m.clone();
        m.incr("x", 7);
        assert_eq!(m2.counter("x"), 7);
    }
}
