//! Monitoring + analytics (paper §4.6): internal metrics, traces, and the
//! report/accounting pipelines (CSV lists) — the Graphite/Elasticsearch/
//! Hadoop stack collapsed to in-process equivalents.

pub mod campaigns;
pub mod chaos;
pub mod metrics;
pub mod reports;

pub use metrics::Metrics;
