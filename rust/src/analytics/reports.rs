//! Report generation (paper §4.6): the daily/weekly CSV lists produced by
//! the Hadoop/Pig pipeline — per-RSE replica lists (the consistency
//! daemon's input), dataset-lock lists for site admins, unused-dataset
//! lists for resource planning, and storage accounting.

use std::collections::BTreeMap;

use crate::common::clock::{EpochMs, WEEK_MS};
use crate::core::types::DidType;
use crate::core::Catalog;

/// CSV rendering helper: rows of string cells → one CSV document.
pub fn to_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// The daily "list of file replicas per RSE" (auditor input).
pub fn replicas_per_rse(catalog: &Catalog, rse: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    catalog.replicas.for_each(|r| {
        if r.rse == rse {
            rows.push(vec![
                r.did.scope.clone(),
                r.did.name.clone(),
                r.pfn.clone(),
                r.bytes.to_string(),
                r.state.as_str().to_string(),
            ]);
        }
    });
    rows
}

/// Dataset-lock list per RSE: which rules pin data at a site (site-admin
/// report).
pub fn locks_per_rse(catalog: &Catalog, rse: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    catalog.locks.for_each(|l| {
        if l.rse == rse {
            rows.push(vec![
                l.rule_id.to_string(),
                l.did.to_string(),
                l.bytes.to_string(),
                format!("{:?}", l.state),
            ]);
        }
    });
    rows
}

/// Unused datasets: no accesses within `idle_ms` (resource planning).
pub fn unused_datasets(catalog: &Catalog, now: EpochMs, idle_ms: i64) -> Vec<String> {
    let mut out = Vec::new();
    catalog.dids.for_each(|d| {
        if d.did_type == DidType::Dataset {
            let last = catalog
                .popularity
                .get(&d.key)
                .map(|p| p.last_access)
                .unwrap_or(d.created_at);
            if now - last > idle_ms {
                out.push(d.key.to_string());
            }
        }
    });
    out
}

/// Storage accounting: per (RSE) → (bytes, files) of catalog replicas.
pub fn storage_accounting(catalog: &Catalog) -> BTreeMap<String, (u64, u64)> {
    let mut acc: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    catalog.replicas.for_each(|r| {
        let e = acc.entry(r.rse.clone()).or_insert((0, 0));
        e.0 += r.bytes;
        e.1 += 1;
    });
    acc
}

/// Account usage accounting across RSEs (management report).
pub fn account_accounting(catalog: &Catalog) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    catalog.usages.for_each(|u| {
        rows.push(vec![
            u.account.clone(),
            u.rse.clone(),
            u.bytes.to_string(),
            u.files.to_string(),
        ]);
    });
    rows
}

/// VO usage accounting (multi-tenant management report): per (VO, RSE)
/// → (bytes, files) rolled up from account usage via each account's VO.
/// Rows: `[vo, rse, bytes, files]`, plus one `[vo, *, bytes, files]`
/// total row per VO.
pub fn vo_accounting(catalog: &Catalog) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for ((vo, rse), (bytes, files)) in catalog.vo_usage_by_rse() {
        rows.push(vec![vo, rse, bytes.to_string(), files.to_string()]);
    }
    for (vo, (bytes, files)) in catalog.vo_usage() {
        rows.push(vec![vo, "*".to_string(), bytes.to_string(), files.to_string()]);
    }
    rows
}

/// Weekly "suspicious and lost files" list (site-admin report).
pub fn problem_files(catalog: &Catalog) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    catalog.replicas.for_each(|r| {
        if matches!(
            r.state,
            crate::core::types::ReplicaState::Bad | crate::core::types::ReplicaState::Suspicious
        ) {
            rows.push(vec![
                r.rse.clone(),
                r.did.to_string(),
                r.state.as_str().to_string(),
            ]);
        }
    });
    rows
}

/// Metadata catalog shape: per metadata key → (distinct scope-local
/// values, total postings) out of the inverted index — what the query
/// planner's selectivity choices look like in production
/// (capacity-planning report).
pub fn metadata_key_stats(catalog: &Catalog) -> Vec<Vec<String>> {
    let mut acc: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for ((_scope, key, _value), postings) in catalog.meta_index.key_counts() {
        let e = acc.entry(key).or_insert((0, 0));
        e.0 += 1;
        e.1 += postings;
    }
    acc.into_iter()
        .map(|(key, (values, postings))| {
            vec![key, values.to_string(), postings.to_string()]
        })
        .collect()
}

/// Per-activity transfer service report (paper Fig 6 companion): for
/// every transfer activity, outcome counts, moved volume, and the mean
/// wait from request creation to its terminal state — the quantities the
/// throttler's shares trade against each other. Rows:
/// `[activity, done, failed, live, bytes_done, avg_wait_ms]`.
pub fn activity_transfer_stats(catalog: &Catalog) -> Vec<Vec<String>> {
    use crate::core::types::RequestState;
    struct Acc {
        done: u64,
        failed: u64,
        live: u64,
        bytes_done: u64,
        wait_ms_sum: i64,
    }
    let mut acc: BTreeMap<String, Acc> = BTreeMap::new();
    catalog.requests.for_each(|r| {
        let e = acc.entry(r.activity.clone()).or_insert(Acc {
            done: 0,
            failed: 0,
            live: 0,
            bytes_done: 0,
            wait_ms_sum: 0,
        });
        match r.state {
            RequestState::Done => {
                e.done += 1;
                e.bytes_done += r.bytes;
                e.wait_ms_sum += (r.updated_at - r.created_at).max(0);
            }
            RequestState::Failed => {
                e.failed += 1;
                e.wait_ms_sum += (r.updated_at - r.created_at).max(0);
            }
            _ => e.live += 1,
        }
    });
    acc.into_iter()
        .map(|(activity, a)| {
            let terminal = a.done + a.failed;
            let avg_wait = if terminal > 0 { a.wait_ms_sum / terminal as i64 } else { 0 };
            vec![
                activity,
                a.done.to_string(),
                a.failed.to_string(),
                a.live.to_string(),
                a.bytes_done.to_string(),
                avg_wait.to_string(),
            ]
        })
        .collect()
}

/// Durability report: per-table WAL shape off the registry's
/// persistence handles. Empty on non-durable catalogs. Per-table rows:
/// `[table, wal_bytes, records, records_since_ckpt, last_ckpt_seq]` —
/// all-numeric cells. One sentinel row (name `_recovery`, always last)
/// carries the boot/maintenance gauges instead:
/// `[_recovery, recovery_ms, recovered_rows, replayed_ops, checkpoints]`
/// (set by `Catalog::open_with` / `Catalog::checkpoint_all`).
pub fn wal_stats(catalog: &Catalog) -> Vec<Vec<String>> {
    let mut rows: Vec<Vec<String>> = catalog
        .registry
        .wal_stats()
        .into_iter()
        .map(|(name, s)| {
            vec![
                name,
                s.bytes.to_string(),
                s.records.to_string(),
                s.records_since_checkpoint.to_string(),
                s.last_checkpoint_seq.to_string(),
            ]
        })
        .collect();
    if !rows.is_empty() {
        rows.push(vec![
            "_recovery".to_string(),
            catalog.metrics.gauge("db.recovery_ms").to_string(),
            catalog.metrics.gauge("db.recovered_rows").to_string(),
            catalog.metrics.gauge("db.recovery_replayed_ops").to_string(),
            catalog.metrics.counter("db.checkpoints").to_string(),
        ]);
    }
    rows
}

/// Paged-mode report: per-table hot/cold shape off the registry's
/// persistence handles. Empty on non-durable catalogs. Per-table rows:
/// `[table, shards, cold_shards, hot_rows, cold_rows, budget,
/// evictions, fault_ins, disk_reads]` — all-numeric cells. With
/// `[db] memory_budget` unset every `budget` cell is `0` and the table
/// is fully resident; with it set, `hot_rows <= budget` is the RSS
/// proxy the checkpointer's eviction pass maintains.
pub fn spill_stats(catalog: &Catalog) -> Vec<Vec<String>> {
    catalog
        .registry
        .spill()
        .into_iter()
        .map(|(name, s)| {
            vec![
                name,
                s.shard_count.to_string(),
                s.cold_shards.to_string(),
                s.hot_rows.to_string(),
                s.cold_rows.to_string(),
                s.budget.to_string(),
                s.evictions.to_string(),
                s.fault_ins.to_string(),
                s.disk_reads.to_string(),
            ]
        })
        .collect()
}

/// Shard-lock contention report (paper §3.6 scaling companion): per
/// table, how write traffic hits the shard locks and — for durable
/// tables — how well WAL group commit batches it. Rows:
/// `[table, shards, single_write_locks, bulk_commits, bulk_shards_locked,
/// wal_flush_windows, wal_flushed_frames, wal_max_window_frames]` —
/// all-numeric cells; the three WAL cells are `0` for non-durable
/// tables. `flushed_frames / flush_windows` is the mean group-commit
/// batch size; `bulk_shards_locked / bulk_commits` the mean shards a
/// bulk mutation touched (an all-shard bulk path would pin this at the
/// shard count). What the `benches/abl_concurrency` ablations measure,
/// exposed as a production report.
pub fn contention_stats(catalog: &Catalog) -> Vec<Vec<String>> {
    let wal = catalog.registry.wal_stats();
    catalog
        .registry
        .contention()
        .into_iter()
        .map(|(name, c)| {
            let (fw, ff, mw) = wal
                .get(&name)
                .map(|w| (w.flush_windows, w.flushed_frames, w.max_window_frames))
                .unwrap_or((0, 0, 0));
            vec![
                name,
                c.shard_count.to_string(),
                c.single_write_locks.to_string(),
                c.bulk_commits.to_string(),
                c.bulk_shards_locked.to_string(),
                fw.to_string(),
                ff.to_string(),
                mw.to_string(),
            ]
        })
        .collect()
}

/// Dynamic-placement report (paper §6.1/§6.2): where the C3PO cache
/// rules and BB8 rebalancing moves currently sit. Per-RSE-expression
/// rows `[rse, cache_rules, cache_bytes, moves_in, moves_out]`, plus a
/// final `[_heat, rows, hot_rows, total_accesses, max_score]` sentinel
/// row describing the demand signal itself (`hot_rows` counts DIDs
/// whose decayed score is at least `hot_floor` as of `now`).
pub fn placement_stats(catalog: &Catalog, now: EpochMs, hot_floor: f64) -> Vec<Vec<String>> {
    #[derive(Default)]
    struct Acc {
        cache_rules: u64,
        cache_bytes: u64,
        moves_in: u64,
        moves_out: u64,
    }
    let mut acc: BTreeMap<String, Acc> = BTreeMap::new();
    catalog.rules.for_each(|r| {
        if r.activity != crate::placement::CACHE_ACTIVITY {
            return;
        }
        let e = acc.entry(r.rse_expression.clone()).or_default();
        e.cache_rules += 1;
        for lock_key in catalog.locks_by_rule.get(&r.id) {
            if let Some(lock) = catalog.locks.get(&lock_key) {
                e.cache_bytes += lock.bytes;
            }
        }
    });
    // moves need a second (collected) pass: the child rule lives in the
    // same table the closure above iterates
    for parent in catalog.rules.scan(|r| r.child_rule.is_some()) {
        acc.entry(parent.rse_expression.clone()).or_default().moves_out += 1;
        if let Some(child) = parent.child_rule.and_then(|id| catalog.rules.get(&id)) {
            acc.entry(child.rse_expression.clone()).or_default().moves_in += 1;
        }
    }
    let mut rows: Vec<Vec<String>> = acc
        .into_iter()
        .map(|(rse, a)| {
            vec![
                rse,
                a.cache_rules.to_string(),
                a.cache_bytes.to_string(),
                a.moves_in.to_string(),
                a.moves_out.to_string(),
            ]
        })
        .collect();
    let half_life = catalog.heat_half_life_ms();
    let (mut n, mut hot, mut accesses, mut max_score) = (0u64, 0u64, 0u64, 0.0f64);
    catalog.heat.for_each(|h| {
        n += 1;
        accesses += h.accesses;
        let s = h.score_at(now, half_life);
        if s >= hot_floor {
            hot += 1;
        }
        if s > max_score {
            max_score = s;
        }
    });
    rows.push(vec![
        "_heat".to_string(),
        n.to_string(),
        hot.to_string(),
        accesses.to_string(),
        format!("{max_score:.3}"),
    ]);
    rows
}

/// Table-size report off the monitoring registry (paper §4.6: "a probe
/// regularly checks the database" — queue depths and catalog scale).
pub fn table_sizes(catalog: &Catalog) -> Vec<Vec<String>> {
    catalog
        .registry
        .snapshot()
        .into_iter()
        .map(|(name, rows)| vec![name, rows.to_string()])
        .collect()
}

/// Default idle horizon for unused-dataset reports.
pub fn default_idle_ms() -> i64 {
    4 * WEEK_MS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escaping() {
        let doc = to_csv(
            &["a", "b"],
            &[vec!["plain".into(), "with,comma".into()], vec!["q\"uote".into(), "x".into()]],
        );
        assert!(doc.contains("\"with,comma\""));
        assert!(doc.contains("\"q\"\"uote\""));
        assert_eq!(doc.lines().count(), 3);
    }

    #[test]
    fn accounting_and_reports_on_catalog() {
        use crate::core::rse::Rse;
        use crate::core::types::{DidKey, ReplicaState};
        let c = Catalog::new_for_tests();
        c.add_scope("s", "root").unwrap();
        c.add_rse(Rse::new("A", c.now())).unwrap();
        c.add_file("s", "f1", "root", 100, "x", None).unwrap();
        c.add_file("s", "f2", "root", 50, "y", None).unwrap();
        c.add_replica("A", &DidKey::new("s", "f1"), ReplicaState::Available, None).unwrap();
        c.add_replica("A", &DidKey::new("s", "f2"), ReplicaState::Available, None).unwrap();
        c.declare_bad("A", &DidKey::new("s", "f2"), "rot", "root").unwrap();

        let acc = storage_accounting(&c);
        assert_eq!(acc["A"], (150, 2));
        assert_eq!(replicas_per_rse(&c, "A").len(), 2);
        assert_eq!(problem_files(&c).len(), 1);

        // registry-backed sizes reflect live rows
        let sizes = table_sizes(&c);
        let replicas_row = sizes.iter().find(|r| r[0] == "replicas").unwrap();
        assert_eq!(replicas_row[1], "2");

        c.add_dataset("s", "ds", "root").unwrap();
        let unused = unused_datasets(&c, c.now() + 10 * WEEK_MS, default_idle_ms());
        assert_eq!(unused, vec!["s:ds"]);
    }

    #[test]
    fn placement_stats_count_caches_moves_and_heat() {
        use crate::core::rse::Rse;
        use crate::core::rules_api::RuleSpec;
        use crate::core::types::{DidKey, ReplicaState};
        let c = Catalog::new_for_tests();
        c.add_scope("s", "root").unwrap();
        c.add_rse(Rse::new("A", c.now())).unwrap();
        c.add_rse(Rse::new("B", c.now())).unwrap();
        c.add_file("s", "f", "root", 100, "x", None).unwrap();
        let key = DidKey::new("s", "f");
        c.add_replica("A", &key, ReplicaState::Available, None).unwrap();
        let pinned = c.add_rule(RuleSpec::new("root", key.clone(), "A", 1)).unwrap();
        let cache = c
            .add_rule(
                RuleSpec::new("root", key.clone(), "B", 1)
                    .with_activity(crate::placement::CACHE_ACTIVITY),
            )
            .unwrap();
        // a live move: the pinned rule points at the cache rule as its child
        c.rules.update(&pinned, c.now(), |r| r.child_rule = Some(cache));
        c.touch_replica("A", &key);
        c.touch_replica("A", &key);

        let rows = placement_stats(&c, c.now(), 1.5);
        assert_eq!(
            rows,
            vec![
                vec!["A".to_string(), "0".into(), "0".into(), "0".into(), "1".into()],
                vec!["B".to_string(), "1".into(), "100".into(), "1".into(), "0".into()],
                vec!["_heat".to_string(), "1".into(), "1".into(), "2".into(), "2.000".into()],
            ]
        );
    }

    #[test]
    fn activity_stats_aggregate_outcomes_and_wait() {
        use crate::core::rse::Rse;
        use crate::core::rules_api::RuleSpec;
        use crate::core::types::DidKey;
        let c = Catalog::new_for_tests();
        c.add_scope("s", "root").unwrap();
        c.add_rse(Rse::new("A", c.now())).unwrap();
        for (i, act) in [(0, "Production"), (1, "Production"), (2, "Analysis")] {
            let name = format!("f{i}");
            c.add_file("s", &name, "root", 100, "x", None).unwrap();
            c.add_rule(
                RuleSpec::new("root", DidKey::new("s", &name), "A", 1).with_activity(act),
            )
            .unwrap();
        }
        // one Production done (after a 5s wait), one failed, Analysis live
        if let crate::common::clock::Clock::Sim(s) = &c.clock {
            s.advance(5_000);
        }
        let reqs = c.requests.scan(|_| true);
        let prod: Vec<_> = reqs.iter().filter(|r| r.activity == "Production").collect();
        c.on_transfer_done(prod[0].id).unwrap();
        for _ in 0..3 {
            c.on_transfer_failed(prod[1].id, "x").unwrap();
        }
        let stats = activity_transfer_stats(&c);
        let get = |a: &str| stats.iter().find(|r| r[0] == a).unwrap().clone();
        assert_eq!(get("Production")[1..4], ["1", "1", "0"].map(String::from));
        assert_eq!(get("Production")[4], "100", "bytes of the done transfer");
        assert_eq!(get("Production")[5], "5000", "avg wait in ms");
        assert_eq!(get("Analysis")[1..4], ["0", "0", "1"].map(String::from));
    }

    #[test]
    fn vo_accounting_rolls_up_by_tenant() {
        use crate::core::rse::Rse;
        use crate::core::rules_api::RuleSpec;
        use crate::core::types::{AccountType, DidKey, ReplicaState};
        let c = Catalog::new_for_tests();
        c.add_rse(Rse::new("A", c.now())).unwrap();
        c.add_account_vo("at1", AccountType::User, "", "atlas").unwrap();
        c.add_scope("s-atlas", "at1").unwrap();
        c.add_file("s-atlas", "f", "at1", 70, "x", None).unwrap();
        c.add_replica("A", &DidKey::new("s-atlas", "f"), ReplicaState::Available, None)
            .unwrap();
        c.add_rule(RuleSpec::new("at1", DidKey::new("s-atlas", "f"), "A", 1)).unwrap();
        let rows = vo_accounting(&c);
        assert!(rows.contains(&vec![
            "atlas".to_string(),
            "A".to_string(),
            "70".to_string(),
            "1".to_string()
        ]));
        assert!(rows.contains(&vec![
            "atlas".to_string(),
            "*".to_string(),
            "70".to_string(),
            "1".to_string()
        ]));
    }

    #[test]
    fn wal_stats_report_covers_durable_tables() {
        use crate::common::clock::Clock;
        use crate::common::config::Config;
        let dir = std::env::temp_dir()
            .join(format!("rucio-walreport-{}", std::process::id()));
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
        c.add_scope("s", "root").unwrap();
        c.add_file("s", "f", "root", 1, "x", None).unwrap();
        let rows = wal_stats(&c);
        assert!(rows.len() >= 20, "19 tables + recovery row: {}", rows.len());
        let dids = rows.iter().find(|r| r[0] == "dids").unwrap();
        assert!(dids[1].parse::<u64>().unwrap() > 0, "dids WAL has bytes");
        assert_eq!(rows.last().unwrap()[0], "_recovery");
        // non-durable catalog: empty report
        assert!(wal_stats(&Catalog::new_for_tests()).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_report_tracks_paged_mode_shape() {
        use crate::common::clock::Clock;
        use crate::common::config::Config;
        let dir = std::env::temp_dir()
            .join(format!("rucio-spillreport-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        cfg.set("db", "memory_budget", "3");
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
        c.add_scope("s", "root").unwrap();
        for i in 0..40 {
            c.add_file("s", &format!("f{i}"), "root", 1, "x", None).unwrap();
        }
        c.enforce_memory_budgets();
        let rows = spill_stats(&c);
        assert!(rows.len() >= 19, "one row per durable table: {}", rows.len());
        for r in &rows {
            assert_eq!(r.len(), 9);
            for cell in &r[1..] {
                cell.parse::<u64>().expect("numeric cell");
            }
        }
        let dids = rows.iter().find(|r| r[0] == "dids").unwrap();
        assert_eq!(dids[5], "3", "budget cell");
        assert!(dids[3].parse::<u64>().unwrap() <= 3, "hot rows under budget");
        assert!(dids[2].parse::<u64>().unwrap() > 0, "cold shards exist");
        // non-durable catalog: empty report
        assert!(spill_stats(&Catalog::new_for_tests()).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn contention_report_tracks_locks_and_wal_windows() {
        use crate::common::clock::Clock;
        use crate::common::config::Config;
        let dir = std::env::temp_dir()
            .join(format!("rucio-contreport-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
        c.add_scope("s", "root").unwrap();
        for i in 0..5 {
            c.add_file("s", &format!("f{i}"), "root", 1, "x", None).unwrap();
        }
        let rows = contention_stats(&c);
        assert!(rows.len() >= 19, "one row per table: {}", rows.len());
        for r in &rows {
            assert_eq!(r.len(), 8);
            for cell in &r[1..] {
                cell.parse::<u64>().expect("numeric cell");
            }
        }
        let dids = rows.iter().find(|r| r[0] == "dids").unwrap();
        assert!(dids[2].parse::<u64>().unwrap() >= 5, "5 single-row inserts");
        assert!(dids[5].parse::<u64>().unwrap() > 0, "WAL flush windows");
        assert!(dids[6].parse::<u64>().unwrap() > 0, "WAL flushed frames");

        // non-durable catalog: contention rows present, WAL cells zero
        let mem = Catalog::new_for_tests();
        mem.add_scope("s", "root").unwrap();
        mem.add_file("s", "f", "root", 1, "x", None).unwrap();
        let rows = contention_stats(&mem);
        let dids = rows.iter().find(|r| r[0] == "dids").unwrap();
        assert!(dids[2].parse::<u64>().unwrap() >= 1);
        assert_eq!(&dids[5..8], ["0", "0", "0"].map(String::from).as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metadata_key_stats_aggregates_the_inverted_index() {
        use crate::core::types::DidKey;
        let c = Catalog::new_for_tests();
        c.add_scope("s", "root").unwrap();
        for i in 0..4 {
            let name = format!("f{i}");
            c.add_file("s", &name, "root", 1, "x", None).unwrap();
            let key = DidKey::new("s", &name);
            c.set_metadata(&key, "datatype", if i < 3 { "RAW" } else { "AOD" }).unwrap();
            c.set_metadata(&key, "run", &(100 + i).to_string()).unwrap();
        }
        let stats = metadata_key_stats(&c);
        let get = |k: &str| stats.iter().find(|r| r[0] == k).unwrap().clone();
        assert_eq!(get("datatype"), vec!["datatype", "2", "4"]); // 2 values, 4 DIDs
        assert_eq!(get("run"), vec!["run", "4", "4"]); // 4 distinct runs
    }
}
