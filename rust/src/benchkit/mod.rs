//! Benchmark harness — the criterion substitute (criterion is not
//! available offline). Used by every `benches/*.rs` target with
//! `harness = false`.
//!
//! Two modes:
//! * [`bench`] — timed micro/meso benchmarks with warmup, percentiles, and
//!   throughput, printed as aligned rows;
//! * [`Table`] — free-form result tables for the paper-figure
//!   reproductions (efficiency matrices, per-month volumes, …) where the
//!   measurement is a simulation outcome rather than wall time.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    /// Operations per second implied by the mean.
    pub fn ops_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            return f64::INFINITY;
        }
        1e9 / self.mean_ns
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} it  mean {:>12}  p50 {:>12}  p95 {:>12}  p99 {:>12}  {:>14.0} op/s",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            self.ops_per_sec()
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Smoke mode (`RUCIO_BENCH_SMOKE=1`): CI runs every bench with a
/// handful of iterations so the harnesses can't silently rot, without
/// paying for full measurements. Numbers printed in smoke mode are
/// meaningless — the run only proves the bench still builds and executes.
pub fn smoke_mode() -> bool {
    std::env::var("RUCIO_BENCH_SMOKE")
        .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
        .unwrap_or(false)
}

fn effective(warmup: usize, iters: usize) -> (usize, usize) {
    if smoke_mode() {
        (warmup.min(1), iters.clamp(1, 3))
    } else {
        (warmup, iters)
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured iterations,
/// print the row, and return the stats. `f` runs once per iteration.
/// In smoke mode iterations are capped to a handful.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    let (warmup, iters) = effective(warmup, iters);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let result = summarize(name, &mut samples);
    println!("{}", result.row());
    result
}

/// Like [`bench`] but `f` receives the iteration index (for pre-generated
/// distinct inputs without timing the generation).
pub fn bench_indexed<F: FnMut(usize)>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> BenchResult {
    let (warmup, iters) = effective(warmup, iters);
    for i in 0..warmup {
        f(i);
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        f(warmup + i);
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let result = summarize(name, &mut samples);
    println!("{}", result.row());
    result
}

/// Measure one batch run of `n_ops` operations; reports per-op figures.
pub fn bench_throughput<F: FnOnce()>(name: &str, n_ops: usize, f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let total_ns = t0.elapsed().as_nanos() as f64;
    let per_op = total_ns / n_ops.max(1) as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters: n_ops,
        mean_ns: per_op,
        p50_ns: per_op,
        p95_ns: per_op,
        p99_ns: per_op,
        min_ns: per_op,
        max_ns: per_op,
    };
    println!(
        "{:<44} {:>10} ops  total {:>12}  per-op {:>12}  {:>14.0} op/s",
        name,
        n_ops,
        fmt_ns(total_ns),
        fmt_ns(per_op),
        result.ops_per_sec()
    );
    result
}

fn summarize(name: &str, samples: &mut [f64]) -> BenchResult {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    let mean = samples.iter().sum::<f64>() / n as f64;
    let pct = |p: f64| samples[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: pct(0.50),
        p95_ns: pct(0.95),
        p99_ns: pct(0.99),
        min_ns: samples.first().copied().unwrap_or(0.0),
        max_ns: samples.last().copied().unwrap_or(0.0),
    }
}

/// Section banner for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} {}", "=".repeat(70_usize.saturating_sub(title.len())));
}

/// A free-form result table (paper figures: efficiency matrix, volume
/// series, …). Prints aligned columns.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.rows.push(cells.iter().map(|c| format!("{c}")).collect());
    }

    pub fn print(&self) {
        println!("\n--- {} ---", self.title);
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for (i, cell) in cells.iter().enumerate().take(ncols) {
                line.push_str(&format!("{:>w$}  ", cell, w = widths[i]));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * ncols));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 5, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns && r.p95_ns <= r.max_ns);
        assert!(r.ops_per_sec() > 0.0);
    }

    #[test]
    fn throughput_counts_ops() {
        let r = bench_throughput("batch", 1000, || {
            std::hint::black_box((0..1000).map(|i| i * 2).sum::<u64>());
        });
        assert_eq!(r.iters, 1000);
    }

    #[test]
    fn format_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.00 ms");
        assert_eq!(fmt_ns(3e9), "3.00 s");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("efficiency", &["src", "dst", "eff"]);
        t.row(&["CA".into(), "CERN".into(), "97%".into()]);
        t.row_display(&[&"DE", &"FR", &0.56]);
        t.print();
        assert_eq!(t.rows.len(), 2);
    }
}
