//! Client API (paper §3.2): the `BaseClient`/`Client` split of the Python
//! client layer — token acquisition + caching on top of HTTP, typed
//! wrappers for the REST surface, and upload/download helpers that move
//! bytes against the storage fleet while emitting traces.

use crate::common::error::{Result, RucioError};
use crate::httpd::HttpClient;
use crate::jsonx::Json;

/// A connected, authenticated Rucio client.
pub struct RucioClient {
    http: HttpClient,
    pub account: String,
    token: String,
}

impl RucioClient {
    /// Authenticate with username/password and cache the token
    /// (the `BaseClient` behaviour of §3.2).
    pub fn connect(base_url: &str, account: &str, user: &str, password: &str) -> Result<Self> {
        let http = HttpClient::new(base_url);
        let mut req = crate::httpd::Request::new("GET", "/auth/userpass");
        req.headers.insert("x-rucio-account".into(), account.into());
        req.headers.insert("x-rucio-username".into(), user.into());
        req.headers.insert("x-rucio-password".into(), password.into());
        let resp = http.send(req)?;
        if !resp.ok() {
            return Err(RucioError::CannotAuthenticate(format!(
                "auth failed: {}",
                String::from_utf8_lossy(&resp.body)
            )));
        }
        let token = resp
            .header("x-rucio-auth-token")
            .ok_or_else(|| RucioError::CannotAuthenticate("no token in reply".into()))?;
        http.set_header("x-rucio-auth-token", token);
        let token = token.to_string();
        Ok(RucioClient { http, account: account.to_string(), token })
    }

    /// The cached auth token (for wiring raw requests in tests/tools).
    pub fn token(&self) -> &str {
        &self.token
    }

    pub fn ping(&self) -> Result<Json> {
        self.expect_json(self.http.get("/ping")?)
    }

    fn expect_ok(&self, resp: crate::httpd::Response) -> Result<()> {
        if resp.ok() {
            Ok(())
        } else {
            Err(http_error(&resp))
        }
    }

    fn expect_json(&self, resp: crate::httpd::Response) -> Result<Json> {
        if resp.ok() {
            resp.body_json()
        } else {
            Err(http_error(&resp))
        }
    }

    fn expect_ndjson(&self, resp: crate::httpd::Response) -> Result<Vec<Json>> {
        if resp.ok() {
            resp.body_ndjson()
        } else {
            Err(http_error(&resp))
        }
    }

    // -------------- scopes / dids --------------

    pub fn add_scope(&self, scope: &str, owner: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/scopes/{scope}"),
            &Json::obj().with("account", owner),
        )?)
    }

    pub fn add_file(&self, scope: &str, name: &str, bytes: u64, adler32: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/dids/{scope}/{name}"),
            &Json::obj()
                .with("type", "FILE")
                .with("bytes", bytes)
                .with("adler32", adler32),
        )?)
    }

    pub fn add_dataset(&self, scope: &str, name: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/dids/{scope}/{name}"),
            &Json::obj().with("type", "DATASET"),
        )?)
    }

    pub fn add_container(&self, scope: &str, name: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/dids/{scope}/{name}"),
            &Json::obj().with("type", "CONTAINER"),
        )?)
    }

    pub fn attach(&self, pscope: &str, pname: &str, cscope: &str, cname: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/attachments/{pscope}/{pname}"),
            &Json::obj()
                .with("child_scope", cscope)
                .with("child_name", cname),
        )?)
    }

    pub fn get_did(&self, scope: &str, name: &str) -> Result<Json> {
        self.expect_json(self.http.get(&format!("/dids/{scope}/{name}"))?)
    }

    pub fn list_dids(&self, scope: &str) -> Result<Vec<Json>> {
        self.expect_ndjson(self.http.get(&format!("/dids/{scope}"))?)
    }

    /// One page of a scope's DIDs. `cursor` is the opaque
    /// `x-rucio-next-cursor` value of the previous page (or `None` to
    /// start); returns the rows plus the next cursor (`None` = done).
    pub fn list_dids_page(
        &self,
        scope: &str,
        cursor: Option<&str>,
        limit: usize,
    ) -> Result<(Vec<Json>, Option<String>)> {
        let mut path = format!("/dids/{scope}?limit={limit}");
        if let Some(c) = cursor {
            path.push_str(&format!("&cursor={c}"));
        }
        let resp = self.http.get(&path)?;
        if !resp.ok() {
            return Err(http_error(&resp));
        }
        let next = resp.header("x-rucio-next-cursor").map(|s| s.to_string());
        Ok((resp.body_ndjson()?, next))
    }

    // -------------- metadata & discovery --------------

    /// Set metadata pairs from a JSON object: JSON types become metadata
    /// types (string/int/float/bool).
    pub fn set_metadata(&self, scope: &str, name: &str, meta: &Json) -> Result<()> {
        self.expect_ok(self.http.post_json(&format!("/meta/{scope}/{name}"), meta)?)
    }

    /// The DID's typed metadata as a JSON object.
    pub fn get_metadata(&self, scope: &str, name: &str) -> Result<Json> {
        self.expect_json(self.http.get(&format!("/meta/{scope}/{name}"))?)
    }

    /// All DIDs of a scope matching a `meta-expr` filter (walks every
    /// page; use [`RucioClient::list_dids_filter_page`] for one page).
    pub fn list_dids_filter(&self, scope: &str, filter: &str) -> Result<Vec<Json>> {
        let mut out = Vec::new();
        let mut cursor: Option<String> = None;
        loop {
            let (rows, next) =
                self.list_dids_filter_page(scope, filter, cursor.as_deref(), 1000)?;
            out.extend(rows);
            match next {
                Some(c) => cursor = Some(c),
                None => return Ok(out),
            }
        }
    }

    /// One page of a filtered DID listing. `filter` is a `meta-expr`
    /// (e.g. `datatype=RAW AND run>=358000 AND name=data18*`); `cursor`
    /// is the previous page's `x-rucio-next-cursor`.
    pub fn list_dids_filter_page(
        &self,
        scope: &str,
        filter: &str,
        cursor: Option<&str>,
        limit: usize,
    ) -> Result<(Vec<Json>, Option<String>)> {
        let mut path = format!(
            "/dids/{scope}?limit={limit}&filter={}",
            crate::httpd::percent_encode(filter)
        );
        if let Some(c) = cursor {
            path.push_str(&format!("&cursor={c}"));
        }
        let resp = self.http.get(&path)?;
        if !resp.ok() {
            return Err(http_error(&resp));
        }
        let next = resp.header("x-rucio-next-cursor").map(|s| s.to_string());
        Ok((resp.body_ndjson()?, next))
    }

    // -------------- replicas --------------

    pub fn list_replicas(&self, scope: &str, name: &str) -> Result<Vec<Json>> {
        self.expect_ndjson(self.http.get(&format!("/replicas/{scope}/{name}"))?)
    }

    pub fn register_replica(&self, rse: &str, scope: &str, name: &str, pfn: Option<&str>) -> Result<Json> {
        let mut body = Json::obj();
        if let Some(p) = pfn {
            body.set("pfn", p);
        }
        self.expect_json(self.http.post_json(&format!("/replicas/{rse}/{scope}/{name}"), &body)?)
    }

    /// Register many replicas on one RSE in a single request (the
    /// server-side batched commit). Returns the number added.
    pub fn register_replicas_bulk(&self, rse: &str, dids: &[(String, String)]) -> Result<u64> {
        let items: Vec<Json> = dids
            .iter()
            .map(|(scope, name)| {
                Json::obj().with("scope", scope.as_str()).with("name", name.as_str())
            })
            .collect();
        let body = Json::obj().with("rse", rse).with("replicas", Json::Arr(items));
        let j = self.expect_json(self.http.post_json("/replicas/bulk", &body)?)?;
        j.req_u64("added")
    }

    /// One page of the global replica list (cursor from the previous
    /// page's `x-rucio-next-cursor`, `None` to start).
    pub fn list_replicas_page(
        &self,
        cursor: Option<&str>,
        limit: usize,
    ) -> Result<(Vec<Json>, Option<String>)> {
        let mut path = format!("/replicas?limit={limit}");
        if let Some(c) = cursor {
            path.push_str(&format!("&cursor={c}"));
        }
        let resp = self.http.get(&path)?;
        if !resp.ok() {
            return Err(http_error(&resp));
        }
        let next = resp.header("x-rucio-next-cursor").map(|s| s.to_string());
        Ok((resp.body_ndjson()?, next))
    }

    // -------------- rules --------------

    pub fn add_rule(
        &self,
        scope: &str,
        name: &str,
        rse_expression: &str,
        copies: u32,
        lifetime_ms: Option<i64>,
    ) -> Result<u64> {
        let mut body = Json::obj()
            .with("scope", scope)
            .with("name", name)
            .with("rse_expression", rse_expression)
            .with("copies", copies as u64);
        if let Some(l) = lifetime_ms {
            body.set("lifetime_ms", l);
        }
        let j = self.expect_json(self.http.post_json("/rules", &body)?)?;
        j.req_u64("rule_id")
    }

    /// Create many rules in one request; each entry is
    /// `(scope, name, rse_expression, copies)`. Returns the rule ids.
    pub fn add_rules_bulk(&self, specs: &[(String, String, String, u32)]) -> Result<Vec<u64>> {
        let items: Vec<Json> = specs
            .iter()
            .map(|(scope, name, expr, copies)| {
                Json::obj()
                    .with("scope", scope.as_str())
                    .with("name", name.as_str())
                    .with("rse_expression", expr.as_str())
                    .with("copies", *copies as u64)
            })
            .collect();
        let body = Json::obj().with("rules", Json::Arr(items));
        let j = self.expect_json(self.http.post_json("/rules/bulk", &body)?)?;
        let arr = j
            .get("rule_ids")
            .and_then(Json::as_arr)
            .ok_or_else(|| RucioError::JsonError("rule_ids missing".into()))?;
        Ok(arr.iter().filter_map(Json::as_u64).collect())
    }

    pub fn get_rule(&self, rule_id: u64) -> Result<Json> {
        self.expect_json(self.http.get(&format!("/rules/{rule_id}"))?)
    }

    pub fn delete_rule(&self, rule_id: u64) -> Result<()> {
        self.expect_ok(self.http.delete(&format!("/rules/{rule_id}"))?)
    }

    pub fn list_rules(&self, scope: &str, name: &str) -> Result<Vec<Json>> {
        self.expect_ndjson(self.http.get(&format!("/dids/{scope}/{name}/rules"))?)
    }

    // -------------- admin --------------

    pub fn add_rse(&self, name: &str, tape: bool) -> Result<()> {
        self.expect_ok(
            self.http
                .post_json(&format!("/rses/{name}"), &Json::obj().with("tape", tape))?,
        )
    }

    pub fn list_rses(&self) -> Result<Vec<Json>> {
        self.expect_ndjson(self.http.get("/rses")?)
    }

    pub fn add_account(&self, name: &str, password: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            &format!("/accounts/{name}"),
            &Json::obj().with("type", "USER").with("password", password),
        )?)
    }

    pub fn usage(&self, account: &str, rse: &str) -> Result<(u64, u64)> {
        let j = self.expect_json(self.http.get(&format!("/accounts/{account}/usage/{rse}"))?)?;
        Ok((j.req_u64("bytes")?, j.req_u64("files")?))
    }

    // -------------- traces --------------

    pub fn send_trace(&self, event: &str, rse: &str, scope: &str, name: &str) -> Result<()> {
        self.expect_ok(self.http.post_json(
            "/traces",
            &Json::obj()
                .with("event", event)
                .with("rse", rse)
                .with("scope", scope)
                .with("name", name),
        )?)
    }
}

/// Rebuild a `RucioError` from an error response. Enveloped bodies
/// (`{"error": {"code", "message"}}`) round-trip the exact server-side
/// variant; anything else falls back to a status-based guess.
fn http_error(resp: &crate::httpd::Response) -> RucioError {
    if let Ok(body) = resp.body_json() {
        if let Some(env) = body.get("error") {
            if let (Some(code), Some(msg)) = (env.opt_str("code"), env.opt_str("message")) {
                return RucioError::from_code(code, msg.to_string());
            }
        }
    }
    let body = String::from_utf8_lossy(&resp.body);
    match resp.status {
        401 => RucioError::CannotAuthenticate(body.into_owned()),
        403 => RucioError::AccessDenied(body.into_owned()),
        404 => RucioError::DidNotFound(body.into_owned()),
        409 => RucioError::Duplicate(body.into_owned()),
        413 => RucioError::QuotaExceeded(body.into_owned()),
        _ => RucioError::HttpError(format!("status {}: {}", resp.status, body)),
    }
}
