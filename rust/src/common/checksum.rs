//! File checksums: Adler-32 and MD5, the two algorithms the paper mandates
//! (§2.2: "The two checksum algorithms MD5 and Adler32 are supported" and
//! are "rigidly enforced ... whenever any file is accessed or transferred").
//!
//! Both are implemented from scratch (no crates.io in this image). MD5 here
//! is a data-integrity fingerprint exactly as in WLCG tooling — not a
//! security primitive.

/// Adler-32 (RFC 1950), the default WLCG checksum.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    // Process in chunks small enough that u32 accumulators cannot overflow.
    const NMAX: usize = 5552;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(NMAX) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Adler-32 rendered as the 8-hex-digit string stored in the catalog.
pub fn adler32_hex(data: &[u8]) -> String {
    format!("{:08x}", adler32(data))
}

/// Streaming Adler-32 for storage-side verification of large writes.
#[derive(Clone, Debug)]
pub struct Adler32 {
    a: u32,
    b: u32,
}

impl Default for Adler32 {
    fn default() -> Self {
        Adler32 { a: 1, b: 0 }
    }
}

impl Adler32 {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, data: &[u8]) {
        const MOD: u32 = 65_521;
        const NMAX: usize = 5552;
        for chunk in data.chunks(NMAX) {
            for &byte in chunk {
                self.a += byte as u32;
                self.b += self.a;
            }
            self.a %= MOD;
            self.b %= MOD;
        }
    }

    pub fn finish(&self) -> u32 {
        (self.b << 16) | self.a
    }
}

/// MD5 (RFC 1321).
pub fn md5(data: &[u8]) -> [u8; 16] {
    let mut h: [u32; 4] = [0x67452301, 0xefcdab89, 0x98badcfe, 0x10325476];

    // Pre-processing: pad to 64-byte blocks with 0x80, zeros, bit length.
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_le_bytes());

    const S: [u32; 64] = [
        7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, //
        5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, //
        4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, //
        6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
    ];
    const K: [u32; 64] = [
        0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a, 0xa8304613,
        0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be, 0x6b901122, 0xfd987193,
        0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340, 0x265e5a51, 0xe9b6c7aa, 0xd62f105d,
        0x02441453, 0xd8a1e681, 0xe7d3fbc8, 0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed,
        0xa9e3e905, 0xfcefa3f8, 0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122,
        0xfde5380c, 0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
        0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665, 0xf4292244,
        0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92, 0xffeff47d, 0x85845dd1,
        0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1, 0xf7537e82, 0xbd3af235, 0x2ad7d2bb,
        0xeb86d391,
    ];

    for block in msg.chunks_exact(64) {
        let mut m = [0u32; 16];
        for (i, w) in block.chunks_exact(4).enumerate() {
            m[i] = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        }
        let (mut a, mut b, mut c, mut d) = (h[0], h[1], h[2], h[3]);
        for i in 0..64 {
            let (f, g) = match i / 16 {
                0 => ((b & c) | (!b & d), i),
                1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                2 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            b = b.wrapping_add(
                a.wrapping_add(f)
                    .wrapping_add(K[i])
                    .wrapping_add(m[g])
                    .rotate_left(S[i]),
            );
            a = tmp;
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
    }

    let mut out = [0u8; 16];
    for (i, word) in h.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_le_bytes());
    }
    out
}

/// MD5 rendered as the 32-hex-digit catalog string.
pub fn md5_hex(data: &[u8]) -> String {
    md5(data).iter().map(|b| format!("{b:02x}")).collect()
}

/// SHA-256 (FIPS 180-4); backs the auth layer's salted secret hashes.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, //
        0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
    ];
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];

    // Pre-processing: pad with 0x80, zeros, 64-bit big-endian bit length.
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());

    for block in msg.chunks_exact(64) {
        let mut w = [0u32; 64];
        for (i, word) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d) = (h[0], h[1], h[2], h[3]);
        let (mut e, mut f, mut g, mut hh) = (h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let temp1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let temp2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(temp1);
            d = c;
            c = b;
            b = a;
            a = temp1.wrapping_add(temp2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }

    let mut out = [0u8; 32];
    for (i, word) in h.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// SHA-256 rendered as 64 hex digits.
pub fn sha256_hex(data: &[u8]) -> String {
    sha256(data).iter().map(|b| format!("{b:02x}")).collect()
}

/// HMAC-SHA256 (RFC 2104) over `msg` with `key`.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    const BLOCK: usize = 64;
    let mut k = [0u8; BLOCK];
    if key.len() > BLOCK {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner: Vec<u8> = k.iter().map(|b| b ^ 0x36).collect();
    inner.extend_from_slice(msg);
    let inner_hash = sha256(&inner);
    let mut outer: Vec<u8> = k.iter().map(|b| b ^ 0x5c).collect();
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

/// HMAC-SHA256 rendered as 64 hex digits (the auth secret-hash format).
pub fn hmac_sha256_hex(key: &[u8], msg: &[u8]) -> String {
    hmac_sha256(key, msg).iter().map(|b| format!("{b:02x}")).collect()
}

/// Constant-time equality for secret material (HMAC signatures, token
/// secrets). An early-exit `==` leaks the length of the matching prefix
/// through timing; this XOR-accumulates over every byte so comparison
/// time depends only on the input lengths. Length mismatch still returns
/// early — lengths of hex digests are public.
pub fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b.iter()) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_time_eq_matches_plain_eq() {
        assert!(constant_time_eq(b"", b""));
        assert!(constant_time_eq(b"abcdef", b"abcdef"));
        assert!(!constant_time_eq(b"abcdef", b"abcdeg"));
        assert!(!constant_time_eq(b"abcdef", b"Xbcdef"));
        assert!(!constant_time_eq(b"short", b"longer"));
        let h1 = hmac_sha256_hex(b"k", b"m");
        let h2 = hmac_sha256_hex(b"k", b"m");
        let h3 = hmac_sha256_hex(b"k", b"n");
        assert!(constant_time_eq(h1.as_bytes(), h2.as_bytes()));
        assert!(!constant_time_eq(h1.as_bytes(), h3.as_bytes()));
    }

    // RFC 1321 appendix A.5 test suite.
    #[test]
    fn md5_rfc_vectors() {
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"a"), "0cc175b9c0f1b6a831c399e269772661");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(md5_hex(b"message digest"), "f96b697d7cb7938d525a2f31aaf161d0");
        assert_eq!(
            md5_hex(b"abcdefghijklmnopqrstuvwxyz"),
            "c3fcd3d76192e4007dfb496cca67e13b"
        );
        assert_eq!(
            md5_hex(b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789"),
            "d174ab98d277d9f5a5611c2c9f419d9f"
        );
        assert_eq!(
            md5_hex(
                b"12345678901234567890123456789012345678901234567890123456789012345678901234567890"
            ),
            "57edf4a22be3c955ac49da2e2107b67a"
        );
    }

    // FIPS 180-4 / NIST example vectors.
    #[test]
    fn sha256_nist_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    // RFC 4231-style vector.
    #[test]
    fn hmac_sha256_known_vector() {
        assert_eq!(
            hmac_sha256_hex(b"key", b"The quick brown fox jumps over the lazy dog"),
            "f7bc83f430538424b13298e6aa6fb143ef4d59a14946175997479dbc2d1a3cd8"
        );
    }

    #[test]
    fn adler32_known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
        assert_eq!(adler32_hex(b"Wikipedia"), "11e60398");
    }

    #[test]
    fn adler32_streaming_matches_oneshot() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let mut s = Adler32::new();
        for chunk in data.chunks(977) {
            s.update(chunk);
        }
        assert_eq!(s.finish(), adler32(&data));
    }

    #[test]
    fn md5_long_input_padding_edges() {
        // lengths around the 56-byte padding boundary
        for len in [55usize, 56, 57, 63, 64, 65, 119, 120] {
            let data = vec![0x61u8; len];
            let hex = md5_hex(&data);
            assert_eq!(hex.len(), 32);
        }
        // one specific cross-check: 64 'a's
        assert_eq!(md5_hex(&vec![b'a'; 64]), "014842d480b571495a4a0363793f7367");
    }
}
