//! File checksums: Adler-32 and MD5, the two algorithms the paper mandates
//! (§2.2: "The two checksum algorithms MD5 and Adler32 are supported" and
//! are "rigidly enforced ... whenever any file is accessed or transferred").
//!
//! Both are implemented from scratch (no crates.io in this image). MD5 here
//! is a data-integrity fingerprint exactly as in WLCG tooling — not a
//! security primitive.

/// Adler-32 (RFC 1950), the default WLCG checksum.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    // Process in chunks small enough that u32 accumulators cannot overflow.
    const NMAX: usize = 5552;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(NMAX) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Adler-32 rendered as the 8-hex-digit string stored in the catalog.
pub fn adler32_hex(data: &[u8]) -> String {
    format!("{:08x}", adler32(data))
}

/// Streaming Adler-32 for storage-side verification of large writes.
#[derive(Clone, Debug)]
pub struct Adler32 {
    a: u32,
    b: u32,
}

impl Default for Adler32 {
    fn default() -> Self {
        Adler32 { a: 1, b: 0 }
    }
}

impl Adler32 {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, data: &[u8]) {
        const MOD: u32 = 65_521;
        const NMAX: usize = 5552;
        for chunk in data.chunks(NMAX) {
            for &byte in chunk {
                self.a += byte as u32;
                self.b += self.a;
            }
            self.a %= MOD;
            self.b %= MOD;
        }
    }

    pub fn finish(&self) -> u32 {
        (self.b << 16) | self.a
    }
}

/// MD5 (RFC 1321).
pub fn md5(data: &[u8]) -> [u8; 16] {
    let mut h: [u32; 4] = [0x67452301, 0xefcdab89, 0x98badcfe, 0x10325476];

    // Pre-processing: pad to 64-byte blocks with 0x80, zeros, bit length.
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_le_bytes());

    const S: [u32; 64] = [
        7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, //
        5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, //
        4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, //
        6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
    ];
    const K: [u32; 64] = [
        0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a, 0xa8304613,
        0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be, 0x6b901122, 0xfd987193,
        0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340, 0x265e5a51, 0xe9b6c7aa, 0xd62f105d,
        0x02441453, 0xd8a1e681, 0xe7d3fbc8, 0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed,
        0xa9e3e905, 0xfcefa3f8, 0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122,
        0xfde5380c, 0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
        0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665, 0xf4292244,
        0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92, 0xffeff47d, 0x85845dd1,
        0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1, 0xf7537e82, 0xbd3af235, 0x2ad7d2bb,
        0xeb86d391,
    ];

    for block in msg.chunks_exact(64) {
        let mut m = [0u32; 16];
        for (i, w) in block.chunks_exact(4).enumerate() {
            m[i] = u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        }
        let (mut a, mut b, mut c, mut d) = (h[0], h[1], h[2], h[3]);
        for i in 0..64 {
            let (f, g) = match i / 16 {
                0 => ((b & c) | (!b & d), i),
                1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                2 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            b = b.wrapping_add(
                a.wrapping_add(f)
                    .wrapping_add(K[i])
                    .wrapping_add(m[g])
                    .rotate_left(S[i]),
            );
            a = tmp;
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
    }

    let mut out = [0u8; 16];
    for (i, word) in h.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_le_bytes());
    }
    out
}

/// MD5 rendered as the 32-hex-digit catalog string.
pub fn md5_hex(data: &[u8]) -> String {
    md5(data).iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // RFC 1321 appendix A.5 test suite.
    #[test]
    fn md5_rfc_vectors() {
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"a"), "0cc175b9c0f1b6a831c399e269772661");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(md5_hex(b"message digest"), "f96b697d7cb7938d525a2f31aaf161d0");
        assert_eq!(
            md5_hex(b"abcdefghijklmnopqrstuvwxyz"),
            "c3fcd3d76192e4007dfb496cca67e13b"
        );
        assert_eq!(
            md5_hex(b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789"),
            "d174ab98d277d9f5a5611c2c9f419d9f"
        );
        assert_eq!(
            md5_hex(
                b"12345678901234567890123456789012345678901234567890123456789012345678901234567890"
            ),
            "57edf4a22be3c955ac49da2e2107b67a"
        );
    }

    #[test]
    fn adler32_known_vectors() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
        assert_eq!(adler32_hex(b"Wikipedia"), "11e60398");
    }

    #[test]
    fn adler32_streaming_matches_oneshot() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let mut s = Adler32::new();
        for chunk in data.chunks(977) {
            s.update(chunk);
        }
        assert_eq!(s.finish(), adler32(&data));
    }

    #[test]
    fn md5_long_input_padding_edges() {
        // lengths around the 56-byte padding boundary
        for len in [55usize, 56, 57, 63, 64, 65, 119, 120] {
            let data = vec![0x61u8; len];
            let hex = md5_hex(&data);
            assert_eq!(hex.len(), 32);
        }
        // one specific cross-check: 64 'a's
        assert_eq!(md5_hex(&vec![b'a'; 64]), "014842d480b571495a4a0363793f7367");
    }
}
