//! Wall-clock abstraction: real time for production mode, virtual time for
//! the discrete-event simulation driver (DESIGN.md §1 `sim/driver`).
//!
//! All timestamps in the system are milliseconds since the UNIX epoch
//! (`i64`), matching the granularity Rucio cares about (second-level
//! lifetimes, hour-level grace periods).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Milliseconds since the UNIX epoch.
pub type EpochMs = i64;

pub const SECOND_MS: i64 = 1_000;
pub const MINUTE_MS: i64 = 60 * SECOND_MS;
pub const HOUR_MS: i64 = 60 * MINUTE_MS;
pub const DAY_MS: i64 = 24 * HOUR_MS;
pub const WEEK_MS: i64 = 7 * DAY_MS;
/// 30-day month used by the workload calendar.
pub const MONTH_MS: i64 = 30 * DAY_MS;

/// A clock every component reads time through. Cheap to clone.
#[derive(Clone)]
pub enum Clock {
    /// Real wall-clock time.
    Real,
    /// Simulated time, advanced explicitly by the discrete-event driver.
    Sim(SimClock),
}

impl Clock {
    pub fn real() -> Self {
        Clock::Real
    }

    pub fn sim_at(start: EpochMs) -> Self {
        Clock::Sim(SimClock::new(start))
    }

    /// Current time in epoch milliseconds.
    pub fn now_ms(&self) -> EpochMs {
        match self {
            Clock::Real => SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as i64)
                .unwrap_or(0),
            Clock::Sim(s) => s.now_ms(),
        }
    }

    /// True when this is a simulated clock (daemons then never sleep for
    /// real; the driver advances time instead).
    pub fn is_sim(&self) -> bool {
        matches!(self, Clock::Sim(_))
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::Real
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Clock::Real => write!(f, "Clock::Real"),
            Clock::Sim(s) => write!(f, "Clock::Sim({})", s.now_ms()),
        }
    }
}

/// Shared simulated clock. The driver owns advancement; everything else
/// only reads.
#[derive(Clone)]
pub struct SimClock {
    now: Arc<AtomicI64>,
}

impl SimClock {
    pub fn new(start: EpochMs) -> Self {
        SimClock { now: Arc::new(AtomicI64::new(start)) }
    }

    pub fn now_ms(&self) -> EpochMs {
        self.now.load(Ordering::Acquire)
    }

    /// Advance by `delta_ms`; returns the new now.
    pub fn advance(&self, delta_ms: i64) -> EpochMs {
        debug_assert!(delta_ms >= 0, "simulated time cannot go backwards");
        self.now.fetch_add(delta_ms, Ordering::AcqRel) + delta_ms
    }

    /// Jump to an absolute time (must not go backwards).
    pub fn set(&self, t: EpochMs) {
        let prev = self.now.swap(t, Ordering::AcqRel);
        debug_assert!(t >= prev, "simulated time cannot go backwards");
    }
}

/// Render an epoch-ms timestamp as a compact UTC-ish string for logs and
/// reports. Purely arithmetic (no tz database): `YYYY-MM-DD HH:MM:SS`.
pub fn format_ts(ms: EpochMs) -> String {
    // Civil-from-days algorithm (Howard Hinnant).
    let secs = ms.div_euclid(1000);
    let days = secs.div_euclid(86_400);
    let rem = secs.rem_euclid(86_400);
    let (h, m, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let mo = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if mo <= 2 { y + 1 } else { y };
    format!("{y:04}-{mo:02}-{d:02} {h:02}:{m:02}:{s:02}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let c = Clock::sim_at(1_000);
        assert_eq!(c.now_ms(), 1_000);
        if let Clock::Sim(s) = &c {
            assert_eq!(s.advance(500), 1_500);
        }
        assert_eq!(c.now_ms(), 1_500);
        assert!(c.is_sim());
    }

    #[test]
    fn real_clock_is_monotonic_enough() {
        let c = Clock::real();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
        assert!(a > 1_600_000_000_000); // after 2020
    }

    #[test]
    fn clones_share_state() {
        let s = SimClock::new(0);
        let s2 = s.clone();
        s.advance(42);
        assert_eq!(s2.now_ms(), 42);
    }

    #[test]
    fn format_known_timestamps() {
        assert_eq!(format_ts(0), "1970-01-01 00:00:00");
        // 2018-11-01 00:00:00 UTC = 1541030400
        assert_eq!(format_ts(1_541_030_400_000), "2018-11-01 00:00:00");
        // leap-year day: 2016-02-29 12:00:00 = 1456747200
        assert_eq!(format_ts(1_456_747_200_000), "2016-02-29 12:00:00");
    }
}
