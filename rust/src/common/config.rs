//! Configuration system: an INI-style format (sections, `key = value`),
//! mirroring upstream Rucio's `rucio.cfg`. Values support strings, ints,
//! floats, bools, byte sizes, and durations. Overlay semantics let a
//! scenario file override the defaults, and components read through typed
//! accessors with defaults.

use std::collections::BTreeMap;

use crate::common::clock;
use crate::common::error::{Result, RucioError};
use crate::common::units;

/// Parsed configuration: `section -> key -> raw string value`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse INI text. `#` and `;` start comments; whitespace is trimmed;
    /// later duplicate keys win (overlay-friendly).
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::from("default");
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(RucioError::ConfigError(format!(
                        "line {}: malformed section header: {raw}",
                        lineno + 1
                    )));
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(RucioError::ConfigError(format!(
                    "line {}: expected key = value: {raw}",
                    lineno + 1
                )));
            };
            let key = line[..eq].trim().to_string();
            let value = line[eq + 1..].trim().to_string();
            if key.is_empty() {
                return Err(RucioError::ConfigError(format!("line {}: empty key", lineno + 1)));
            }
            cfg.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| RucioError::ConfigError(format!("{path}: {e}")))?;
        Self::parse(&text)
    }

    /// Overlay `other` on top of `self` (other wins).
    pub fn merge(&mut self, other: &Config) {
        for (sec, kv) in &other.sections {
            let dst = self.sections.entry(sec.clone()).or_default();
            for (k, v) in kv {
                dst.insert(k.clone(), v.clone());
            }
        }
    }

    pub fn set(&mut self, section: &str, key: &str, value: impl Into<String>) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.into());
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn get_i64(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key).map(|s| s.to_ascii_lowercase()) {
            Some(v) => matches!(v.as_str(), "1" | "true" | "yes" | "on"),
            None => default,
        }
    }

    /// Byte sizes: `catalog.max_volume = 500GB`.
    pub fn get_bytes(&self, section: &str, key: &str, default: u64) -> u64 {
        self.get(section, key).and_then(units::parse_bytes).unwrap_or(default)
    }

    /// Durations in ms: accepts `500ms`, `30s`, `5m`, `2h`, `7d`, `1w`.
    pub fn get_duration_ms(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(parse_duration_ms).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = (&String, &BTreeMap<String, String>)> {
        self.sections.iter()
    }

    pub fn section(&self, name: &str) -> Option<&BTreeMap<String, String>> {
        self.sections.get(name)
    }

    /// Serialize back to INI text (stable order for golden tests).
    pub fn to_ini(&self) -> String {
        let mut out = String::new();
        for (sec, kv) in &self.sections {
            out.push_str(&format!("[{sec}]\n"));
            for (k, v) in kv {
                out.push_str(&format!("{k} = {v}\n"));
            }
            out.push('\n');
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find(|c| c == '#' || c == ';') {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Parse `"30s"`-style durations into milliseconds.
pub fn parse_duration_ms(s: &str) -> Option<i64> {
    let s = s.trim();
    let split = s.find(|c: char| c.is_ascii_alphabetic()).unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let value: f64 = num.trim().parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "ms" => 1,
        "" | "s" => clock::SECOND_MS,
        "m" | "min" => clock::MINUTE_MS,
        "h" => clock::HOUR_MS,
        "d" => clock::DAY_MS,
        "w" => clock::WEEK_MS,
        _ => return None,
    };
    Some((value * mult as f64).round() as i64)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# rucio.cfg style
[common]
instance = atlas-sim
debug = true

[conveyor]
bulk = 500           ; batch size
poll_interval = 30s
max_volume = 1.5TB
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_str("common", "instance", ""), "atlas-sim");
        assert!(c.get_bool("common", "debug", false));
        assert_eq!(c.get_i64("conveyor", "bulk", 0), 500);
        assert_eq!(c.get_duration_ms("conveyor", "poll_interval", 0), 30_000);
        assert_eq!(c.get_bytes("conveyor", "max_volume", 0), 1_500_000_000_000);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_str("nope", "k", "dflt"), "dflt");
        assert_eq!(c.get_i64("nope", "k", 9), 9);
        assert!(!c.get_bool("nope", "k", false));
    }

    #[test]
    fn merge_overlays() {
        let mut base = Config::parse("[a]\nx = 1\ny = 2\n").unwrap();
        let over = Config::parse("[a]\nx = 10\n[b]\nz = 3\n").unwrap();
        base.merge(&over);
        assert_eq!(base.get_i64("a", "x", 0), 10);
        assert_eq!(base.get_i64("a", "y", 0), 2);
        assert_eq!(base.get_i64("b", "z", 0), 3);
    }

    #[test]
    fn malformed_lines_error() {
        assert!(Config::parse("[broken").is_err());
        assert!(Config::parse("justtext").is_err());
        assert!(Config::parse("= value").is_err());
    }

    #[test]
    fn ini_round_trip() {
        let c = Config::parse(SAMPLE).unwrap();
        let again = Config::parse(&c.to_ini()).unwrap();
        assert_eq!(c, again);
    }

    #[test]
    fn duration_forms() {
        assert_eq!(parse_duration_ms("500ms"), Some(500));
        assert_eq!(parse_duration_ms("2h"), Some(7_200_000));
        assert_eq!(parse_duration_ms("1w"), Some(604_800_000));
        assert_eq!(parse_duration_ms("1.5s"), Some(1500));
        assert_eq!(parse_duration_ms("xyz"), None);
    }
}
