//! Crate-wide error type.
//!
//! Mirrors the exception taxonomy of the upstream Python Rucio
//! (`rucio.common.exception`): a client can distinguish "does not exist",
//! "already exists", "denied", "quota exceeded", etc. — the REST layer maps
//! these onto HTTP status codes.

/// Convenience result alias used across the crate.
pub type Result<T> = std::result::Result<T, RucioError>;

/// Declares the error enum plus its `Display` in one place (offline stand-in
/// for the `thiserror` derive: every variant carries one detail string).
macro_rules! rucio_error {
    ($( $(#[$meta:meta])* $variant:ident => $prefix:literal ),+ $(,)?) => {
        /// The crate-wide error enum.
        #[derive(Debug, Clone, PartialEq, Eq)]
        pub enum RucioError {
            $( $(#[$meta])* $variant(String), )+
        }

        impl std::fmt::Display for RucioError {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                match self {
                    $( RucioError::$variant(msg) => write!(f, concat!($prefix, "{}"), msg), )+
                }
            }
        }

        impl RucioError {
            /// Stable machine-readable error code: the variant name,
            /// mirroring upstream Rucio's exception-class strings. The
            /// REST error envelope carries it as `error.code`.
            pub fn code(&self) -> &'static str {
                match self {
                    $( RucioError::$variant(_) => stringify!($variant), )+
                }
            }

            /// Rebuild an error from its wire code (the envelope's
            /// `error.code`): the client regains the exact variant the
            /// server raised. Unknown codes become `HttpError`.
            pub fn from_code(code: &str, message: String) -> RucioError {
                match code {
                    $( stringify!($variant) => RucioError::$variant(message), )+
                    _ => RucioError::HttpError(message),
                }
            }
        }
    };
}

rucio_error! {
    DidNotFound => "DID not found: ",
    DidAlreadyExists => "DID already exists: ",
    UnsupportedOperation => "unsupported operation: ",
    ScopeNotFound => "scope not found: ",
    AccountNotFound => "account not found: ",
    RseNotFound => "RSE not found: ",
    RuleNotFound => "rule not found: ",
    ReplicaNotFound => "replica not found: ",
    RequestNotFound => "transfer request not found: ",
    SubscriptionNotFound => "subscription not found: ",
    Duplicate => "duplicate: ",
    AccessDenied => "access denied: ",
    CannotAuthenticate => "authentication failed: ",
    QuotaExceeded => "quota exceeded: ",
    InvalidRseExpression => "invalid RSE expression: ",
    InvalidMetaExpression => "invalid metadata filter expression: ",
    RseExpressionEmpty => "RSE expression resolved to empty set: ",
    InvalidObject => "invalid name: ",
    InvalidValue => "invalid value: ",
    ChecksumMismatch => "checksum mismatch: ",
    SourceNotFound => "file on storage not found: ",
    NoSpaceLeft => "no space left on RSE: ",
    StorageError => "storage error: ",
    TransferToolError => "transfer tool error: ",
    DatabaseError => "database error: ",
    TxnConflict => "transaction conflict: ",
    ConfigError => "config error: ",
    JsonError => "json error: ",
    HttpError => "http error: ",
    RouteNotFound => "no such route: ",
    MethodNotAllowed => "method not allowed: ",
    RuntimeError => "runtime (PJRT) error: ",
    Io => "io error: ",
    Internal => "internal error: ",
}

impl std::error::Error for RucioError {}

impl From<std::io::Error> for RucioError {
    fn from(e: std::io::Error) -> Self {
        RucioError::Io(e.to_string())
    }
}

impl RucioError {
    /// HTTP status code for the REST layer (paper §3.3).
    pub fn http_status(&self) -> u16 {
        use RucioError::*;
        match self {
            DidNotFound(_) | ScopeNotFound(_) | AccountNotFound(_) | RseNotFound(_)
            | RuleNotFound(_) | ReplicaNotFound(_) | RequestNotFound(_)
            | SubscriptionNotFound(_) | SourceNotFound(_) | RouteNotFound(_) => 404,
            MethodNotAllowed(_) => 405,
            DidAlreadyExists(_) | Duplicate(_) | TxnConflict(_) => 409,
            AccessDenied(_) => 403,
            CannotAuthenticate(_) => 401,
            QuotaExceeded(_) | NoSpaceLeft(_) => 413,
            InvalidRseExpression(_) | InvalidMetaExpression(_) | RseExpressionEmpty(_)
            | InvalidObject(_) | InvalidValue(_) | JsonError(_) | UnsupportedOperation(_) => 400,
            ChecksumMismatch(_) => 422,
            _ => 500,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_codes_map() {
        assert_eq!(RucioError::DidNotFound("x".into()).http_status(), 404);
        assert_eq!(RucioError::AccessDenied("x".into()).http_status(), 403);
        assert_eq!(RucioError::CannotAuthenticate("x".into()).http_status(), 401);
        assert_eq!(RucioError::Duplicate("x".into()).http_status(), 409);
        assert_eq!(RucioError::InvalidValue("x".into()).http_status(), 400);
        assert_eq!(RucioError::Internal("x".into()).http_status(), 500);
    }

    #[test]
    fn display_prefixes_detail() {
        assert_eq!(
            RucioError::DidNotFound("data18:f1".into()).to_string(),
            "DID not found: data18:f1"
        );
        assert_eq!(RucioError::QuotaExceeded("alice".into()).to_string(), "quota exceeded: alice");
    }

    #[test]
    fn codes_are_variant_names() {
        assert_eq!(RucioError::DidNotFound("x".into()).code(), "DidNotFound");
        assert_eq!(RucioError::AccessDenied("x".into()).code(), "AccessDenied");
        assert_eq!(RucioError::Internal("x".into()).code(), "Internal");
    }

    #[test]
    fn codes_round_trip_through_from_code() {
        let variants = [
            RucioError::DidNotFound("x".into()),
            RucioError::QuotaExceeded("x".into()),
            RucioError::MethodNotAllowed("x".into()),
        ];
        for e in variants {
            let back = RucioError::from_code(e.code(), "x".into());
            assert_eq!(back, e);
            assert_eq!(back.http_status(), e.http_status());
        }
        assert!(matches!(
            RucioError::from_code("NoSuchCode", "x".into()),
            RucioError::HttpError(_)
        ));
    }

    #[test]
    fn io_error_converts() {
        let e: RucioError = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(e, RucioError::Io(_)));
    }
}
