//! Crate-wide error type.
//!
//! Mirrors the exception taxonomy of the upstream Python Rucio
//! (`rucio.common.exception`): a client can distinguish "does not exist",
//! "already exists", "denied", "quota exceeded", etc. — the REST layer maps
//! these onto HTTP status codes.

use thiserror::Error;

/// Convenience result alias used across the crate.
pub type Result<T> = std::result::Result<T, RucioError>;

/// The crate-wide error enum.
#[derive(Error, Debug, Clone, PartialEq, Eq)]
pub enum RucioError {
    #[error("DID not found: {0}")]
    DidNotFound(String),
    #[error("DID already exists: {0}")]
    DidAlreadyExists(String),
    #[error("unsupported operation: {0}")]
    UnsupportedOperation(String),
    #[error("scope not found: {0}")]
    ScopeNotFound(String),
    #[error("account not found: {0}")]
    AccountNotFound(String),
    #[error("RSE not found: {0}")]
    RseNotFound(String),
    #[error("rule not found: {0}")]
    RuleNotFound(String),
    #[error("replica not found: {0}")]
    ReplicaNotFound(String),
    #[error("subscription not found: {0}")]
    SubscriptionNotFound(String),
    #[error("duplicate: {0}")]
    Duplicate(String),
    #[error("access denied: {0}")]
    AccessDenied(String),
    #[error("authentication failed: {0}")]
    CannotAuthenticate(String),
    #[error("quota exceeded: {0}")]
    QuotaExceeded(String),
    #[error("invalid RSE expression: {0}")]
    InvalidRseExpression(String),
    #[error("RSE expression resolved to empty set: {0}")]
    RseExpressionEmpty(String),
    #[error("invalid name: {0}")]
    InvalidObject(String),
    #[error("invalid value: {0}")]
    InvalidValue(String),
    #[error("checksum mismatch: {0}")]
    ChecksumMismatch(String),
    #[error("file on storage not found: {0}")]
    SourceNotFound(String),
    #[error("no space left on RSE: {0}")]
    NoSpaceLeft(String),
    #[error("storage error: {0}")]
    StorageError(String),
    #[error("transfer tool error: {0}")]
    TransferToolError(String),
    #[error("database error: {0}")]
    DatabaseError(String),
    #[error("transaction conflict: {0}")]
    TxnConflict(String),
    #[error("config error: {0}")]
    ConfigError(String),
    #[error("json error: {0}")]
    JsonError(String),
    #[error("http error: {0}")]
    HttpError(String),
    #[error("runtime (PJRT) error: {0}")]
    RuntimeError(String),
    #[error("io error: {0}")]
    Io(String),
    #[error("internal error: {0}")]
    Internal(String),
}

impl From<std::io::Error> for RucioError {
    fn from(e: std::io::Error) -> Self {
        RucioError::Io(e.to_string())
    }
}

impl RucioError {
    /// HTTP status code for the REST layer (paper §3.3).
    pub fn http_status(&self) -> u16 {
        use RucioError::*;
        match self {
            DidNotFound(_) | ScopeNotFound(_) | AccountNotFound(_) | RseNotFound(_)
            | RuleNotFound(_) | ReplicaNotFound(_) | SubscriptionNotFound(_)
            | SourceNotFound(_) => 404,
            DidAlreadyExists(_) | Duplicate(_) | TxnConflict(_) => 409,
            AccessDenied(_) => 403,
            CannotAuthenticate(_) => 401,
            QuotaExceeded(_) | NoSpaceLeft(_) => 413,
            InvalidRseExpression(_) | RseExpressionEmpty(_) | InvalidObject(_)
            | InvalidValue(_) | JsonError(_) | UnsupportedOperation(_) => 400,
            ChecksumMismatch(_) => 422,
            _ => 500,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_codes_map() {
        assert_eq!(RucioError::DidNotFound("x".into()).http_status(), 404);
        assert_eq!(RucioError::AccessDenied("x".into()).http_status(), 403);
        assert_eq!(RucioError::CannotAuthenticate("x".into()).http_status(), 401);
        assert_eq!(RucioError::Duplicate("x".into()).http_status(), 409);
        assert_eq!(RucioError::InvalidValue("x".into()).http_status(), 400);
        assert_eq!(RucioError::Internal("x".into()).http_status(), 500);
    }

    #[test]
    fn io_error_converts() {
        let e: RucioError = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(e, RucioError::Io(_)));
    }
}
