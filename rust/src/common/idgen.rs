//! Process-wide monotonic id generation.
//!
//! Rucio's catalog rows (rules, requests, locks, messages, …) carry UUIDs in
//! the upstream schema. We use compact `u64`s: dense, ordered, and cheap to
//! index — plus a uuid-ish hex rendering for externally visible tokens.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing id source. One per [`crate::db::Db`]; also
/// usable standalone in tests.
#[derive(Debug)]
pub struct IdGen {
    next: AtomicU64,
}

impl Default for IdGen {
    fn default() -> Self {
        IdGen { next: AtomicU64::new(1) }
    }
}

impl IdGen {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn starting_at(n: u64) -> Self {
        IdGen { next: AtomicU64::new(n) }
    }

    /// Allocate the next id (never 0; 0 is reserved as "none").
    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }

    /// Current high-water mark (next id to be returned).
    pub fn peek(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    /// Raise the high-water mark to at least `n` (never lowers it). The
    /// catalog recovery path uses this so ids allocated before a crash
    /// are never re-issued after it.
    pub fn bump_to(&self, n: u64) {
        self.next.fetch_max(n, Ordering::Relaxed);
    }
}

/// Render an id as a 32-hex-char token body (uuid-like, no dashes), mixing
/// in a salt so externally visible ids do not leak row counts.
pub fn hex_token(id: u64, salt: u64) -> String {
    let a = id.wrapping_mul(0x9E3779B97F4A7C15) ^ salt;
    let b = id ^ salt.rotate_left(17).wrapping_mul(0xBF58476D1CE4E5B9);
    format!("{a:016x}{b:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_monotonic_and_nonzero() {
        let g = IdGen::new();
        let a = g.next();
        let b = g.next();
        assert!(a >= 1);
        assert!(b > a);
    }

    #[test]
    fn hex_token_shape_and_distinctness() {
        let t1 = hex_token(1, 42);
        let t2 = hex_token(2, 42);
        assert_eq!(t1.len(), 32);
        assert_ne!(t1, t2);
        assert!(t1.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn bump_to_only_raises() {
        let g = IdGen::new();
        g.bump_to(100);
        assert_eq!(g.peek(), 100);
        g.bump_to(50);
        assert_eq!(g.peek(), 100, "bump never lowers the mark");
        assert_eq!(g.next(), 100);
    }

    #[test]
    fn concurrent_allocation_unique() {
        use std::sync::Arc;
        let g = Arc::new(IdGen::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| g.next()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n);
    }
}
