//! Minimal `log` facade backend: leveled, timestamped stderr logging with a
//! per-module-path filter, standing in for the td-agent → Elasticsearch
//! pipeline of paper §4.6 (the structured *metric* side lives in
//! [`crate::analytics::metrics`]).

use std::sync::atomic::{AtomicBool, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static INSTALLED: AtomicBool = AtomicBool::new(false);
static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let now = crate::common::clock::Clock::Real.now_ms();
        eprintln!(
            "{} {} [{}] {}",
            crate::common::clock::format_ts(now),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent). `verbosity`: 0=warn, 1=info, 2=debug, 3+=trace.
pub fn init(verbosity: u8) {
    let filter = match verbosity {
        0 => LevelFilter::Warn,
        1 => LevelFilter::Info,
        2 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    };
    if INSTALLED
        .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok()
    {
        let _ = log::set_logger(&LOGGER);
    }
    log::set_max_level(filter);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_sets_level() {
        init(1);
        assert_eq!(log::max_level(), LevelFilter::Info);
        init(2);
        assert_eq!(log::max_level(), LevelFilter::Debug);
        log::info!("logger smoke test");
    }
}
