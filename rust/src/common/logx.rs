//! Minimal leveled logger: timestamped stderr logging with a global level
//! filter, standing in for the td-agent → Elasticsearch pipeline of paper
//! §4.6 (the structured *metric* side lives in
//! [`crate::analytics::metrics`]). Self-contained — the `log` facade crate
//! is unavailable offline — with [`crate::log_warn!`]-style macros for
//! call sites.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Install/adjust the logger (idempotent). `verbosity`: 0=warn, 1=info,
/// 2=debug, 3+=trace.
pub fn init(verbosity: u8) {
    let level = match verbosity {
        0 => Level::Warn,
        1 => Level::Info,
        2 => Level::Debug,
        _ => Level::Trace,
    };
    MAX_LEVEL.store(level as u8, Ordering::SeqCst);
}

/// The currently enabled maximum level.
pub fn max_level() -> Level {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        4 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::SeqCst)
}

/// Emit one record (macro back-end; prefer the `log_*!` macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = crate::common::clock::Clock::Real.now_ms();
    eprintln!(
        "{} {} [{}] {}",
        crate::common::clock::format_ts(now),
        level.tag(),
        target,
        args
    );
}

/// `log_error!("..{}", x)` — error-level record tagged with the module path.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::common::logx::log(
            $crate::common::logx::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_warn!("..{}", x)` — warn-level record tagged with the module path.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::common::logx::log(
            $crate::common::logx::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_info!("..{}", x)` — info-level record tagged with the module path.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::common::logx::log(
            $crate::common::logx::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_debug!("..{}", x)` — debug-level record tagged with the module path.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::common::logx::log(
            $crate::common::logx::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_sets_level() {
        init(1);
        assert_eq!(max_level(), Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        init(2);
        assert_eq!(max_level(), Level::Debug);
        crate::log_info!("logger smoke test");
    }
}
