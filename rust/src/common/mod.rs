//! Cross-cutting plumbing: errors, clocks, PRNG, checksums, config, units,
//! logging, id generation, and a tiny property-testing harness.
//!
//! Everything here is dependency-free (std only) because the build image has
//! no network access to crates.io; see DESIGN.md §1.

pub mod checksum;
pub mod clock;
pub mod config;
pub mod error;
pub mod idgen;
pub mod logx;
pub mod prng;
pub mod proptest;
pub mod regex;
pub mod units;

pub use clock::{Clock, SimClock};
pub use error::{Result, RucioError};
pub use prng::Prng;
