//! Deterministic PRNG + the distributions the workload generator needs.
//!
//! crates.io is unavailable in this build image, so instead of `rand` we
//! carry a small, well-known generator: splitmix64 for seeding and PCG32
//! (XSH-RR) for the stream. Everything in the simulator draws from this so
//! whole scenario runs are reproducible from a single seed.

/// PCG32 (XSH-RR 64/32) with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Prng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut p = Prng { state: 0, inc: init_inc };
        p.state = init_state.wrapping_add(init_inc);
        p.next_u32();
        p
    }

    /// Derive an independent child stream (for per-component determinism).
    pub fn fork(&mut self, tag: u64) -> Prng {
        Prng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (Lemire-style, unbiased enough for sim).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        let span = hi - lo;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.range_u64(0, (hi - lo) as u64) as i64
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Exponential with mean `mean`.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0,1]
        -mean * u.ln()
    }

    /// Log-normal-ish positive value with median `median` and shape `sigma`
    /// (Box–Muller under the hood). Used for file sizes.
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        let n = self.normal(0.0, 1.0);
        median * (sigma * n).exp()
    }

    /// Normal via Box–Muller.
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let mag = (-2.0 * u1.ln()).sqrt();
        mu + sigma * mag * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (popularity skew
    /// of user analysis; paper §6.1). Rejection-free inverse-CDF over a
    /// precomputed table would be faster, but n is small in our sweeps.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Approximate inverse CDF via the continuous Zipf (bounded Pareto).
        if (s - 1.0).abs() < 1e-9 {
            let u = self.f64();
            let hn = (n as f64).ln();
            return ((u * hn).exp() - 1.0).floor().min((n - 1) as f64) as usize;
        }
        let u = self.f64();
        let t = ((n as f64).powf(1.0 - s) - 1.0) * u + 1.0;
        let x = t.powf(1.0 / (1.0 - s)); // bounded Pareto on [1, n]
        ((x.floor() as usize).saturating_sub(1)).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index choice; `weights` must be non-negative, not all zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(3);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut p = Prng::new(4);
        for _ in 0..10_000 {
            let x = p.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn range_covers_values() {
        let mut p = Prng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[p.range_usize(0, 10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn exp_mean_close() {
        let mut p = Prng::new(6);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| p.exp(5.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn normal_moments_close() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
        assert!((var - 9.0).abs() < 0.5, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut p = Prng::new(8);
        let n = 100;
        let mut counts = vec![0usize; n];
        for _ in 0..50_000 {
            counts[p.zipf(n, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[n - 1] * 5);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut p = Prng::new(9);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[p.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut p = Prng::new(11);
        let mut a = p.fork(1);
        let mut b = p.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
