//! A tiny property-based testing harness (crates.io `proptest` is not
//! available offline). Provides seeded case generation with automatic
//! counterexample reporting and a bounded shrink pass for integer-vector
//! inputs.
//!
//! Usage (`ignore`: doctest binaries cannot load libstdc++ under the
//! image's nix loader; the same code runs as a unit test below):
//! ```ignore
//! use rucio::common::proptest::{forall, Gen};
//! forall(200, |g: &mut Gen| {
//!     let xs = g.vec_u64(0, 100, 0..20);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::common::prng::Prng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Prng,
    /// Trace of drawn values, for reproduction messages.
    pub case_index: usize,
}

impl Gen {
    fn new(seed: u64, case_index: usize) -> Self {
        Gen { rng: Prng::new(seed), case_index }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Lowercase alphanumeric identifier, Rucio-name-like.
    pub fn ident(&mut self, len: Range<usize>) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        let n = self.usize(len.start.max(1), len.end.max(2));
        (0..n).map(|_| CHARS[self.usize(0, CHARS.len())] as char).collect()
    }

    /// Arbitrary printable string (includes spaces and punctuation, to shake
    /// out parser bugs).
    pub fn string(&mut self, len: Range<usize>) -> String {
        let n = self.usize(len.start, len.end.max(1));
        (0..n)
            .map(|_| {
                let c = self.usize(0x20, 0x7f) as u8 as char;
                c
            })
            .collect()
    }

    pub fn vec_u64(&mut self, lo: u64, hi: u64, len: Range<usize>) -> Vec<u64> {
        let n = self.usize(len.start, len.end.max(1));
        (0..n).map(|_| self.u64(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        self.rng.shuffle(xs)
    }
}

/// Run `prop` against `cases` generated inputs. Panics (failing the test)
/// with the seed + case index of the first counterexample. Honors
/// `RUCIO_PROPTEST_SEED` for reproduction.
pub fn forall<F: FnMut(&mut Gen)>(cases: usize, mut prop: F) {
    let base_seed = std::env::var("RUCIO_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDA7A_u64);
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed, i);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i}/{cases} (RUCIO_PROPTEST_SEED={base_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(50, |g| {
            let x = g.u64(0, 1000);
            assert!(x < 1000);
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(100, |g| {
            let x = g.u64(0, 100);
            assert!(x < 90, "x={x} too big");
        });
    }

    #[test]
    fn ident_is_wellformed() {
        forall(100, |g| {
            let s = g.ident(1..12);
            assert!(!s.is_empty() && s.len() < 12);
            assert!(s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()));
        });
    }
}
