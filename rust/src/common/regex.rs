//! A small regular-expression engine (offline stand-in for the `regex`
//! crate, which is unavailable in this environment — same approach as the
//! in-repo `thiserror`/`sha2` substitutes).
//!
//! Supports the subset the catalog actually uses — naming-schema
//! validation patterns and glob-derived matchers:
//! anchors `^`/`$`, `.`, postfix `*`/`+`/`?`, character classes
//! `[a-z0-9]` (with ranges and leading-`^` negation), alternation groups
//! `(a|b)`, `\`-escapes (including `\d`/`\w`/`\s`), and literals.
//! `{m,n}` repetition is *not* implemented and is rejected at compile
//! time (never silently matched as a literal). Matching is unanchored
//! unless the pattern anchors itself, like the real crate's `is_match`.

use std::fmt;

/// Pattern compilation error (position + message).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex error: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// One step of a compiled pattern.
#[derive(Debug, Clone)]
enum Node {
    /// Literal character.
    Char(char),
    /// `.` — any single character.
    Any,
    /// Character class: (negated, ranges). Single chars are (c, c) ranges.
    Class(bool, Vec<(char, char)>),
    /// Alternation group `(a|b|...)`: each branch is a sub-sequence.
    Group(Vec<Vec<Node>>),
    /// Zero or more of the inner node.
    Star(Box<Node>),
    /// One or more of the inner node.
    Plus(Box<Node>),
    /// Zero or one of the inner node.
    Opt(Box<Node>),
    /// `^` / `$` anchors.
    Start,
    End,
}

/// A compiled regular expression.
#[derive(Debug, Clone)]
pub struct Regex {
    nodes: Vec<Node>,
}

struct Parser<'a> {
    chars: std::iter::Peekable<std::str::Chars<'a>>,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn next(&mut self) -> Option<char> {
        self.pos += 1;
        self.chars.next()
    }

    fn peek(&mut self) -> Option<char> {
        self.chars.peek().copied()
    }

    fn err(&self, msg: &str) -> Error {
        Error(format!("{msg} at {}", self.pos))
    }

    /// Parse a `|`-separated sequence list until `)` or end of input.
    fn alternation(&mut self, in_group: bool) -> Result<Vec<Vec<Node>>, Error> {
        let mut branches = vec![Vec::new()];
        loop {
            match self.peek() {
                None => {
                    if in_group {
                        return Err(self.err("unclosed group"));
                    }
                    return Ok(branches);
                }
                Some(')') if in_group => {
                    self.next();
                    return Ok(branches);
                }
                Some(')') => return Err(self.err("unmatched ')'")),
                Some('|') => {
                    self.next();
                    branches.push(Vec::new());
                }
                Some(_) => {
                    let node = self.atom()?;
                    let node = self.postfix(node)?;
                    branches.last_mut().expect("one branch always open").push(node);
                }
            }
        }
    }

    fn atom(&mut self) -> Result<Node, Error> {
        match self.next() {
            Some('(') => Ok(Node::Group(self.alternation(true)?)),
            Some('[') => self.class(),
            Some('.') => Ok(Node::Any),
            Some('^') => Ok(Node::Start),
            Some('$') => Ok(Node::End),
            Some('\\') => self.escape(),
            Some(c @ ('*' | '+' | '?')) => Err(self.err(&format!("dangling '{c}'"))),
            // `{m,n}` repetition is not implemented — erroring beats
            // silently matching a literal brace (the `regex` crate this
            // stands in for would repeat); escape `\{` for a literal.
            Some(c @ ('{' | '}')) => Err(self.err(&format!(
                "unsupported repetition syntax '{c}' (escape literal braces)"
            ))),
            Some(c) => Ok(Node::Char(c)),
            None => Err(self.err("unexpected end of pattern")),
        }
    }

    fn escape(&mut self) -> Result<Node, Error> {
        match self.next() {
            Some('d') => Ok(Node::Class(false, vec![('0', '9')])),
            Some('w') => Ok(Node::Class(
                false,
                vec![('a', 'z'), ('A', 'Z'), ('0', '9'), ('_', '_')],
            )),
            Some('s') => Ok(Node::Class(
                false,
                vec![(' ', ' '), ('\t', '\t'), ('\n', '\n'), ('\r', '\r')],
            )),
            Some('n') => Ok(Node::Char('\n')),
            Some('t') => Ok(Node::Char('\t')),
            Some(c) => Ok(Node::Char(c)), // \. \\ \( \[ \* ... literal
            None => Err(self.err("trailing backslash")),
        }
    }

    fn class(&mut self) -> Result<Node, Error> {
        let negated = if self.peek() == Some('^') {
            self.next();
            true
        } else {
            false
        };
        let mut ranges = Vec::new();
        loop {
            let c = match self.next() {
                None => return Err(self.err("unclosed character class")),
                Some(']') => break, // empty classes allowed: match nothing
                Some('\\') => match self.next() {
                    Some(e) => e,
                    None => return Err(self.err("trailing backslash in class")),
                },
                Some(c) => c,
            };
            // range `a-z` (a trailing '-' is a literal)
            if self.peek() == Some('-') {
                self.next();
                match self.peek() {
                    Some(']') | None => {
                        ranges.push((c, c));
                        ranges.push(('-', '-'));
                    }
                    Some(hi) => {
                        self.next();
                        if hi < c {
                            return Err(self.err("inverted class range"));
                        }
                        ranges.push((c, hi));
                    }
                }
            } else {
                ranges.push((c, c));
            }
        }
        Ok(Node::Class(negated, ranges))
    }

    fn postfix(&mut self, node: Node) -> Result<Node, Error> {
        let node = match self.peek() {
            Some('*') => {
                self.next();
                Node::Star(Box::new(node))
            }
            Some('+') => {
                self.next();
                Node::Plus(Box::new(node))
            }
            Some('?') => {
                self.next();
                Node::Opt(Box::new(node))
            }
            _ => return Ok(node),
        };
        if matches!(self.peek(), Some('*' | '+' | '?')) {
            return Err(self.err("nested quantifier"));
        }
        if matches!(&node, Node::Star(i) | Node::Plus(i) | Node::Opt(i)
            if matches!(**i, Node::Start | Node::End))
        {
            return Err(self.err("quantified anchor"));
        }
        Ok(node)
    }
}

impl Regex {
    /// Compile a pattern. Errors mirror the real crate: malformed input
    /// returns `Err`, it never panics.
    pub fn new(pattern: &str) -> Result<Regex, Error> {
        let mut p = Parser { chars: pattern.chars().peekable(), pos: 0 };
        let branches = p.alternation(false)?;
        let nodes = if branches.len() == 1 {
            branches.into_iter().next().expect("one branch")
        } else {
            vec![Node::Group(branches)]
        };
        Ok(Regex { nodes })
    }

    /// Does the pattern match anywhere in `text`? (Use `^`/`$` anchors for
    /// whole-string matching, as all in-repo patterns do.)
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        // Unanchored: try every start offset. A leading `^` fails all
        // offsets except 0 via the Start node itself.
        for start in 0..=chars.len() {
            if match_seq(&self.nodes, 0, &chars, start, &|_pos| true) {
                return true;
            }
            if matches!(self.nodes.first(), Some(Node::Start)) {
                break; // ^-anchored: offset 0 was the only candidate
            }
        }
        false
    }
}

fn class_matches(negated: bool, ranges: &[(char, char)], c: char) -> bool {
    let inside = ranges.iter().any(|&(lo, hi)| lo <= c && c <= hi);
    inside != negated
}

/// Backtracking matcher: does `nodes[ni..]` match `text` starting at
/// `pos`, with `cont` accepting the final position? Pattern depth bounds
/// recursion (patterns are short config strings).
fn match_seq(
    nodes: &[Node],
    ni: usize,
    text: &[char],
    pos: usize,
    cont: &dyn Fn(usize) -> bool,
) -> bool {
    let Some(node) = nodes.get(ni) else {
        return cont(pos);
    };
    let rest = |p: usize| match_seq(nodes, ni + 1, text, p, cont);
    match node {
        Node::Char(c) => text.get(pos) == Some(c) && rest(pos + 1),
        Node::Any => pos < text.len() && rest(pos + 1),
        Node::Class(neg, ranges) => {
            matches!(text.get(pos), Some(&c) if class_matches(*neg, ranges, c)) && rest(pos + 1)
        }
        Node::Start => pos == 0 && rest(pos),
        Node::End => pos == text.len() && rest(pos),
        Node::Group(branches) => branches
            .iter()
            .any(|b| match_seq(b, 0, text, pos, &rest)),
        Node::Opt(inner) => match_one(inner, text, pos, &rest) || rest(pos),
        Node::Star(inner) => match_repeat(inner, text, pos, 0, &rest),
        Node::Plus(inner) => {
            match_one(inner, text, pos, &|p| match_repeat(inner, text, p, 0, &rest))
        }
    }
}

/// Match exactly one occurrence of `node`, then continue.
fn match_one(node: &Node, text: &[char], pos: usize, cont: &dyn Fn(usize) -> bool) -> bool {
    match_seq(std::slice::from_ref(node), 0, text, pos, cont)
}

/// Greedy `*`: consume as many repetitions as possible, backtracking one
/// at a time. `depth` bounds pathological patterns like `(a*)*`.
fn match_repeat(
    node: &Node,
    text: &[char],
    pos: usize,
    depth: usize,
    cont: &dyn Fn(usize) -> bool,
) -> bool {
    if depth <= text.len()
        && match_one(node, text, pos, &|p| {
            // zero-width inner match would loop forever — force progress
            p > pos && match_repeat(node, text, p, depth + 1, cont)
        })
    {
        return true;
    }
    cont(pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pat: &str, text: &str) -> bool {
        Regex::new(pat).unwrap().is_match(text)
    }

    #[test]
    fn literals_and_anchors() {
        assert!(m("abc", "xxabcxx")); // unanchored
        assert!(m("^abc$", "abc"));
        assert!(!m("^abc$", "xabc"));
        assert!(!m("^abc$", "abcx"));
        assert!(m("^$", ""));
        assert!(!m("^$", "a"));
    }

    #[test]
    fn naming_schema_pattern() {
        // the pattern used by the naming-schema config test
        let re = Regex::new("^(raw|aod)\\.[0-9]+$").unwrap();
        assert!(re.is_match("raw.001"));
        assert!(re.is_match("aod.123456"));
        assert!(!re.is_match("freeform"));
        assert!(!re.is_match("raw."));
        assert!(!re.is_match("raw.001x"));
        assert!(!re.is_match("xraw.001"));
    }

    #[test]
    fn glob_derived_patterns() {
        // what glob_to_regex produces: ^raw\..*$ / ^.*\.0001$
        assert!(m("^raw\\..*$", "raw.0002"));
        assert!(!m("^raw\\..*$", "aod.0002"));
        assert!(m("^.*\\.0001$", "raw.0001"));
        assert!(m("^f\\..$", "f.1"));
        assert!(m("^a\\{x\\}$", "a{x}"), "escaped braces are literal");
    }

    #[test]
    fn quantifiers() {
        assert!(m("^a*$", ""));
        assert!(m("^a*$", "aaaa"));
        assert!(m("^a+$", "aaa"));
        assert!(!m("^a+$", ""));
        assert!(m("^ab?c$", "ac"));
        assert!(m("^ab?c$", "abc"));
        assert!(!m("^ab?c$", "abbc"));
        assert!(m("^(ab)+$", "ababab"));
        assert!(!m("^(ab)+$", "ababa"));
    }

    #[test]
    fn classes() {
        assert!(m("^[a-z0-9]+$", "run358031"));
        assert!(!m("^[a-z]+$", "Run"));
        assert!(m("^[^0-9]+$", "abc-def"));
        assert!(!m("^[^0-9]+$", "ab1"));
        assert!(m("^\\d+$", "12345"));
        assert!(m("^\\w+$", "data18_13TeV"));
        assert!(m("^a[-.]b$", "a-b") && m("^a[-.]b$", "a.b"));
    }

    #[test]
    fn alternation_backtracks() {
        assert!(m("^(a|ab)c$", "abc"));
        assert!(m("^(ab|a)bc$", "abc"));
        assert!(m("^x(1|2|3)*y$", "x123321y"));
    }

    #[test]
    fn star_backtracks_into_suffix() {
        assert!(m("^.*\\.log$", "a.b.c.log"));
        assert!(!m("^.*\\.log$", "a.b.c.txt"));
        assert!(m("^a.*a$", "aba"));
        assert!(m("^a.*a$", "aa"));
    }

    #[test]
    fn malformed_patterns_error() {
        for bad in [
            "(abc", "abc)", "[abc", "*a", "+", "a**", "a\\", "[z-a]", "^*",
            // unsupported repetition syntax must error, not match literally
            "a{2}", "[0-9]{6}", "a{2,3}", "x}",
        ] {
            assert!(Regex::new(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn pathological_star_terminates() {
        // zero-width repetition guard: must terminate, not hang
        assert!(m("^(a*)*$", "aaaa"));
        assert!(!m("^(a*)*b$", "aaac"));
    }
}
