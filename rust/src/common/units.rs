//! Byte-size helpers. The paper speaks in PB/month and TB catalogs; all
//! internal accounting is plain `u64` bytes — these helpers only parse and
//! format for configs, reports, and benches.

pub const KB: u64 = 1_000;
pub const MB: u64 = 1_000_000;
pub const GB: u64 = 1_000_000_000;
pub const TB: u64 = 1_000_000_000_000;
pub const PB: u64 = 1_000_000_000_000_000;

/// Human-readable size with two decimals: `1.50 TB`.
pub fn fmt_bytes(n: u64) -> String {
    let f = n as f64;
    if n >= PB {
        format!("{:.2} PB", f / PB as f64)
    } else if n >= TB {
        format!("{:.2} TB", f / TB as f64)
    } else if n >= GB {
        format!("{:.2} GB", f / GB as f64)
    } else if n >= MB {
        format!("{:.2} MB", f / MB as f64)
    } else if n >= KB {
        format!("{:.2} KB", f / KB as f64)
    } else {
        format!("{n} B")
    }
}

/// Parse `"500GB"`, `"1.5 TB"`, `"42"` (bytes). Decimal units (10^x), as in
/// storage-vendor and WLCG pledge accounting.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| c.is_ascii_alphabetic()).unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let value: f64 = num.trim().parse().ok()?;
    if value < 0.0 {
        return None;
    }
    let mult = match unit.trim().to_ascii_uppercase().as_str() {
        "" | "B" => 1,
        "KB" | "K" => KB,
        "MB" | "M" => MB,
        "GB" | "G" => GB,
        "TB" | "T" => TB,
        "PB" | "P" => PB,
        _ => return None,
    };
    Some((value * mult as f64).round() as u64)
}

/// Throughput formatter for reports: bytes over a millisecond window.
pub fn fmt_rate(bytes: u64, elapsed_ms: i64) -> String {
    if elapsed_ms <= 0 {
        return "-".into();
    }
    let bps = bytes as f64 * 1000.0 / elapsed_ms as f64;
    format!("{}/s", fmt_bytes(bps as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_round_trips_scales() {
        assert_eq!(fmt_bytes(999), "999 B");
        assert_eq!(fmt_bytes(1_500), "1.50 KB");
        assert_eq!(fmt_bytes(2 * GB), "2.00 GB");
        assert_eq!(fmt_bytes(450 * PB), "450.00 PB");
    }

    #[test]
    fn parse_accepts_common_forms() {
        assert_eq!(parse_bytes("42"), Some(42));
        assert_eq!(parse_bytes("500GB"), Some(500 * GB));
        assert_eq!(parse_bytes("1.5 TB"), Some(1_500_000_000_000));
        assert_eq!(parse_bytes("2 pb"), Some(2 * PB));
        assert_eq!(parse_bytes("10K"), Some(10_000));
        assert_eq!(parse_bytes("bogus"), None);
        assert_eq!(parse_bytes("-5GB"), None);
    }

    #[test]
    fn rate_formats() {
        assert_eq!(fmt_rate(1_000_000, 1000), "1.00 MB/s");
        assert_eq!(fmt_rate(123, 0), "-");
    }
}
