//! Accounts, identities, authentication, tokens, and the permission
//! policy (paper §2.3 + §4.1), extended with multi-VO tenancy (the
//! ESCAPE data-lake deployment model: one catalog, many communities).
//!
//! # Multi-VO model
//!
//! Every account, scope, and token carries a `vo` (virtual
//! organisation). Scopes inherit the VO of their owning account, tokens
//! pin the VO of the account at issue time, and the permission layer
//! rejects any scope-targeted action that crosses a VO boundary. Admins
//! are VO-scoped: an `admin` account administers only its own VO, except
//! admins of the default VO ([`DEFAULT_VO`]) who operate the whole
//! instance (the `root` super-admin). A VO can be switched off with
//! config `[vo] active.<name> = false`; token issue *and* every
//! validation re-check it, so deactivation revokes an entire community
//! at once.
//!
//! Fair shares nest per-VO: the throttler runs a two-level deficit
//! round-robin per network link — the outer level splits link slots
//! across VOs by `[throttler] vo_share.<vo>` weights, the inner level
//! splits each VO's allocation across activities by
//! `[throttler] share.<activity>`. A small VO with a large share weight
//! is therefore protected from a large VO's backlog no matter which
//! activities either runs.
//!
//! # Auth hot path
//!
//! Logins resolve identities through the `(identity, auth_type)`
//! secondary index (or a primary-key point get when the account is
//! already known) — never a table scan — and secret comparisons
//! (SSH signatures, token equality) use constant-time equality.

use crate::common::checksum::{constant_time_eq, hmac_sha256_hex};
use crate::common::clock::HOUR_MS;
use crate::common::error::{Result, RucioError};
use crate::common::idgen::hex_token;

use super::types::*;
use super::Catalog;

/// Operations gated by the permission policy (paper §4.1: "each
/// client-facing operation ... is validated through a permission
/// function").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    AddScope,
    AddDid,
    AttachDid,
    DetachDid,
    SetMetadata,
    AddRule,
    DeleteRule,
    AddRse,
    AdminRse,
    AddAccount,
    SetQuota,
    DeclareBadReplica,
    AddSubscription,
    GetUsage,
}

impl Catalog {
    // ------------------------------------------------------------------
    // accounts
    // ------------------------------------------------------------------

    /// Create an account in the default VO (single-tenant deployments).
    pub fn add_account(&self, name: &str, account_type: AccountType, email: &str) -> Result<()> {
        self.add_account_vo(name, account_type, email, DEFAULT_VO)
    }

    /// Create an account inside a VO; the home scope inherits the VO.
    pub fn add_account_vo(
        &self,
        name: &str,
        account_type: AccountType,
        email: &str,
        vo: &str,
    ) -> Result<()> {
        validate_name(name, 25)?;
        validate_name(vo, 25)?;
        let now = self.now();
        self.accounts.insert(
            Account {
                name: name.to_string(),
                account_type,
                email: email.to_string(),
                created_at: now,
                suspended: false,
                admin: false,
                vo: vo.to_string(),
            },
            now,
        )?;
        // §2.3: "each account has an associated scope", like a home dir.
        let scope_name = match account_type {
            AccountType::User => format!("user.{name}"),
            AccountType::Group => format!("group.{name}"),
            AccountType::Service => name.to_string(),
        };
        let _ = self.scopes.insert(
            Scope {
                name: scope_name,
                account: name.to_string(),
                created_at: now,
                vo: vo.to_string(),
            },
            now,
        );
        self.metrics.incr("accounts.added", 1);
        Ok(())
    }

    /// Is a VO accepting logins? Config `[vo] active.<name> = false`
    /// deactivates a whole community (checked at issue *and* validation).
    pub fn vo_active(&self, vo: &str) -> bool {
        self.cfg.get_bool("vo", &format!("active.{vo}"), true)
    }

    /// VO of an account (the tenant every action is attributed to).
    pub fn account_vo(&self, account: &str) -> Result<String> {
        Ok(self.get_account(account)?.vo)
    }

    pub fn get_account(&self, name: &str) -> Result<Account> {
        self.accounts
            .get(&name.to_string())
            .ok_or_else(|| RucioError::AccountNotFound(name.to_string()))
    }

    pub fn set_admin(&self, name: &str, admin: bool) -> Result<()> {
        self.get_account(name)?;
        self.accounts.update(&name.to_string(), self.now(), |a| a.admin = admin);
        Ok(())
    }

    pub fn suspend_account(&self, name: &str) -> Result<()> {
        self.get_account(name)?;
        self.accounts.update(&name.to_string(), self.now(), |a| a.suspended = true);
        Ok(())
    }

    // ------------------------------------------------------------------
    // identities (paper Fig 2: many-to-many identity ↔ account)
    // ------------------------------------------------------------------

    /// Map an identity to an account. For `UserPass` the secret is the
    /// password (stored salted+hashed); for `Ssh` it is the public key.
    pub fn add_identity(
        &self,
        identity: &str,
        auth_type: AuthType,
        account: &str,
        secret: Option<&str>,
    ) -> Result<()> {
        self.get_account(account)?;
        let stored_secret = match (auth_type, secret) {
            (AuthType::UserPass, Some(pw)) => Some(self.hash_secret(identity, pw)),
            (_, s) => s.map(|x| x.to_string()),
        };
        self.identities.insert(
            Identity {
                identity: identity.to_string(),
                auth_type,
                account: account.to_string(),
                secret: stored_secret,
            },
            self.now(),
        )?;
        Ok(())
    }

    /// Unmap an identity from an account (index maintenance mirrors
    /// [`Catalog::add_identity`]).
    pub fn remove_identity(
        &self,
        identity: &str,
        auth_type: AuthType,
        account: &str,
    ) -> Result<()> {
        self.identities
            .remove(
                &(identity.to_string(), auth_type, account.to_string()),
                self.now(),
            )
            .ok_or_else(|| {
                RucioError::InvalidObject(format!("no {} identity {identity} for {account}", auth_type.as_str()))
            })?;
        Ok(())
    }

    /// Accounts an identity may act as — an `(identity, auth_type)`
    /// index probe; the primary key's third component is the account.
    pub fn identity_accounts(&self, identity: &str, auth_type: AuthType) -> Vec<String> {
        self.identities_by_key
            .get(&(identity.to_string(), auth_type))
            .into_iter()
            .map(|(_, _, account)| account)
            .collect()
    }

    /// Point lookup of one identity row on the login path: the primary
    /// key is `(identity, auth_type, account)`, so when the account is
    /// named by the client this is a single O(log n) get — no scan.
    fn identity_for(&self, identity: &str, auth_type: AuthType, account: &str) -> Option<Identity> {
        self.identities.get(&(identity.to_string(), auth_type, account.to_string()))
    }

    fn hash_secret(&self, identity: &str, secret: &str) -> String {
        hmac_sha256_hex(format!("salt:{identity}").as_bytes(), secret.as_bytes())
    }

    // ------------------------------------------------------------------
    // authentication → tokens (paper §4.1)
    // ------------------------------------------------------------------

    /// Username/password authentication (native implementation, §4.1).
    pub fn auth_userpass(&self, account: &str, username: &str, password: &str) -> Result<Token> {
        let Some(id) = self.identity_for(username, AuthType::UserPass, account) else {
            return Err(RucioError::CannotAuthenticate(format!(
                "no userpass identity {username} for account {account}"
            )));
        };
        let supplied = self.hash_secret(username, password);
        let stored = id.secret.as_deref().unwrap_or("");
        if !constant_time_eq(stored.as_bytes(), supplied.as_bytes()) {
            return Err(RucioError::CannotAuthenticate("wrong credentials".into()));
        }
        self.issue_token(account)
    }

    /// X.509 DN authentication (GridSite stand-in: the DN string is the
    /// identity; transport-level verification is assumed).
    pub fn auth_x509(&self, account: &str, dn: &str) -> Result<Token> {
        self.auth_by_identity(account, dn, AuthType::X509)
    }

    /// GSSAPI/Kerberos principal authentication (ModAuthKerb stand-in).
    pub fn auth_gss(&self, account: &str, principal: &str) -> Result<Token> {
        self.auth_by_identity(account, principal, AuthType::Gss)
    }

    /// SSH public-key authentication: the client signs a server challenge;
    /// here the "signature" is an HMAC with the registered key material
    /// (cryptographic transport is out of scope for the simulation).
    pub fn auth_ssh(&self, account: &str, key_id: &str, signature: &str) -> Result<Token> {
        let Some(id) = self.identity_for(key_id, AuthType::Ssh, account) else {
            return Err(RucioError::CannotAuthenticate(format!("unknown ssh key {key_id}")));
        };
        let expected = self.hash_secret(key_id, id.secret.as_deref().unwrap_or(""));
        if !constant_time_eq(signature.as_bytes(), expected.as_bytes()) {
            return Err(RucioError::CannotAuthenticate("bad ssh signature".into()));
        }
        self.issue_token(account)
    }

    /// The challenge an SSH client must answer (see [`Catalog::auth_ssh`]).
    pub fn ssh_challenge(&self, key_id: &str, pubkey: &str) -> String {
        self.hash_secret(key_id, pubkey)
    }

    fn auth_by_identity(&self, account: &str, identity: &str, t: AuthType) -> Result<Token> {
        // `(identity, auth_type)` index probe instead of a table scan:
        // the candidate set is every account this identity maps to, and
        // the primary key carries the account name.
        let can_act = self
            .identities_by_key
            .get(&(identity.to_string(), t))
            .iter()
            .any(|(_, _, a)| a == account);
        if !can_act {
            return Err(RucioError::CannotAuthenticate(format!(
                "identity {identity} cannot act as {account}"
            )));
        }
        self.issue_token(account)
    }

    fn issue_token(&self, account: &str) -> Result<Token> {
        let acc = self.get_account(account)?;
        if acc.suspended {
            return Err(RucioError::CannotAuthenticate(format!("account {account} suspended")));
        }
        if !self.vo_active(&acc.vo) {
            return Err(RucioError::CannotAuthenticate(format!("VO {} inactive", acc.vo)));
        }
        let now = self.now();
        let lifetime = self.cfg.get_duration_ms("auth", "token_lifetime", HOUR_MS);
        let token = Token {
            token: format!("{}-{}", account, hex_token(self.next_id(), self.token_salt)),
            account: account.to_string(),
            expires_at: now + lifetime,
            issued_at: now,
            vo: acc.vo,
        };
        self.tokens.insert(token.clone(), now)?;
        self.metrics.incr("auth.tokens_issued", 1);
        Ok(token)
    }

    /// Validate an `X-Rucio-Auth-Token`; returns the account.
    ///
    /// Every validation — not only issue — re-checks account suspension
    /// and VO active status, so suspending an account (or deactivating a
    /// VO) revokes its outstanding tokens immediately instead of leaving
    /// them live until expiry.
    pub fn validate_token(&self, token: &str) -> Result<String> {
        self.validate_token_vo(token).map(|(account, _vo)| account)
    }

    /// [`Catalog::validate_token`] returning `(account, vo)` — the REST
    /// layer needs the VO on every request for tenant isolation.
    pub fn validate_token_vo(&self, token: &str) -> Result<(String, String)> {
        let t = self
            .tokens
            .get(&token.to_string())
            .ok_or_else(|| RucioError::CannotAuthenticate("unknown token".into()))?;
        // defense in depth: the final equality on the secret is constant
        // time even though the point get already matched on the key
        if !constant_time_eq(t.token.as_bytes(), token.as_bytes()) {
            return Err(RucioError::CannotAuthenticate("unknown token".into()));
        }
        if t.expires_at < self.now() {
            return Err(RucioError::CannotAuthenticate("token expired".into()));
        }
        let acc = self.get_account(&t.account)?;
        if acc.suspended {
            return Err(RucioError::CannotAuthenticate(format!(
                "account {} suspended",
                t.account
            )));
        }
        if !self.vo_active(&acc.vo) {
            return Err(RucioError::CannotAuthenticate(format!("VO {} inactive", acc.vo)));
        }
        Ok((t.account, acc.vo))
    }

    /// Drop expired tokens (housekeeping daemon path): non-cloning key
    /// projection, then one batched removal.
    pub fn purge_expired_tokens(&self) -> usize {
        let now = self.now();
        let expired: Vec<String> = self
            .tokens
            .filter_map(|t| (t.expires_at < now).then(|| t.token.clone()));
        self.tokens.remove_bulk(&expired, now).len()
    }

    // ------------------------------------------------------------------
    // permission policy (paper §4.1, §2.3)
    // ------------------------------------------------------------------

    /// The default policy: admins may do anything; regular accounts get
    /// read everywhere, write into their own scopes, and rule management
    /// on their own rules. "These access permissions can be
    /// programmatically specified" — instances customize by overriding
    /// config keys `permissions.<action> = admin|any`.
    pub fn check_permission(&self, account: &str, action: Action, scope: Option<&str>) -> Result<()> {
        let acc = self.get_account(account)?;
        // Tenant isolation precedes everything, including the admin
        // bypass: a scope-targeted action must stay inside the caller's
        // VO. Only default-VO admins (the instance operators) cross.
        if let Some(s) = scope {
            if let Some(sc) = self.scopes.get(&s.to_string()) {
                if sc.vo != acc.vo && !(acc.admin && acc.vo == DEFAULT_VO) {
                    return Err(RucioError::AccessDenied(format!(
                        "{account} (VO {}) may not {action:?} on scope {s} (VO {})",
                        acc.vo, sc.vo
                    )));
                }
            }
        }
        if acc.admin {
            return Ok(());
        }
        let action_key = format!("{action:?}").to_lowercase();
        match self.cfg.get_str("permissions", &action_key, "").as_str() {
            "any" => return Ok(()),
            "admin" => {
                return Err(RucioError::AccessDenied(format!(
                    "{account}: {action:?} requires admin"
                )))
            }
            _ => {}
        }
        use Action::*;
        let allowed = match action {
            // admin-only surface
            AddRse | AdminRse | AddAccount | SetQuota | AddSubscription | AddScope
            | DeclareBadReplica => false,
            // write actions need scope ownership
            AddDid | AttachDid | DetachDid | SetMetadata => match scope {
                Some(s) => self.scope_owned_by(s, account),
                None => false,
            },
            // rules: any account may place rules (quota enforces limits)
            AddRule | DeleteRule => true,
            GetUsage => true,
        };
        if allowed {
            Ok(())
        } else {
            Err(RucioError::AccessDenied(format!(
                "{account} may not {action:?} on scope {scope:?}"
            )))
        }
    }

    pub(crate) fn scope_owned_by(&self, scope: &str, account: &str) -> bool {
        self.scopes
            .get(&scope.to_string())
            .map(|s| s.account == account)
            .unwrap_or(false)
    }
}

/// Identifier validation shared by accounts/scopes/RSE names.
pub fn validate_name(name: &str, max_len: usize) -> Result<()> {
    if name.is_empty() || name.len() > max_len {
        return Err(RucioError::InvalidObject(format!(
            "name '{name}' must be 1..={max_len} chars"
        )));
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
    {
        return Err(RucioError::InvalidObject(format!("invalid characters in '{name}'")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Catalog;

    fn catalog_with_alice() -> Catalog {
        let c = Catalog::new_for_tests();
        c.add_account("alice", AccountType::User, "alice@cern.ch").unwrap();
        c.add_identity("alice", AuthType::UserPass, "alice", Some("hunter2")).unwrap();
        c
    }

    #[test]
    fn account_creation_makes_home_scope() {
        let c = catalog_with_alice();
        let s = c.scopes.get(&"user.alice".to_string()).unwrap();
        assert_eq!(s.account, "alice");
        assert!(c.scope_owned_by("user.alice", "alice"));
        assert!(!c.scope_owned_by("user.alice", "bob"));
    }

    #[test]
    fn duplicate_account_rejected() {
        let c = catalog_with_alice();
        assert!(c.add_account("alice", AccountType::User, "x").is_err());
    }

    #[test]
    fn bad_account_names_rejected() {
        let c = Catalog::new_for_tests();
        assert!(c.add_account("", AccountType::User, "x").is_err());
        assert!(c.add_account("has space", AccountType::User, "x").is_err());
        assert!(c
            .add_account("waaaaaaaaaaaaaaaaaaaaaaaaaytoolong", AccountType::User, "x")
            .is_err());
    }

    #[test]
    fn userpass_auth_round_trip() {
        let c = catalog_with_alice();
        let tok = c.auth_userpass("alice", "alice", "hunter2").unwrap();
        assert_eq!(c.validate_token(&tok.token).unwrap(), "alice");
        assert!(c.auth_userpass("alice", "alice", "wrong").is_err());
        assert!(c.auth_userpass("alice", "nobody", "hunter2").is_err());
    }

    #[test]
    fn x509_multi_account_mapping() {
        let c = catalog_with_alice();
        c.add_account("prod", AccountType::Service, "prod@cern.ch").unwrap();
        let dn = "/DC=ch/DC=cern/CN=Alice Adams";
        c.add_identity(dn, AuthType::X509, "alice", None).unwrap();
        c.add_identity(dn, AuthType::X509, "prod", None).unwrap();
        // Fig 2: one identity, many accounts.
        let mut accts = c.identity_accounts(dn, AuthType::X509);
        accts.sort();
        assert_eq!(accts, vec!["alice", "prod"]);
        assert!(c.auth_x509("alice", dn).is_ok());
        assert!(c.auth_x509("prod", dn).is_ok());
        assert!(c.auth_x509("root", dn).is_err());
    }

    #[test]
    fn ssh_challenge_auth() {
        let c = catalog_with_alice();
        c.add_identity("key-1", AuthType::Ssh, "alice", Some("ssh-rsa AAAA...")).unwrap();
        let sig = c.ssh_challenge("key-1", "ssh-rsa AAAA...");
        assert!(c.auth_ssh("alice", "key-1", &sig).is_ok());
        assert!(c.auth_ssh("alice", "key-1", "forged").is_err());
    }

    #[test]
    fn token_expiry_and_purge() {
        let c = catalog_with_alice();
        let tok = c.auth_userpass("alice", "alice", "hunter2").unwrap();
        if let crate::common::clock::Clock::Sim(s) = &c.clock {
            s.advance(2 * crate::common::clock::HOUR_MS);
        }
        assert!(c.validate_token(&tok.token).is_err());
        assert_eq!(c.purge_expired_tokens(), 1);
        assert_eq!(c.tokens.len(), 0);
    }

    #[test]
    fn suspended_account_cannot_auth() {
        let c = catalog_with_alice();
        c.suspend_account("alice").unwrap();
        assert!(c.auth_userpass("alice", "alice", "hunter2").is_err());
    }

    #[test]
    fn suspension_revokes_outstanding_tokens() {
        let c = catalog_with_alice();
        let tok = c.auth_userpass("alice", "alice", "hunter2").unwrap();
        assert_eq!(c.validate_token(&tok.token).unwrap(), "alice");
        c.suspend_account("alice").unwrap();
        // the already-issued token dies with the suspension, immediately
        assert!(c.validate_token(&tok.token).is_err());
    }

    #[test]
    fn vo_deactivation_revokes_tokens_and_logins() {
        let mut c = Catalog::new_for_tests();
        c.add_account_vo("carol", AccountType::User, "c@x", "cms").unwrap();
        c.add_identity("carol", AuthType::UserPass, "carol", Some("pw")).unwrap();
        let tok = c.auth_userpass("carol", "carol", "pw").unwrap();
        assert_eq!(tok.vo, "cms");
        assert_eq!(c.validate_token_vo(&tok.token).unwrap(), ("carol".into(), "cms".into()));
        c.cfg.set("vo", "active.cms", "false");
        assert!(c.validate_token(&tok.token).is_err(), "existing token revoked");
        assert!(c.auth_userpass("carol", "carol", "pw").is_err(), "new logins refused");
    }

    #[test]
    fn identity_index_maintained_across_add_and_remove() {
        let c = catalog_with_alice();
        c.add_account("prod", AccountType::Service, "p@x").unwrap();
        let dn = "/DC=ch/CN=Alice";
        c.add_identity(dn, AuthType::X509, "alice", None).unwrap();
        c.add_identity(dn, AuthType::X509, "prod", None).unwrap();
        let probe = (dn.to_string(), AuthType::X509);
        assert_eq!(c.identities_by_key.count(&probe), 2);
        assert!(c.auth_x509("prod", dn).is_ok());
        c.remove_identity(dn, AuthType::X509, "prod").unwrap();
        assert_eq!(c.identities_by_key.count(&probe), 1, "index entry removed");
        assert!(c.auth_x509("prod", dn).is_err(), "removed mapping no longer authenticates");
        assert!(c.auth_x509("alice", dn).is_ok(), "sibling mapping untouched");
        assert!(c.remove_identity(dn, AuthType::X509, "prod").is_err(), "double remove");
        // userpass entries live under a distinct index key
        assert_eq!(c.identities_by_key.count(&("alice".into(), AuthType::UserPass)), 1);
    }

    #[test]
    fn cross_vo_permissions_denied() {
        let c = Catalog::new_for_tests();
        c.add_account_vo("a1", AccountType::User, "a@x", "atlas").unwrap();
        c.add_account_vo("c1", AccountType::User, "c@x", "cms").unwrap();
        // own-VO scope writes work; foreign-VO scope writes are denied
        assert!(c.check_permission("a1", Action::AddDid, Some("user.a1")).is_ok());
        assert!(c.check_permission("c1", Action::AddDid, Some("user.a1")).is_err());
        // a VO admin stays confined to its VO...
        c.set_admin("c1", true).unwrap();
        assert!(c.check_permission("c1", Action::AddDid, Some("user.a1")).is_err());
        assert!(c.check_permission("c1", Action::AddDid, Some("user.c1")).is_ok());
        // ...while the default-VO root crosses (instance operator)
        assert!(c.check_permission("root", Action::AddDid, Some("user.a1")).is_ok());
    }

    #[test]
    fn permission_policy_defaults() {
        let c = catalog_with_alice();
        // alice can write her own scope
        assert!(c.check_permission("alice", Action::AddDid, Some("user.alice")).is_ok());
        // but not someone else's, nor admin surface
        assert!(c.check_permission("alice", Action::AddDid, Some("root")).is_err());
        assert!(c.check_permission("alice", Action::AddRse, None).is_err());
        // rules are open to all
        assert!(c.check_permission("alice", Action::AddRule, None).is_ok());
        // root does everything
        assert!(c.check_permission("root", Action::AddRse, None).is_ok());
        assert!(c.check_permission("root", Action::AddDid, Some("user.alice")).is_ok());
    }

    #[test]
    fn permission_policy_configurable() {
        let mut cfg = crate::common::config::Config::new();
        cfg.set("permissions", "addrule", "admin");
        cfg.set("permissions", "adddid", "any");
        let c = Catalog::new(crate::common::clock::Clock::sim_at(0), cfg);
        c.add_account("bob", AccountType::User, "b@x").unwrap();
        assert!(c.check_permission("bob", Action::AddRule, None).is_err());
        assert!(c.check_permission("bob", Action::AddDid, Some("root")).is_ok());
    }
}
