//! Accounts, identities, authentication, tokens, and the permission
//! policy (paper §2.3 + §4.1).

use crate::common::checksum::hmac_sha256_hex;
use crate::common::clock::HOUR_MS;
use crate::common::error::{Result, RucioError};
use crate::common::idgen::hex_token;

use super::types::*;
use super::Catalog;

/// Operations gated by the permission policy (paper §4.1: "each
/// client-facing operation ... is validated through a permission
/// function").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    AddScope,
    AddDid,
    AttachDid,
    DetachDid,
    SetMetadata,
    AddRule,
    DeleteRule,
    AddRse,
    AdminRse,
    AddAccount,
    SetQuota,
    DeclareBadReplica,
    AddSubscription,
    GetUsage,
}

impl Catalog {
    // ------------------------------------------------------------------
    // accounts
    // ------------------------------------------------------------------

    pub fn add_account(&self, name: &str, account_type: AccountType, email: &str) -> Result<()> {
        validate_name(name, 25)?;
        let now = self.now();
        self.accounts.insert(
            Account {
                name: name.to_string(),
                account_type,
                email: email.to_string(),
                created_at: now,
                suspended: false,
                admin: false,
            },
            now,
        )?;
        // §2.3: "each account has an associated scope", like a home dir.
        let scope_name = match account_type {
            AccountType::User => format!("user.{name}"),
            AccountType::Group => format!("group.{name}"),
            AccountType::Service => name.to_string(),
        };
        let _ = self.scopes.insert(
            Scope { name: scope_name, account: name.to_string(), created_at: now },
            now,
        );
        self.metrics.incr("accounts.added", 1);
        Ok(())
    }

    pub fn get_account(&self, name: &str) -> Result<Account> {
        self.accounts
            .get(&name.to_string())
            .ok_or_else(|| RucioError::AccountNotFound(name.to_string()))
    }

    pub fn set_admin(&self, name: &str, admin: bool) -> Result<()> {
        self.get_account(name)?;
        self.accounts.update(&name.to_string(), self.now(), |a| a.admin = admin);
        Ok(())
    }

    pub fn suspend_account(&self, name: &str) -> Result<()> {
        self.get_account(name)?;
        self.accounts.update(&name.to_string(), self.now(), |a| a.suspended = true);
        Ok(())
    }

    // ------------------------------------------------------------------
    // identities (paper Fig 2: many-to-many identity ↔ account)
    // ------------------------------------------------------------------

    /// Map an identity to an account. For `UserPass` the secret is the
    /// password (stored salted+hashed); for `Ssh` it is the public key.
    pub fn add_identity(
        &self,
        identity: &str,
        auth_type: AuthType,
        account: &str,
        secret: Option<&str>,
    ) -> Result<()> {
        self.get_account(account)?;
        let stored_secret = match (auth_type, secret) {
            (AuthType::UserPass, Some(pw)) => Some(self.hash_secret(identity, pw)),
            (_, s) => s.map(|x| x.to_string()),
        };
        self.identities.insert(
            Identity {
                identity: identity.to_string(),
                auth_type,
                account: account.to_string(),
                secret: stored_secret,
            },
            self.now(),
        )?;
        Ok(())
    }

    /// Accounts an identity may act as (non-cloning projection).
    pub fn identity_accounts(&self, identity: &str, auth_type: AuthType) -> Vec<String> {
        self.identities.filter_map(|i| {
            (i.identity == identity && i.auth_type == auth_type).then(|| i.account.clone())
        })
    }

    fn hash_secret(&self, identity: &str, secret: &str) -> String {
        hmac_sha256_hex(format!("salt:{identity}").as_bytes(), secret.as_bytes())
    }

    // ------------------------------------------------------------------
    // authentication → tokens (paper §4.1)
    // ------------------------------------------------------------------

    /// Username/password authentication (native implementation, §4.1).
    pub fn auth_userpass(&self, account: &str, username: &str, password: &str) -> Result<Token> {
        let matches = self.identities.scan(|i| {
            i.identity == username && i.auth_type == AuthType::UserPass && i.account == account
        });
        let Some(id) = matches.first() else {
            return Err(RucioError::CannotAuthenticate(format!(
                "no userpass identity {username} for account {account}"
            )));
        };
        if id.secret.as_deref() != Some(self.hash_secret(username, password).as_str()) {
            return Err(RucioError::CannotAuthenticate("wrong credentials".into()));
        }
        self.issue_token(account)
    }

    /// X.509 DN authentication (GridSite stand-in: the DN string is the
    /// identity; transport-level verification is assumed).
    pub fn auth_x509(&self, account: &str, dn: &str) -> Result<Token> {
        self.auth_by_identity(account, dn, AuthType::X509)
    }

    /// GSSAPI/Kerberos principal authentication (ModAuthKerb stand-in).
    pub fn auth_gss(&self, account: &str, principal: &str) -> Result<Token> {
        self.auth_by_identity(account, principal, AuthType::Gss)
    }

    /// SSH public-key authentication: the client signs a server challenge;
    /// here the "signature" is an HMAC with the registered key material
    /// (cryptographic transport is out of scope for the simulation).
    pub fn auth_ssh(&self, account: &str, key_id: &str, signature: &str) -> Result<Token> {
        let matches = self.identities.scan(|i| {
            i.identity == key_id && i.auth_type == AuthType::Ssh && i.account == account
        });
        let Some(id) = matches.first() else {
            return Err(RucioError::CannotAuthenticate(format!("unknown ssh key {key_id}")));
        };
        let expected = self.hash_secret(key_id, id.secret.as_deref().unwrap_or(""));
        if signature != expected {
            return Err(RucioError::CannotAuthenticate("bad ssh signature".into()));
        }
        self.issue_token(account)
    }

    /// The challenge an SSH client must answer (see [`Catalog::auth_ssh`]).
    pub fn ssh_challenge(&self, key_id: &str, pubkey: &str) -> String {
        self.hash_secret(key_id, pubkey)
    }

    fn auth_by_identity(&self, account: &str, identity: &str, t: AuthType) -> Result<Token> {
        let ok = self
            .identities
            .scan(|i| i.identity == identity && i.auth_type == t && i.account == account);
        if ok.is_empty() {
            return Err(RucioError::CannotAuthenticate(format!(
                "identity {identity} cannot act as {account}"
            )));
        }
        self.issue_token(account)
    }

    fn issue_token(&self, account: &str) -> Result<Token> {
        let acc = self.get_account(account)?;
        if acc.suspended {
            return Err(RucioError::CannotAuthenticate(format!("account {account} suspended")));
        }
        let now = self.now();
        let lifetime = self.cfg.get_duration_ms("auth", "token_lifetime", HOUR_MS);
        let token = Token {
            token: format!("{}-{}", account, hex_token(self.next_id(), self.token_salt)),
            account: account.to_string(),
            expires_at: now + lifetime,
            issued_at: now,
        };
        self.tokens.insert(token.clone(), now)?;
        self.metrics.incr("auth.tokens_issued", 1);
        Ok(token)
    }

    /// Validate an `X-Rucio-Auth-Token`; returns the account.
    pub fn validate_token(&self, token: &str) -> Result<String> {
        let t = self
            .tokens
            .get(&token.to_string())
            .ok_or_else(|| RucioError::CannotAuthenticate("unknown token".into()))?;
        if t.expires_at < self.now() {
            return Err(RucioError::CannotAuthenticate("token expired".into()));
        }
        Ok(t.account)
    }

    /// Drop expired tokens (housekeeping daemon path): non-cloning key
    /// projection, then one batched removal.
    pub fn purge_expired_tokens(&self) -> usize {
        let now = self.now();
        let expired: Vec<String> = self
            .tokens
            .filter_map(|t| (t.expires_at < now).then(|| t.token.clone()));
        self.tokens.remove_bulk(&expired, now).len()
    }

    // ------------------------------------------------------------------
    // permission policy (paper §4.1, §2.3)
    // ------------------------------------------------------------------

    /// The default policy: admins may do anything; regular accounts get
    /// read everywhere, write into their own scopes, and rule management
    /// on their own rules. "These access permissions can be
    /// programmatically specified" — instances customize by overriding
    /// config keys `permissions.<action> = admin|any`.
    pub fn check_permission(&self, account: &str, action: Action, scope: Option<&str>) -> Result<()> {
        let acc = self.get_account(account)?;
        if acc.admin {
            return Ok(());
        }
        let action_key = format!("{action:?}").to_lowercase();
        match self.cfg.get_str("permissions", &action_key, "").as_str() {
            "any" => return Ok(()),
            "admin" => {
                return Err(RucioError::AccessDenied(format!(
                    "{account}: {action:?} requires admin"
                )))
            }
            _ => {}
        }
        use Action::*;
        let allowed = match action {
            // admin-only surface
            AddRse | AdminRse | AddAccount | SetQuota | AddSubscription | AddScope
            | DeclareBadReplica => false,
            // write actions need scope ownership
            AddDid | AttachDid | DetachDid | SetMetadata => match scope {
                Some(s) => self.scope_owned_by(s, account),
                None => false,
            },
            // rules: any account may place rules (quota enforces limits)
            AddRule | DeleteRule => true,
            GetUsage => true,
        };
        if allowed {
            Ok(())
        } else {
            Err(RucioError::AccessDenied(format!(
                "{account} may not {action:?} on scope {scope:?}"
            )))
        }
    }

    pub(crate) fn scope_owned_by(&self, scope: &str, account: &str) -> bool {
        self.scopes
            .get(&scope.to_string())
            .map(|s| s.account == account)
            .unwrap_or(false)
    }
}

/// Identifier validation shared by accounts/scopes/RSE names.
pub fn validate_name(name: &str, max_len: usize) -> Result<()> {
    if name.is_empty() || name.len() > max_len {
        return Err(RucioError::InvalidObject(format!(
            "name '{name}' must be 1..={max_len} chars"
        )));
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
    {
        return Err(RucioError::InvalidObject(format!("invalid characters in '{name}'")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Catalog;

    fn catalog_with_alice() -> Catalog {
        let c = Catalog::new_for_tests();
        c.add_account("alice", AccountType::User, "alice@cern.ch").unwrap();
        c.add_identity("alice", AuthType::UserPass, "alice", Some("hunter2")).unwrap();
        c
    }

    #[test]
    fn account_creation_makes_home_scope() {
        let c = catalog_with_alice();
        let s = c.scopes.get(&"user.alice".to_string()).unwrap();
        assert_eq!(s.account, "alice");
        assert!(c.scope_owned_by("user.alice", "alice"));
        assert!(!c.scope_owned_by("user.alice", "bob"));
    }

    #[test]
    fn duplicate_account_rejected() {
        let c = catalog_with_alice();
        assert!(c.add_account("alice", AccountType::User, "x").is_err());
    }

    #[test]
    fn bad_account_names_rejected() {
        let c = Catalog::new_for_tests();
        assert!(c.add_account("", AccountType::User, "x").is_err());
        assert!(c.add_account("has space", AccountType::User, "x").is_err());
        assert!(c
            .add_account("waaaaaaaaaaaaaaaaaaaaaaaaaytoolong", AccountType::User, "x")
            .is_err());
    }

    #[test]
    fn userpass_auth_round_trip() {
        let c = catalog_with_alice();
        let tok = c.auth_userpass("alice", "alice", "hunter2").unwrap();
        assert_eq!(c.validate_token(&tok.token).unwrap(), "alice");
        assert!(c.auth_userpass("alice", "alice", "wrong").is_err());
        assert!(c.auth_userpass("alice", "nobody", "hunter2").is_err());
    }

    #[test]
    fn x509_multi_account_mapping() {
        let c = catalog_with_alice();
        c.add_account("prod", AccountType::Service, "prod@cern.ch").unwrap();
        let dn = "/DC=ch/DC=cern/CN=Alice Adams";
        c.add_identity(dn, AuthType::X509, "alice", None).unwrap();
        c.add_identity(dn, AuthType::X509, "prod", None).unwrap();
        // Fig 2: one identity, many accounts.
        let mut accts = c.identity_accounts(dn, AuthType::X509);
        accts.sort();
        assert_eq!(accts, vec!["alice", "prod"]);
        assert!(c.auth_x509("alice", dn).is_ok());
        assert!(c.auth_x509("prod", dn).is_ok());
        assert!(c.auth_x509("root", dn).is_err());
    }

    #[test]
    fn ssh_challenge_auth() {
        let c = catalog_with_alice();
        c.add_identity("key-1", AuthType::Ssh, "alice", Some("ssh-rsa AAAA...")).unwrap();
        let sig = c.ssh_challenge("key-1", "ssh-rsa AAAA...");
        assert!(c.auth_ssh("alice", "key-1", &sig).is_ok());
        assert!(c.auth_ssh("alice", "key-1", "forged").is_err());
    }

    #[test]
    fn token_expiry_and_purge() {
        let c = catalog_with_alice();
        let tok = c.auth_userpass("alice", "alice", "hunter2").unwrap();
        if let crate::common::clock::Clock::Sim(s) = &c.clock {
            s.advance(2 * crate::common::clock::HOUR_MS);
        }
        assert!(c.validate_token(&tok.token).is_err());
        assert_eq!(c.purge_expired_tokens(), 1);
        assert_eq!(c.tokens.len(), 0);
    }

    #[test]
    fn suspended_account_cannot_auth() {
        let c = catalog_with_alice();
        c.suspend_account("alice").unwrap();
        assert!(c.auth_userpass("alice", "alice", "hunter2").is_err());
    }

    #[test]
    fn permission_policy_defaults() {
        let c = catalog_with_alice();
        // alice can write her own scope
        assert!(c.check_permission("alice", Action::AddDid, Some("user.alice")).is_ok());
        // but not someone else's, nor admin surface
        assert!(c.check_permission("alice", Action::AddDid, Some("root")).is_err());
        assert!(c.check_permission("alice", Action::AddRse, None).is_err());
        // rules are open to all
        assert!(c.check_permission("alice", Action::AddRule, None).is_ok());
        // root does everything
        assert!(c.check_permission("root", Action::AddRse, None).is_ok());
        assert!(c.check_permission("root", Action::AddDid, Some("user.alice")).is_ok());
    }

    #[test]
    fn permission_policy_configurable() {
        let mut cfg = crate::common::config::Config::new();
        cfg.set("permissions", "addrule", "admin");
        cfg.set("permissions", "adddid", "any");
        let c = Catalog::new(crate::common::clock::Clock::sim_at(0), cfg);
        c.add_account("bob", AccountType::User, "b@x").unwrap();
        assert!(c.check_permission("bob", Action::AddRule, None).is_err());
        assert!(c.check_permission("bob", Action::AddDid, Some("root")).is_ok());
    }
}
