//! Namespace operations: scopes, DIDs, attachments, metadata, archives
//! (paper §2.2).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::ops::Bound;

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};
use crate::common::regex;

use super::accounts_api::validate_name;
use super::metaexpr::{CmpOp, MetaExpr, MetaValue};
use super::types::*;
use super::Catalog;

/// Maximum DID name length ("limits on overall character length, e.g., to
/// reflect file system limitations", §2.2).
pub const MAX_NAME_LEN: usize = 250;

impl Catalog {
    // ------------------------------------------------------------------
    // scopes
    // ------------------------------------------------------------------

    pub fn add_scope(&self, scope: &str, account: &str) -> Result<()> {
        validate_name(scope, 30)?;
        // the scope inherits the VO of its owning account (tenant boundary)
        let owner = self.get_account(account)?;
        let now = self.now();
        self.scopes.insert(
            Scope {
                name: scope.to_string(),
                account: account.to_string(),
                created_at: now,
                vo: owner.vo,
            },
            now,
        )?;
        Ok(())
    }

    pub fn get_scope(&self, scope: &str) -> Result<Scope> {
        self.scopes
            .get(&scope.to_string())
            .ok_or_else(|| RucioError::ScopeNotFound(scope.to_string()))
    }

    pub fn list_scopes(&self) -> Vec<String> {
        self.scopes.keys()
    }

    // ------------------------------------------------------------------
    // DID creation
    // ------------------------------------------------------------------

    /// Register a file DID (paper §2.2: "new files enter the system
    /// usually by registering first the file").
    #[allow(clippy::too_many_arguments)]
    pub fn add_file(
        &self,
        scope: &str,
        name: &str,
        account: &str,
        bytes: u64,
        adler32: &str,
        guid: Option<&str>,
    ) -> Result<()> {
        self.add_did_impl(scope, name, DidType::File, account, bytes, adler32, guid)
    }

    pub fn add_dataset(&self, scope: &str, name: &str, account: &str) -> Result<()> {
        self.add_did_impl(scope, name, DidType::Dataset, account, 0, "", None)
    }

    pub fn add_container(&self, scope: &str, name: &str, account: &str) -> Result<()> {
        self.add_did_impl(scope, name, DidType::Container, account, 0, "", None)
    }

    #[allow(clippy::too_many_arguments)]
    fn add_did_impl(
        &self,
        scope: &str,
        name: &str,
        did_type: DidType,
        account: &str,
        bytes: u64,
        adler32: &str,
        guid: Option<&str>,
    ) -> Result<()> {
        self.get_scope(scope)?;
        self.validate_did_name(name)?;
        let key = DidKey::new(scope, name);
        // §2.2: "a DID, once used, can never be reused to refer to anything
        // else at all, not even if the data it referred to has been deleted".
        if self.name_tombstones.contains(&key) {
            return Err(RucioError::DidAlreadyExists(format!(
                "{key} was used historically and can never be reused"
            )));
        }
        if let Some(g) = guid {
            // GUID uniqueness enforcement (§2.2).
            let clash = self
                .dids
                .scan_limit(1, |d| d.guid.as_deref() == Some(g));
            if !clash.is_empty() {
                return Err(RucioError::Duplicate(format!("guid {g} already registered")));
            }
        }
        let now = self.now();
        let is_coll = did_type.is_collection();
        self.dids.insert(
            Did {
                key,
                did_type,
                account: account.to_string(),
                bytes,
                adler32: adler32.to_string(),
                md5: None,
                guid: guid.map(|s| s.to_string()),
                open: is_coll, // collections are created open (§2.2)
                monotonic: false,
                suppressed: false,
                availability: if is_coll {
                    Availability::Available
                } else {
                    Availability::Deleted // no replicas yet
                },
                meta: BTreeMap::new(),
                created_at: now,
                expired_at: None,
                constituent_of: None,
            },
            now,
        )?;
        self.metrics.incr("dids.added", 1);
        if is_coll {
            // Subscription matching is asynchronous: the transmogrifier
            // daemon consumes this event in batches (§2.5).
            self.notify(
                "did-created",
                crate::jsonx::Json::obj()
                    .with("scope", scope)
                    .with("name", name)
                    .with("did_type", did_type.as_str()),
            );
        }
        Ok(())
    }

    /// Naming convention enforcement (§2.2): length plus an optional
    /// configured regex schema (`naming.schema` config key).
    fn validate_did_name(&self, name: &str) -> Result<()> {
        if name.is_empty() || name.len() > MAX_NAME_LEN {
            return Err(RucioError::InvalidObject(format!(
                "DID name length must be 1..={MAX_NAME_LEN}"
            )));
        }
        if !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '/' | '+'))
        {
            return Err(RucioError::InvalidObject(format!("invalid characters in '{name}'")));
        }
        if let Some(pattern) = self.cfg.get("naming", "schema") {
            let re = regex::Regex::new(pattern)
                .map_err(|e| RucioError::ConfigError(format!("naming.schema: {e}")))?;
            if !re.is_match(name) {
                return Err(RucioError::InvalidObject(format!(
                    "name '{name}' violates naming schema"
                )));
            }
        }
        Ok(())
    }

    pub fn get_did(&self, key: &DidKey) -> Result<Did> {
        self.dids
            .get(key)
            .ok_or_else(|| RucioError::DidNotFound(key.to_string()))
    }

    // ------------------------------------------------------------------
    // hierarchy (Fig 1)
    // ------------------------------------------------------------------

    /// Attach `child` to collection `parent`. Containers hold collections;
    /// datasets hold files (Fig 1). Returns the set of *file* DIDs newly
    /// reachable (rule engine extends covering rules over them).
    pub fn attach(&self, parent: &DidKey, child: &DidKey) -> Result<Vec<DidKey>> {
        let p = self.get_did(parent)?;
        let c = self.get_did(child)?;
        match (p.did_type, c.did_type) {
            (DidType::Dataset, DidType::File) => {}
            (DidType::Container, DidType::Dataset) | (DidType::Container, DidType::Container) => {}
            _ => {
                return Err(RucioError::UnsupportedOperation(format!(
                    "cannot attach {} to {}",
                    c.did_type.as_str(),
                    p.did_type.as_str()
                )))
            }
        }
        if !p.open {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is closed"
            )));
        }
        if parent == child || self.is_ancestor(child, parent) {
            return Err(RucioError::UnsupportedOperation(format!(
                "attaching {child} to {parent} would create a cycle"
            )));
        }
        let now = self.now();
        self.attachments.insert(
            Attachment { parent: parent.clone(), child: child.clone(), created_at: now },
            now,
        )?;
        self.metrics.incr("dids.attached", 1);
        let files = self.resolve_files(child);
        // Rule engine hook: extend rules covering `parent` (and ancestors).
        self.on_content_added(parent, &files)?;
        Ok(files.into_iter().map(|f| f.key).collect())
    }

    /// Detach `child` from `parent` (only open, non-monotonic parents;
    /// §2.2: "if the monotonic attribute is set, content cannot be removed
    /// from an open collection").
    pub fn detach(&self, parent: &DidKey, child: &DidKey) -> Result<()> {
        let p = self.get_did(parent)?;
        if !p.open {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is closed"
            )));
        }
        if p.monotonic {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is monotonic"
            )));
        }
        let now = self.now();
        if self
            .attachments
            .remove(&(parent.clone(), child.clone()), now)
            .is_none()
        {
            return Err(RucioError::DidNotFound(format!("{child} not attached to {parent}")));
        }
        let files = self.resolve_files(child);
        self.on_content_removed(parent, &files)?;
        self.metrics.incr("dids.detached", 1);
        Ok(())
    }

    fn is_ancestor(&self, maybe_ancestor: &DidKey, of: &DidKey) -> bool {
        let mut queue = VecDeque::from([of.clone()]);
        let mut seen = BTreeSet::new();
        while let Some(cur) = queue.pop_front() {
            for (parent, _) in self
                .att_by_child
                .get(&cur)
                .into_iter()
                .map(|(p, c)| (p, c))
            {
                if &parent == maybe_ancestor {
                    return true;
                }
                if seen.insert(parent.clone()) {
                    queue.push_back(parent);
                }
            }
        }
        false
    }

    /// Direct children of a collection.
    pub fn list_content(&self, parent: &DidKey, include_suppressed: bool) -> Vec<Did> {
        self.att_by_parent
            .get(parent)
            .into_iter()
            .filter_map(|(_, child)| self.dids.get(&child))
            .filter(|d| include_suppressed || !d.suppressed)
            .collect()
    }

    /// All *file* DIDs reachable from a DID (BFS through the hierarchy) —
    /// the unit the rule engine operates on. Files include themselves.
    pub fn resolve_files(&self, did: &DidKey) -> Vec<Did> {
        let mut files = Vec::new();
        let mut seen = BTreeSet::new();
        let mut queue = VecDeque::from([did.clone()]);
        while let Some(cur) = queue.pop_front() {
            if !seen.insert(cur.clone()) {
                continue;
            }
            let Some(d) = self.dids.get(&cur) else { continue };
            if d.did_type == DidType::File {
                files.push(d);
            } else {
                for (_, child) in self.att_by_parent.get(&cur) {
                    queue.push_back(child);
                }
            }
        }
        files
    }

    /// Direct parents of a DID.
    pub fn list_parents(&self, did: &DidKey) -> Vec<DidKey> {
        self.att_by_child
            .get(did)
            .into_iter()
            .map(|(parent, _)| parent)
            .collect()
    }

    /// All ancestors (transitive parents) of a DID, nearest first.
    pub fn ancestors(&self, did: &DidKey) -> Vec<DidKey> {
        let mut out = Vec::new();
        let mut seen = BTreeSet::new();
        let mut queue = VecDeque::from([did.clone()]);
        while let Some(cur) = queue.pop_front() {
            for (parent, _) in self.att_by_child.get(&cur) {
                if seen.insert(parent.clone()) {
                    out.push(parent.clone());
                    queue.push_back(parent);
                }
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // collection status (§2.2)
    // ------------------------------------------------------------------

    /// Close a collection ("once closed they cannot be opened again").
    pub fn close(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !d.did_type.is_collection() {
            return Err(RucioError::UnsupportedOperation("cannot close a file".into()));
        }
        self.dids.update(did, self.now(), |d| d.open = false);
        Ok(())
    }

    /// Set monotonic (one-way; "once set to monotonic, this cannot be
    /// reversed").
    pub fn set_monotonic(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !d.did_type.is_collection() {
            return Err(RucioError::UnsupportedOperation("files cannot be monotonic".into()));
        }
        self.dids.update(did, self.now(), |d| d.monotonic = true);
        Ok(())
    }

    /// Suppression flag (§2.2): hidden from default listings.
    pub fn set_suppressed(&self, did: &DidKey, suppressed: bool) -> Result<()> {
        self.get_did(did)?;
        self.dids.update(did, self.now(), |d| d.suppressed = suppressed);
        Ok(())
    }

    /// A collection is *complete* when every reachable file has at least
    /// one available replica (derived attribute, §2.2).
    pub fn is_complete(&self, did: &DidKey) -> Result<bool> {
        self.get_did(did)?;
        Ok(self
            .resolve_files(did)
            .iter()
            .all(|f| f.availability == Availability::Available))
    }

    /// Aggregate byte size of all reachable files.
    pub fn did_bytes(&self, did: &DidKey) -> u64 {
        self.resolve_files(did).iter().map(|f| f.bytes).sum()
    }

    // ------------------------------------------------------------------
    // metadata (§2.2)
    // ------------------------------------------------------------------

    /// Set one metadata pair with lexical typing (`"true"` → bool,
    /// `"358031"` → int, `"13.6"` → float, else string) — the path the
    /// CLI/REST string surface uses.
    pub fn set_metadata(&self, did: &DidKey, key: &str, value: &str) -> Result<()> {
        self.set_metadata_typed(did, key, MetaValue::parse_lexical(value))
    }

    /// Set one typed metadata pair. The dids-table mutation hook mirrors
    /// the change into the inverted index ([`Catalog::meta_index`]).
    pub fn set_metadata_typed(&self, did: &DidKey, key: &str, value: MetaValue) -> Result<()> {
        self.set_metadata_bulk(did, vec![(key.to_string(), value)])
    }

    /// Set many metadata pairs in one row update (one index refresh).
    pub fn set_metadata_bulk(
        &self,
        did: &DidKey,
        mut pairs: Vec<(String, MetaValue)>,
    ) -> Result<()> {
        for (key, value) in &mut pairs {
            if key.is_empty() || key.len() > 64 || !key.chars().all(is_meta_key_char) {
                return Err(RucioError::InvalidValue(format!("bad metadata key '{key}'")));
            }
            if super::metaexpr::is_reserved_key(key) {
                return Err(RucioError::InvalidValue(format!(
                    "'{key}' is reserved by the filter language"
                )));
            }
            if let MetaValue::Float(f) = value {
                if !f.is_finite() {
                    return Err(RucioError::InvalidValue(format!(
                        "non-finite float for metadata key '{key}'"
                    )));
                }
                // canonical zero: the index order must agree with
                // numeric equality (-0.0 == 0.0)
                *f = super::metaexpr::canonical_f64(*f);
            }
        }
        if self
            .dids
            .update(did, self.now(), |d| d.meta.extend(pairs))
            .is_none()
        {
            return Err(RucioError::DidNotFound(did.to_string()));
        }
        self.metrics.incr("dids.meta_set", 1);
        Ok(())
    }

    /// A DID's metadata map. Projects just the map out of the row under
    /// the shard lock — it must not clone the whole `Did` (checksums,
    /// name strings, …) to return one field.
    pub fn get_metadata(&self, did: &DidKey) -> Result<BTreeMap<String, MetaValue>> {
        self.dids
            .read(did, |d| d.meta.clone())
            .ok_or_else(|| RucioError::DidNotFound(did.to_string()))
    }

    /// DID lifetime: the undertaker removes DIDs past expiry.
    pub fn set_did_expiry(&self, did: &DidKey, expired_at: Option<EpochMs>) -> Result<()> {
        self.get_did(did)?;
        self.dids.update(did, self.now(), |d| d.expired_at = expired_at);
        Ok(())
    }

    // ------------------------------------------------------------------
    // listing / search (the meta-expr query engine)
    // ------------------------------------------------------------------

    /// List DIDs in a scope, optionally filtered by a name glob (`*`
    /// wildcard) and type. Suppressed DIDs are hidden (§2.2) unless asked.
    /// Routed through the `meta-expr` engine so the same planner serves
    /// every discovery surface.
    pub fn list_dids(
        &self,
        scope: &str,
        name_glob: Option<&str>,
        did_type: Option<DidType>,
        include_suppressed: bool,
    ) -> Vec<Did> {
        let mut expr = MetaExpr::Any;
        if let Some(glob) = name_glob {
            expr = MetaExpr::NameGlob(glob.to_string());
        }
        if let Some(t) = did_type {
            expr = MetaExpr::And(Box::new(expr), Box::new(MetaExpr::TypeIs(t)));
        }
        self.query_dids(scope, &expr, include_suppressed)
    }

    /// Pick the execution plan for a filter over one scope: the most
    /// selective indexable conjunct of the normalized expression, else
    /// the scope scan. Candidate counts are scope-local (the index leads
    /// with the scope). Public so benches/tests can assert "the planner
    /// chose the index".
    pub fn plan_dids_query(&self, scope: &str, expr: &MetaExpr) -> QueryPlan {
        self.plan_normalized(scope, &expr.normalize())
    }

    /// Planner core over an already-normalized expression (the query
    /// executors normalize once and reuse it here — normalization clones
    /// the AST, so it must not run twice per query).
    fn plan_normalized(&self, scope: &str, expr: &MetaExpr) -> QueryPlan {
        let mut best: Option<QueryPlan> = None;
        for atom in expr.conjuncts() {
            let cand = match atom {
                // Numeric Eq uses the equality band (both typed
                // representations); ordered ops use their range band.
                MetaExpr::Cmp(key, op, value)
                    if !matches!(op, CmpOp::Ne)
                        && MetaValue::numeric_band(*op, value).is_some() =>
                {
                    let (lo, hi) = MetaValue::numeric_band(*op, value)
                        .expect("checked in the guard");
                    let klo = band_bound(scope, key, lo.as_ref());
                    let khi = band_bound(scope, key, hi.as_ref());
                    Some(QueryPlan::MetaRange {
                        key: key.clone(),
                        op: *op,
                        value: value.clone(),
                        candidates: self.meta_index.count_range(klo.as_ref(), khi.as_ref()),
                    })
                }
                // Non-numeric equality (strings/bools): exact point probe.
                MetaExpr::Cmp(key, CmpOp::Eq, value) => {
                    let ik = (scope.to_string(), key.clone(), value.clone());
                    Some(QueryPlan::MetaEq {
                        key: key.clone(),
                        value: value.clone(),
                        candidates: self.meta_index.count(&ik),
                    })
                }
                _ => None, // Ne / NOT / OR / name / type: not indexable
            };
            if let Some(plan) = cand {
                if best
                    .as_ref()
                    .map(|b| plan.candidates() < b.candidates())
                    .unwrap_or(true)
                {
                    best = Some(plan);
                }
            }
        }
        // Cost gate: an index plan does one random point lookup per
        // candidate, a scope scan reads the scope's contiguous pages —
        // once the best index predicate covers ≥ half of *this scope*,
        // the scan wins. Scope sizes come O(1) off `dids_by_scope`.
        let scope_size = self.dids_by_scope.count(&scope.to_string()).max(1);
        match best {
            Some(plan) if plan.candidates().saturating_mul(2) < scope_size => plan,
            _ => QueryPlan::ScopeScan,
        }
    }

    /// Answer a `meta-expr` filter over one scope, name-ordered. The
    /// planner probes the inverted index when any positive equality /
    /// numeric-range conjunct exists, and falls back to an ordered scan
    /// over the scope's contiguous key range otherwise; both executors
    /// apply the full expression, so results are plan-independent
    /// (property-tested).
    pub fn query_dids(&self, scope: &str, expr: &MetaExpr, include_suppressed: bool) -> Vec<Did> {
        let expr = expr.normalize();
        match self.plan_normalized(scope, &expr) {
            QueryPlan::ScopeScan => self.query_dids_scan(scope, &expr, include_suppressed),
            plan => {
                self.metrics.incr("dids.query.indexed", 1);
                let mut keys = self.plan_candidates(scope, &plan);
                keys.sort();
                keys.into_iter()
                    .filter_map(|k| self.dids.get(&k))
                    .filter(|d| (include_suppressed || !d.suppressed) && expr.matches(d))
                    .collect()
            }
        }
    }

    /// The scan executor: ordered walk of the scope's contiguous key
    /// range, applying the expression to every row. Public as the
    /// planner-equivalence baseline for tests and the ablation bench.
    pub fn query_dids_scan(
        &self,
        scope: &str,
        expr: &MetaExpr,
        include_suppressed: bool,
    ) -> Vec<Did> {
        self.metrics.incr("dids.query.scan", 1);
        let mut out = Vec::new();
        let mut after: Option<String> = None;
        loop {
            let (page, next) = self.scope_page(scope, after.as_deref(), 1024);
            out.extend(
                page.into_iter()
                    .filter(|d| (include_suppressed || !d.suppressed) && expr.matches(d)),
            );
            match next {
                Some(n) => after = Some(n),
                None => return out,
            }
        }
    }

    /// One page of filtered results in name order: rows strictly after
    /// `after_name` matching `expr`, plus the cursor for the next page
    /// (`None` once exhausted) — the NDJSON `GET /dids/{scope}?filter=`
    /// surface. A page's row fetches are bounded by the plan's remaining
    /// candidates (index plans re-derive the candidate tail per page; the
    /// scan plan resumes from the cursor's key position).
    pub fn query_dids_page(
        &self,
        scope: &str,
        expr: &MetaExpr,
        after_name: Option<&str>,
        limit: usize,
    ) -> (Vec<Did>, Option<String>) {
        let limit = limit.max(1);
        let expr = expr.normalize();
        let mut rows: Vec<Did> = Vec::with_capacity(limit.min(1024));
        match self.plan_normalized(scope, &expr) {
            QueryPlan::ScopeScan => {
                self.metrics.incr("dids.query.scan", 1);
                let mut after = after_name.map(|s| s.to_string());
                loop {
                    let (page, next) = self.scope_page(scope, after.as_deref(), 1024.max(limit));
                    for d in page {
                        if !d.suppressed && expr.matches(&d) {
                            if rows.len() == limit {
                                // one extra match proves another page exists
                                let cursor = rows.last().map(|d: &Did| d.key.name.clone());
                                return (rows, cursor);
                            }
                            rows.push(d);
                        }
                    }
                    match next {
                        Some(n) => after = Some(n),
                        None => return (rows, None),
                    }
                }
            }
            plan => {
                self.metrics.incr("dids.query.indexed", 1);
                // Drop rows at/before the cursor *before* sorting: each
                // page only sorts the remaining tail of the (scope-local)
                // candidate set, so a paged walk shrinks page over page.
                let mut keys: Vec<DidKey> = self
                    .plan_candidates(scope, &plan)
                    .into_iter()
                    .filter(|k| after_name.map(|a| k.name.as_str() > a).unwrap_or(true))
                    .collect();
                keys.sort();
                for k in keys {
                    let Some(d) = self.dids.get(&k) else { continue };
                    if !d.suppressed && expr.matches(&d) {
                        if rows.len() == limit {
                            let cursor = rows.last().map(|d: &Did| d.key.name.clone());
                            return (rows, cursor);
                        }
                        rows.push(d);
                    }
                }
                (rows, None)
            }
        }
    }

    /// Candidate primary keys of an index-backed plan, already
    /// scope-local (unsorted).
    fn plan_candidates(&self, scope: &str, plan: &QueryPlan) -> Vec<DidKey> {
        match plan {
            QueryPlan::MetaEq { key, value, .. } => {
                self.meta_index.get(&(scope.to_string(), key.clone(), value.clone()))
            }
            QueryPlan::MetaRange { key, op, value, .. } => {
                let (lo, hi) = MetaValue::numeric_band(*op, value)
                    .expect("range plans are built from numeric bands");
                let klo = band_bound(scope, key, lo.as_ref());
                let khi = band_bound(scope, key, hi.as_ref());
                self.meta_index.range_bounds(klo.as_ref(), khi.as_ref())
            }
            QueryPlan::ScopeScan => Vec::new(),
        }
    }

    /// One raw (unfiltered) page of a scope's rows in name order. The
    /// scope's keys are contiguous in the ordered table — "<scope>\0"
    /// sorts after <scope> and before any longer sibling, so it bounds
    /// the scope exactly and each page is O(page), not O(scope).
    fn scope_page(
        &self,
        scope: &str,
        after_name: Option<&str>,
        limit: usize,
    ) -> (Vec<Did>, Option<String>) {
        let lo_key = DidKey::new(scope, after_name.unwrap_or(""));
        let hi_key = DidKey { scope: format!("{scope}\u{0}"), name: String::new() };
        let page = self
            .dids
            .range_page(Bound::Excluded(&lo_key), Bound::Excluded(&hi_key), limit);
        let next = page.next_cursor.map(|k| k.name);
        (page.rows, next)
    }

    /// One page of a scope's DIDs in name order (cursor-based listing for
    /// the NDJSON REST routes): rows strictly after `after_name`, plus
    /// the cursor for the next page (`None` once exhausted). O(page), not
    /// O(scope): the scope's keys are contiguous in the ordered table.
    pub fn list_dids_page(
        &self,
        scope: &str,
        after_name: Option<&str>,
        limit: usize,
    ) -> (Vec<Did>, Option<String>) {
        self.scope_page(scope, after_name, limit)
    }

    // ------------------------------------------------------------------
    // deletion (undertaker path)
    // ------------------------------------------------------------------

    /// Remove a DID from the namespace, writing a permanent name
    /// tombstone. Callers (undertaker) must have removed rules first.
    /// The dids-table removal hook also drops every posting the DID holds
    /// in the metadata inverted index — nothing stale may survive the row
    /// (regression-tested below).
    pub fn erase_did(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !self.rules_by_did.get(did).is_empty() {
            return Err(RucioError::UnsupportedOperation(format!(
                "{did} still has rules"
            )));
        }
        let now = self.now();
        // Detach from parents and drop own attachment edges.
        for (parent, child) in self.att_by_child.get(did) {
            self.attachments.remove(&(parent, child), now);
        }
        for (parent, child) in self.att_by_parent.get(did) {
            self.attachments.remove(&(parent, child), now);
        }
        self.dids.remove(did, now);
        let _ = self.name_tombstones.insert(
            NameTombstone { key: did.clone(), deleted_at: now },
            now,
        );
        self.metrics.incr("dids.erased", 1);
        let _ = d;
        Ok(())
    }

    // ------------------------------------------------------------------
    // archives (§2.2)
    // ------------------------------------------------------------------

    /// Register `constituent` as content of archive file `archive` (e.g.
    /// a ZIP). Resolving replicas of the constituent will use the
    /// archive's replicas.
    pub fn register_constituent(&self, archive: &DidKey, constituent: &DidKey) -> Result<()> {
        let a = self.get_did(archive)?;
        let c = self.get_did(constituent)?;
        if a.did_type != DidType::File || c.did_type != DidType::File {
            return Err(RucioError::UnsupportedOperation(
                "archives and constituents must be files".into(),
            ));
        }
        self.dids.update(constituent, self.now(), |d| {
            d.constituent_of = Some(archive.clone())
        });
        Ok(())
    }
}

/// The execution strategy [`Catalog::plan_dids_query`] picked for a
/// `meta-expr`: an inverted-index probe (equality), an inverted-index
/// numeric range, or the ordered scope scan.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryPlan {
    MetaEq { key: String, value: MetaValue, candidates: usize },
    MetaRange { key: String, op: CmpOp, value: MetaValue, candidates: usize },
    ScopeScan,
}

impl QueryPlan {
    /// Estimated candidate rows the plan touches (usize::MAX for a scan,
    /// which is unbounded by any index).
    pub fn candidates(&self) -> usize {
        match self {
            QueryPlan::MetaEq { candidates, .. } | QueryPlan::MetaRange { candidates, .. } => {
                *candidates
            }
            QueryPlan::ScopeScan => usize::MAX,
        }
    }

    pub fn is_indexed(&self) -> bool {
        !matches!(self, QueryPlan::ScopeScan)
    }
}

fn is_meta_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')
}

/// Lift a value-space bound into the `(scope, key, value)` index-key
/// space.
fn band_bound(scope: &str, key: &str, b: Bound<&MetaValue>) -> Bound<(String, String, MetaValue)> {
    match b {
        Bound::Included(v) => Bound::Included((scope.to_string(), key.to_string(), v.clone())),
        Bound::Excluded(v) => Bound::Excluded((scope.to_string(), key.to_string(), v.clone())),
        // numeric bands are always closed at both ends in value space
        Bound::Unbounded => unreachable!("numeric_band never yields unbounded edges"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Catalog;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        c.add_account("alice", AccountType::User, "a@x").unwrap();
        c.add_scope("data18", "root").unwrap();
        c
    }

    fn add_files(c: &Catalog, scope: &str, prefix: &str, n: usize) -> Vec<DidKey> {
        (0..n)
            .map(|i| {
                let name = format!("{prefix}.{i:04}");
                c.add_file(scope, &name, "root", 1000 + i as u64, "aabbccdd", None)
                    .unwrap();
                DidKey::new(scope, &name)
            })
            .collect()
    }

    #[test]
    fn list_dids_page_walks_scope_in_order() {
        let c = catalog();
        c.add_scope("other", "root").unwrap();
        add_files(&c, "data18", "f", 25);
        add_files(&c, "other", "g", 5); // must never leak into data18 pages
        let mut names = Vec::new();
        let mut cursor: Option<String> = None;
        let mut pages = 0;
        loop {
            let (rows, next) = c.list_dids_page("data18", cursor.as_deref(), 10);
            assert!(rows.iter().all(|d| d.key.scope == "data18"));
            names.extend(rows.into_iter().map(|d| d.key.name));
            pages += 1;
            match next {
                Some(n) => cursor = Some(n),
                None => break,
            }
            assert!(pages < 50);
        }
        let expect: Vec<String> = (0..25).map(|i| format!("f.{i:04}")).collect();
        assert_eq!(names, expect, "paged walk is complete + name-ordered");
        assert_eq!(pages, 3);
        // empty scope: one empty page
        c.add_scope("empty", "root").unwrap();
        let (rows, next) = c.list_dids_page("empty", None, 10);
        assert!(rows.is_empty() && next.is_none());
    }

    #[test]
    fn fig1_hierarchy() {
        // Reproduce the paper's Fig 1 shape: containers of containers of
        // datasets of files, with overlap.
        let c = catalog();
        c.add_container("data18", "experiment", "root").unwrap();
        c.add_container("data18", "detector_data", "root").unwrap();
        c.add_dataset("data18", "dataset_f5f6", "root").unwrap();
        let files = add_files(&c, "data18", "f", 2);
        let exp = DidKey::new("data18", "experiment");
        let det = DidKey::new("data18", "detector_data");
        let ds = DidKey::new("data18", "dataset_f5f6");
        c.attach(&exp, &det).unwrap();
        c.attach(&det, &ds).unwrap();
        c.attach(&ds, &files[0]).unwrap();
        c.attach(&ds, &files[1]).unwrap();
        // Alice's analysis dataset shares F6 (overlapping DIDs).
        c.add_dataset("user.alice", "alices_analysis", "alice").unwrap();
        let ana = DidKey::new("user.alice", "alices_analysis");
        c.attach(&ana, &files[1]).unwrap();

        let resolved = c.resolve_files(&exp);
        assert_eq!(resolved.len(), 2);
        assert_eq!(c.resolve_files(&ana).len(), 1);
        assert_eq!(c.list_parents(&files[1]).len(), 2);
        let anc = c.ancestors(&files[0]);
        assert!(anc.contains(&exp) && anc.contains(&det) && anc.contains(&ds));
    }

    #[test]
    fn type_rules_enforced() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        c.add_container("data18", "cont", "root").unwrap();
        let files = add_files(&c, "data18", "f", 1);
        let ds = DidKey::new("data18", "ds");
        let cont = DidKey::new("data18", "cont");
        // dataset cannot hold datasets; container cannot hold files
        assert!(c.attach(&cont, &files[0]).is_err());
        assert!(c.attach(&files[0], &ds).is_err());
        assert!(c.attach(&ds, &cont).is_err());
        // legal edges
        c.attach(&ds, &files[0]).unwrap();
        c.attach(&cont, &ds).unwrap();
    }

    #[test]
    fn cycles_rejected() {
        let c = catalog();
        c.add_container("data18", "a", "root").unwrap();
        c.add_container("data18", "b", "root").unwrap();
        let a = DidKey::new("data18", "a");
        let b = DidKey::new("data18", "b");
        c.attach(&a, &b).unwrap();
        assert!(c.attach(&b, &a).is_err());
        assert!(c.attach(&a, &a).is_err());
    }

    #[test]
    fn closed_and_monotonic_flags() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let files = add_files(&c, "data18", "f", 3);
        c.attach(&ds, &files[0]).unwrap();
        // monotonic prevents detach but allows attach
        c.set_monotonic(&ds).unwrap();
        c.attach(&ds, &files[1]).unwrap();
        assert!(c.detach(&ds, &files[0]).is_err());
        // closed prevents attach
        c.close(&ds).unwrap();
        assert!(c.attach(&ds, &files[2]).is_err());
        assert!(c.detach(&ds, &files[0]).is_err());
    }

    #[test]
    fn names_are_forever() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 1);
        c.erase_did(&files[0]).unwrap();
        // §2.2: the name can never be reused.
        assert!(c
            .add_file("data18", "f.0000", "root", 1, "00000000", None)
            .is_err());
    }

    #[test]
    fn guid_uniqueness() {
        let c = catalog();
        c.add_file("data18", "g1", "root", 1, "x", Some("GUID-123")).unwrap();
        assert!(c.add_file("data18", "g2", "root", 1, "x", Some("GUID-123")).is_err());
        c.add_file("data18", "g3", "root", 1, "x", Some("GUID-456")).unwrap();
    }

    #[test]
    fn suppression_hides_from_listing() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 2);
        c.set_suppressed(&files[0], true).unwrap();
        let listed = c.list_dids("data18", None, None, false);
        assert_eq!(listed.len(), 1);
        let all = c.list_dids("data18", None, None, true);
        assert_eq!(all.len(), 2);
        // deep check: content listing of collections can include suppressed
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        c.attach(&ds, &files[0]).unwrap();
        assert_eq!(c.list_content(&ds, false).len(), 0);
        assert_eq!(c.list_content(&ds, true).len(), 1);
    }

    #[test]
    fn glob_listing() {
        let c = catalog();
        add_files(&c, "data18", "raw", 3);
        add_files(&c, "data18", "aod", 2);
        assert_eq!(c.list_dids("data18", Some("raw.*"), None, false).len(), 3);
        assert_eq!(c.list_dids("data18", Some("*.0001"), None, false).len(), 2);
        assert_eq!(
            c.list_dids("data18", None, Some(DidType::File), false).len(),
            5
        );
    }

    #[test]
    fn naming_schema_enforced() {
        let mut cfg = crate::common::config::Config::new();
        cfg.set("naming", "schema", "^(raw|aod)\\.[0-9]+$");
        let c = Catalog::new(crate::common::clock::Clock::sim_at(0), cfg);
        c.add_scope("data18", "root").unwrap();
        assert!(c.add_file("data18", "raw.001", "root", 1, "x", None).is_ok());
        assert!(c.add_file("data18", "freeform", "root", 1, "x", None).is_err());
    }

    #[test]
    fn metadata_round_trip_is_lexically_typed() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 1);
        c.set_metadata(&files[0], "datatype", "RAW").unwrap();
        c.set_metadata(&files[0], "run", "358031").unwrap();
        c.set_metadata(&files[0], "lumi", "13.6").unwrap();
        c.set_metadata(&files[0], "good", "true").unwrap();
        let m = c.get_metadata(&files[0]).unwrap();
        assert_eq!(m["datatype"], MetaValue::Str("RAW".into()));
        assert_eq!(m["run"], MetaValue::Int(358031));
        assert_eq!(m["lumi"], MetaValue::Float(13.6));
        assert_eq!(m["good"], MetaValue::Bool(true));
        // missing DID: error, not a clone of anything
        assert!(c.get_metadata(&DidKey::new("data18", "ghost")).is_err());
        // reserved / malformed keys and non-finite floats rejected
        assert!(c.set_metadata(&files[0], "name", "x").is_err());
        assert!(c.set_metadata(&files[0], "type", "x").is_err());
        // language keywords can never be queried → not storable either
        assert!(c.set_metadata(&files[0], "or", "x").is_err());
        assert!(c.set_metadata(&files[0], "AND", "x").is_err());
        assert!(c.set_metadata(&files[0], "not", "x").is_err());
        assert!(c.set_metadata(&files[0], "bad key", "x").is_err());
        assert!(c
            .set_metadata_typed(&files[0], "w", MetaValue::Float(f64::INFINITY))
            .is_err());
    }

    #[test]
    fn set_metadata_backfills_inverted_index() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 3);
        for f in &files {
            c.set_metadata(f, "datatype", "RAW").unwrap();
        }
        c.set_metadata(&files[1], "datatype", "AOD").unwrap(); // overwrite
        let ik = |k: &str, v: MetaValue| ("data18".to_string(), k.to_string(), v);
        let raw_key = ik("datatype", MetaValue::Str("RAW".into()));
        let aod_key = ik("datatype", MetaValue::Str("AOD".into()));
        assert_eq!(c.meta_index.get(&raw_key), vec![files[0].clone(), files[2].clone()]);
        assert_eq!(c.meta_index.get(&aod_key), vec![files[1].clone()]);
        // typed values index under distinct postings
        c.set_metadata(&files[0], "run", "3").unwrap();
        assert_eq!(c.meta_index.count(&ik("run", MetaValue::Int(3))), 1);
        assert_eq!(c.meta_index.count(&ik("run", MetaValue::Str("3".into()))), 0);
    }

    #[test]
    fn erase_did_leaves_no_stale_index_entries() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 2);
        c.set_metadata(&files[0], "datatype", "RAW").unwrap();
        c.set_metadata(&files[0], "run", "358031").unwrap();
        c.set_metadata(&files[1], "datatype", "RAW").unwrap();
        let postings_before = c.meta_index.len();
        c.erase_did(&files[0]).unwrap();
        // every posting of the erased DID is gone; the sibling's survive
        assert_eq!(c.meta_index.len(), postings_before - 2);
        let ik = |k: &str, v: MetaValue| ("data18".to_string(), k.to_string(), v);
        assert_eq!(
            c.meta_index.get(&ik("datatype", MetaValue::Str("RAW".into()))),
            vec![files[1].clone()]
        );
        assert_eq!(c.meta_index.count(&ik("run", MetaValue::Int(358031))), 0);
        // and no query can resurrect it
        let expr = crate::core::metaexpr::parse("datatype=RAW").unwrap();
        let hits = c.query_dids("data18", &expr, true);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].key, files[1]);
    }

    #[test]
    fn planner_picks_most_selective_index() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 20);
        for (i, f) in files.iter().enumerate() {
            c.set_metadata(f, "datatype", if i < 5 { "RAW" } else { "AOD" }).unwrap();
            c.set_metadata(f, "run", &(358000 + i as i64).to_string()).unwrap();
        }
        // equality on run=<one value> (1 row) beats datatype=RAW (5 rows);
        // numeric equality probes the Int/Float equality band
        let expr = crate::core::metaexpr::parse("datatype=RAW AND run=358003").unwrap();
        match c.plan_dids_query("data18", &expr) {
            QueryPlan::MetaRange { key, op: CmpOp::Eq, candidates, .. } => {
                assert_eq!(key, "run");
                assert_eq!(candidates, 1);
            }
            other => panic!("expected run-equality plan, got {other:?}"),
        }
        // string equality is an exact point probe
        let expr = crate::core::metaexpr::parse("datatype=RAW").unwrap();
        match c.plan_dids_query("data18", &expr) {
            QueryPlan::MetaEq { key, candidates, .. } => {
                assert_eq!(key, "datatype");
                assert_eq!(candidates, 5);
            }
            other => panic!("expected datatype-equality plan, got {other:?}"),
        }
        // a numeric range plan when only ordered predicates exist
        let expr = crate::core::metaexpr::parse("run>=358015").unwrap();
        match c.plan_dids_query("data18", &expr) {
            QueryPlan::MetaRange { key, candidates, .. } => {
                assert_eq!(key, "run");
                assert_eq!(candidates, 5);
            }
            other => panic!("expected range plan, got {other:?}"),
        }
        // nothing indexable: scope scan
        let expr = crate::core::metaexpr::parse("name=f.*").unwrap();
        assert_eq!(c.plan_dids_query("data18", &expr), QueryPlan::ScopeScan);
        // NOT over equality normalizes to != — not indexable either
        let expr = crate::core::metaexpr::parse("NOT datatype=RAW").unwrap();
        assert_eq!(c.plan_dids_query("data18", &expr), QueryPlan::ScopeScan);
        // cost gate: an index predicate covering most of the namespace
        // loses to the contiguous scope scan
        let expr = crate::core::metaexpr::parse("datatype=AOD").unwrap();
        assert_eq!(c.plan_dids_query("data18", &expr), QueryPlan::ScopeScan);
        assert_eq!(c.query_dids("data18", &expr, false).len(), 15);
        // results agree regardless of plan
        let expr =
            crate::core::metaexpr::parse("datatype=RAW AND run>=358002 AND run<358008").unwrap();
        let indexed = c.query_dids("data18", &expr, false);
        let scanned = c.query_dids_scan("data18", &expr.normalize(), false);
        assert_eq!(indexed, scanned);
        assert_eq!(indexed.len(), 3);
        assert!(c.metrics.counter("dids.query.indexed") >= 1);
    }

    #[test]
    fn negative_zero_round_trips_through_store_and_index() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 1);
        c.set_metadata(&files[0], "offset", "-0.0").unwrap();
        // stored canonically; indexed under the same key both zeros query
        assert_eq!(c.get_metadata(&files[0]).unwrap()["offset"], MetaValue::Float(0.0));
        for filter in ["offset=0", "offset=0.0", "offset=-0.0", "offset>=0"] {
            let expr = crate::core::metaexpr::parse(filter).unwrap();
            assert_eq!(c.query_dids("data18", &expr, false).len(), 1, "{filter}");
            assert_eq!(c.query_dids_scan("data18", &expr, false).len(), 1, "{filter}");
        }
    }

    #[test]
    fn indexed_query_stays_inside_scope() {
        let c = catalog();
        c.add_scope("mc20", "root").unwrap();
        let a = add_files(&c, "data18", "f", 2);
        c.add_file("mc20", "g.0000", "root", 1, "x", None).unwrap();
        let b = DidKey::new("mc20", "g.0000");
        for k in a.iter().chain(std::iter::once(&b)) {
            c.set_metadata(k, "datatype", "RAW").unwrap();
        }
        let expr = crate::core::metaexpr::parse("datatype=RAW").unwrap();
        assert_eq!(c.query_dids("data18", &expr, false).len(), 2);
        assert_eq!(c.query_dids("mc20", &expr, false).len(), 1);
    }

    #[test]
    fn query_dids_page_walks_filtered_results() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 30);
        for (i, f) in files.iter().enumerate() {
            c.set_metadata(f, "datatype", if i % 3 == 0 { "RAW" } else { "AOD" }).unwrap();
        }
        let expr = crate::core::metaexpr::parse("datatype=RAW").unwrap();
        // indexed plan: walk pages of 4 over the 10 RAW dids
        let mut names = Vec::new();
        let mut cursor: Option<String> = None;
        let mut pages = 0;
        loop {
            let (rows, next) = c.query_dids_page("data18", &expr, cursor.as_deref(), 4);
            names.extend(rows.into_iter().map(|d| d.key.name));
            pages += 1;
            match next {
                Some(n) => cursor = Some(n),
                None => break,
            }
            assert!(pages < 20);
        }
        let flat: Vec<String> =
            c.query_dids("data18", &expr, false).into_iter().map(|d| d.key.name).collect();
        assert_eq!(names, flat, "paged walk == flat query");
        assert_eq!(pages, 3, "10 matches / 4 per page");
        // scan plan paginates identically
        let glob = crate::core::metaexpr::parse("name=f.00*").unwrap();
        let (rows, next) = c.query_dids_page("data18", &glob, None, 5);
        assert_eq!(rows.len(), 5);
        let (rows2, next2) = c.query_dids_page("data18", &glob, next.as_deref(), 5);
        assert_eq!(rows2.len(), 5);
        assert!(next2.is_none(), "f.0000..f.0009 is exactly 10 rows");
        assert!(rows[4].key.name < rows2[0].key.name);
    }

    #[test]
    fn prop_planner_equals_scan_on_random_namespaces() {
        use crate::common::proptest::forall;
        use crate::core::metaexpr::tests::{gen_expr, gen_row};
        use crate::core::metaexpr::MetaSource;
        forall(40, |g| {
            let c = catalog();
            // random namespace: rows with random typed metadata
            for i in 0..g.usize(5, 60) {
                let r = gen_row(g);
                let name = format!("d{i:03}.{}", r.did_name());
                match r.did_type() {
                    DidType::File => {
                        c.add_file("data18", &name, "root", 1, "x", None).unwrap()
                    }
                    DidType::Dataset => c.add_dataset("data18", &name, "root").unwrap(),
                    DidType::Container => c.add_container("data18", &name, "root").unwrap(),
                }
                let key = DidKey::new("data18", &name);
                let pairs: Vec<(String, MetaValue)> = ["datatype", "run", "lumi", "good"]
                    .iter()
                    .filter_map(|k| r.meta_get(k).map(|v| (k.to_string(), v.clone())))
                    .collect();
                c.set_metadata_bulk(&key, pairs).unwrap();
            }
            // random expressions: the planner's answer must equal the scan
            for _ in 0..6 {
                let expr = gen_expr(g, 3).normalize();
                let via_planner = c.query_dids("data18", &expr, false);
                let via_scan = c.query_dids_scan("data18", &expr, false);
                assert_eq!(
                    via_planner.iter().map(|d| &d.key).collect::<Vec<_>>(),
                    via_scan.iter().map(|d| &d.key).collect::<Vec<_>>(),
                    "plan {:?} diverged from scan for '{expr}'",
                    c.plan_dids_query("data18", &expr)
                );
                // and the paged walk covers the same sequence
                let mut paged = Vec::new();
                let mut cursor: Option<String> = None;
                loop {
                    let (rows, next) = c.query_dids_page("data18", &expr, cursor.as_deref(), 7);
                    paged.extend(rows.into_iter().map(|d| d.key));
                    match next {
                        Some(n) => cursor = Some(n),
                        None => break,
                    }
                }
                let flat: Vec<DidKey> = via_planner.into_iter().map(|d| d.key).collect();
                assert_eq!(paged, flat, "paged walk == flat query for '{expr}'");
            }
        });
    }

    #[test]
    fn archive_constituents() {
        let c = catalog();
        c.add_file("data18", "archive.zip", "root", 1000, "x", None).unwrap();
        c.add_file("data18", "inner.root", "root", 400, "y", None).unwrap();
        let arch = DidKey::new("data18", "archive.zip");
        let inner = DidKey::new("data18", "inner.root");
        c.register_constituent(&arch, &inner).unwrap();
        assert_eq!(c.get_did(&inner).unwrap().constituent_of, Some(arch.clone()));
        // collections cannot be archives
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        assert!(c.register_constituent(&ds, &inner).is_err());
    }

    #[test]
    fn did_bytes_aggregates() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let files = add_files(&c, "data18", "f", 3); // 1000+1001+1002
        for f in &files {
            c.attach(&ds, f).unwrap();
        }
        assert_eq!(c.did_bytes(&ds), 3003);
    }
}
