//! Namespace operations: scopes, DIDs, attachments, metadata, archives
//! (paper §2.2).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};

use super::accounts_api::validate_name;
use super::types::*;
use super::Catalog;

/// Maximum DID name length ("limits on overall character length, e.g., to
/// reflect file system limitations", §2.2).
pub const MAX_NAME_LEN: usize = 250;

impl Catalog {
    // ------------------------------------------------------------------
    // scopes
    // ------------------------------------------------------------------

    pub fn add_scope(&self, scope: &str, account: &str) -> Result<()> {
        validate_name(scope, 30)?;
        self.get_account(account)?;
        let now = self.now();
        self.scopes.insert(
            Scope { name: scope.to_string(), account: account.to_string(), created_at: now },
            now,
        )?;
        Ok(())
    }

    pub fn get_scope(&self, scope: &str) -> Result<Scope> {
        self.scopes
            .get(&scope.to_string())
            .ok_or_else(|| RucioError::ScopeNotFound(scope.to_string()))
    }

    pub fn list_scopes(&self) -> Vec<String> {
        self.scopes.keys()
    }

    // ------------------------------------------------------------------
    // DID creation
    // ------------------------------------------------------------------

    /// Register a file DID (paper §2.2: "new files enter the system
    /// usually by registering first the file").
    #[allow(clippy::too_many_arguments)]
    pub fn add_file(
        &self,
        scope: &str,
        name: &str,
        account: &str,
        bytes: u64,
        adler32: &str,
        guid: Option<&str>,
    ) -> Result<()> {
        self.add_did_impl(scope, name, DidType::File, account, bytes, adler32, guid)
    }

    pub fn add_dataset(&self, scope: &str, name: &str, account: &str) -> Result<()> {
        self.add_did_impl(scope, name, DidType::Dataset, account, 0, "", None)
    }

    pub fn add_container(&self, scope: &str, name: &str, account: &str) -> Result<()> {
        self.add_did_impl(scope, name, DidType::Container, account, 0, "", None)
    }

    #[allow(clippy::too_many_arguments)]
    fn add_did_impl(
        &self,
        scope: &str,
        name: &str,
        did_type: DidType,
        account: &str,
        bytes: u64,
        adler32: &str,
        guid: Option<&str>,
    ) -> Result<()> {
        self.get_scope(scope)?;
        self.validate_did_name(name)?;
        let key = DidKey::new(scope, name);
        // §2.2: "a DID, once used, can never be reused to refer to anything
        // else at all, not even if the data it referred to has been deleted".
        if self.name_tombstones.contains(&key) {
            return Err(RucioError::DidAlreadyExists(format!(
                "{key} was used historically and can never be reused"
            )));
        }
        if let Some(g) = guid {
            // GUID uniqueness enforcement (§2.2).
            let clash = self
                .dids
                .scan_limit(1, |d| d.guid.as_deref() == Some(g));
            if !clash.is_empty() {
                return Err(RucioError::Duplicate(format!("guid {g} already registered")));
            }
        }
        let now = self.now();
        let is_coll = did_type.is_collection();
        self.dids.insert(
            Did {
                key,
                did_type,
                account: account.to_string(),
                bytes,
                adler32: adler32.to_string(),
                md5: None,
                guid: guid.map(|s| s.to_string()),
                open: is_coll, // collections are created open (§2.2)
                monotonic: false,
                suppressed: false,
                availability: if is_coll {
                    Availability::Available
                } else {
                    Availability::Deleted // no replicas yet
                },
                meta: BTreeMap::new(),
                created_at: now,
                expired_at: None,
                constituent_of: None,
            },
            now,
        )?;
        self.metrics.incr("dids.added", 1);
        if is_coll {
            // Subscription matching is asynchronous: the judge-injector
            // consumes this event (upstream transmogrifier, §2.5).
            self.notify(
                "did-created",
                crate::jsonx::Json::obj()
                    .with("scope", scope)
                    .with("name", name)
                    .with("did_type", did_type.as_str()),
            );
        }
        Ok(())
    }

    /// Naming convention enforcement (§2.2): length plus an optional
    /// configured regex schema (`naming.schema` config key).
    fn validate_did_name(&self, name: &str) -> Result<()> {
        if name.is_empty() || name.len() > MAX_NAME_LEN {
            return Err(RucioError::InvalidObject(format!(
                "DID name length must be 1..={MAX_NAME_LEN}"
            )));
        }
        if !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '/' | '+'))
        {
            return Err(RucioError::InvalidObject(format!("invalid characters in '{name}'")));
        }
        if let Some(pattern) = self.cfg.get("naming", "schema") {
            let re = regex::Regex::new(pattern)
                .map_err(|e| RucioError::ConfigError(format!("naming.schema: {e}")))?;
            if !re.is_match(name) {
                return Err(RucioError::InvalidObject(format!(
                    "name '{name}' violates naming schema"
                )));
            }
        }
        Ok(())
    }

    pub fn get_did(&self, key: &DidKey) -> Result<Did> {
        self.dids
            .get(key)
            .ok_or_else(|| RucioError::DidNotFound(key.to_string()))
    }

    // ------------------------------------------------------------------
    // hierarchy (Fig 1)
    // ------------------------------------------------------------------

    /// Attach `child` to collection `parent`. Containers hold collections;
    /// datasets hold files (Fig 1). Returns the set of *file* DIDs newly
    /// reachable (rule engine extends covering rules over them).
    pub fn attach(&self, parent: &DidKey, child: &DidKey) -> Result<Vec<DidKey>> {
        let p = self.get_did(parent)?;
        let c = self.get_did(child)?;
        match (p.did_type, c.did_type) {
            (DidType::Dataset, DidType::File) => {}
            (DidType::Container, DidType::Dataset) | (DidType::Container, DidType::Container) => {}
            _ => {
                return Err(RucioError::UnsupportedOperation(format!(
                    "cannot attach {} to {}",
                    c.did_type.as_str(),
                    p.did_type.as_str()
                )))
            }
        }
        if !p.open {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is closed"
            )));
        }
        if parent == child || self.is_ancestor(child, parent) {
            return Err(RucioError::UnsupportedOperation(format!(
                "attaching {child} to {parent} would create a cycle"
            )));
        }
        let now = self.now();
        self.attachments.insert(
            Attachment { parent: parent.clone(), child: child.clone(), created_at: now },
            now,
        )?;
        self.metrics.incr("dids.attached", 1);
        let files = self.resolve_files(child);
        // Rule engine hook: extend rules covering `parent` (and ancestors).
        self.on_content_added(parent, &files)?;
        Ok(files.into_iter().map(|f| f.key).collect())
    }

    /// Detach `child` from `parent` (only open, non-monotonic parents;
    /// §2.2: "if the monotonic attribute is set, content cannot be removed
    /// from an open collection").
    pub fn detach(&self, parent: &DidKey, child: &DidKey) -> Result<()> {
        let p = self.get_did(parent)?;
        if !p.open {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is closed"
            )));
        }
        if p.monotonic {
            return Err(RucioError::UnsupportedOperation(format!(
                "collection {parent} is monotonic"
            )));
        }
        let now = self.now();
        if self
            .attachments
            .remove(&(parent.clone(), child.clone()), now)
            .is_none()
        {
            return Err(RucioError::DidNotFound(format!("{child} not attached to {parent}")));
        }
        let files = self.resolve_files(child);
        self.on_content_removed(parent, &files)?;
        self.metrics.incr("dids.detached", 1);
        Ok(())
    }

    fn is_ancestor(&self, maybe_ancestor: &DidKey, of: &DidKey) -> bool {
        let mut queue = VecDeque::from([of.clone()]);
        let mut seen = BTreeSet::new();
        while let Some(cur) = queue.pop_front() {
            for (parent, _) in self
                .att_by_child
                .get(&cur)
                .into_iter()
                .map(|(p, c)| (p, c))
            {
                if &parent == maybe_ancestor {
                    return true;
                }
                if seen.insert(parent.clone()) {
                    queue.push_back(parent);
                }
            }
        }
        false
    }

    /// Direct children of a collection.
    pub fn list_content(&self, parent: &DidKey, include_suppressed: bool) -> Vec<Did> {
        self.att_by_parent
            .get(parent)
            .into_iter()
            .filter_map(|(_, child)| self.dids.get(&child))
            .filter(|d| include_suppressed || !d.suppressed)
            .collect()
    }

    /// All *file* DIDs reachable from a DID (BFS through the hierarchy) —
    /// the unit the rule engine operates on. Files include themselves.
    pub fn resolve_files(&self, did: &DidKey) -> Vec<Did> {
        let mut files = Vec::new();
        let mut seen = BTreeSet::new();
        let mut queue = VecDeque::from([did.clone()]);
        while let Some(cur) = queue.pop_front() {
            if !seen.insert(cur.clone()) {
                continue;
            }
            let Some(d) = self.dids.get(&cur) else { continue };
            if d.did_type == DidType::File {
                files.push(d);
            } else {
                for (_, child) in self.att_by_parent.get(&cur) {
                    queue.push_back(child);
                }
            }
        }
        files
    }

    /// Direct parents of a DID.
    pub fn list_parents(&self, did: &DidKey) -> Vec<DidKey> {
        self.att_by_child
            .get(did)
            .into_iter()
            .map(|(parent, _)| parent)
            .collect()
    }

    /// All ancestors (transitive parents) of a DID, nearest first.
    pub fn ancestors(&self, did: &DidKey) -> Vec<DidKey> {
        let mut out = Vec::new();
        let mut seen = BTreeSet::new();
        let mut queue = VecDeque::from([did.clone()]);
        while let Some(cur) = queue.pop_front() {
            for (parent, _) in self.att_by_child.get(&cur) {
                if seen.insert(parent.clone()) {
                    out.push(parent.clone());
                    queue.push_back(parent);
                }
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // collection status (§2.2)
    // ------------------------------------------------------------------

    /// Close a collection ("once closed they cannot be opened again").
    pub fn close(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !d.did_type.is_collection() {
            return Err(RucioError::UnsupportedOperation("cannot close a file".into()));
        }
        self.dids.update(did, self.now(), |d| d.open = false);
        Ok(())
    }

    /// Set monotonic (one-way; "once set to monotonic, this cannot be
    /// reversed").
    pub fn set_monotonic(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !d.did_type.is_collection() {
            return Err(RucioError::UnsupportedOperation("files cannot be monotonic".into()));
        }
        self.dids.update(did, self.now(), |d| d.monotonic = true);
        Ok(())
    }

    /// Suppression flag (§2.2): hidden from default listings.
    pub fn set_suppressed(&self, did: &DidKey, suppressed: bool) -> Result<()> {
        self.get_did(did)?;
        self.dids.update(did, self.now(), |d| d.suppressed = suppressed);
        Ok(())
    }

    /// A collection is *complete* when every reachable file has at least
    /// one available replica (derived attribute, §2.2).
    pub fn is_complete(&self, did: &DidKey) -> Result<bool> {
        self.get_did(did)?;
        Ok(self
            .resolve_files(did)
            .iter()
            .all(|f| f.availability == Availability::Available))
    }

    /// Aggregate byte size of all reachable files.
    pub fn did_bytes(&self, did: &DidKey) -> u64 {
        self.resolve_files(did).iter().map(|f| f.bytes).sum()
    }

    // ------------------------------------------------------------------
    // metadata (§2.2)
    // ------------------------------------------------------------------

    pub fn set_metadata(&self, did: &DidKey, key: &str, value: &str) -> Result<()> {
        self.get_did(did)?;
        self.dids.update(did, self.now(), |d| {
            d.meta.insert(key.to_string(), value.to_string());
        });
        Ok(())
    }

    pub fn get_metadata(&self, did: &DidKey) -> Result<BTreeMap<String, String>> {
        Ok(self.get_did(did)?.meta)
    }

    /// DID lifetime: the undertaker removes DIDs past expiry.
    pub fn set_did_expiry(&self, did: &DidKey, expired_at: Option<EpochMs>) -> Result<()> {
        self.get_did(did)?;
        self.dids.update(did, self.now(), |d| d.expired_at = expired_at);
        Ok(())
    }

    // ------------------------------------------------------------------
    // listing / search
    // ------------------------------------------------------------------

    /// List DIDs in a scope, optionally filtered by a name glob (`*`
    /// wildcard) and type. Suppressed DIDs are hidden (§2.2) unless asked.
    pub fn list_dids(
        &self,
        scope: &str,
        name_glob: Option<&str>,
        did_type: Option<DidType>,
        include_suppressed: bool,
    ) -> Vec<Did> {
        let re = name_glob.map(glob_to_regex);
        self.dids.scan(|d| {
            d.key.scope == scope
                && (include_suppressed || !d.suppressed)
                && did_type.map(|t| d.did_type == t).unwrap_or(true)
                && re.as_ref().map(|r| r.is_match(&d.key.name)).unwrap_or(true)
        })
    }

    /// One page of a scope's DIDs in name order (cursor-based listing for
    /// the NDJSON REST routes): rows strictly after `after_name`, plus
    /// the cursor for the next page (`None` once exhausted). O(page), not
    /// O(scope): the scope's keys are contiguous in the ordered table.
    pub fn list_dids_page(
        &self,
        scope: &str,
        after_name: Option<&str>,
        limit: usize,
    ) -> (Vec<Did>, Option<String>) {
        use std::ops::Bound;
        let lo_key = DidKey::new(scope, after_name.unwrap_or(""));
        // First key of the next scope: "<scope>\0" sorts after <scope> and
        // before any longer sibling, so it bounds this scope exactly.
        let hi_key = DidKey { scope: format!("{scope}\u{0}"), name: String::new() };
        let page = self
            .dids
            .range_page(Bound::Excluded(&lo_key), Bound::Excluded(&hi_key), limit);
        let next = page.next_cursor.map(|k| k.name);
        (page.rows, next)
    }

    // ------------------------------------------------------------------
    // deletion (undertaker path)
    // ------------------------------------------------------------------

    /// Remove a DID from the namespace, writing a permanent name
    /// tombstone. Callers (undertaker) must have removed rules first.
    pub fn erase_did(&self, did: &DidKey) -> Result<()> {
        let d = self.get_did(did)?;
        if !self.rules_by_did.get(did).is_empty() {
            return Err(RucioError::UnsupportedOperation(format!(
                "{did} still has rules"
            )));
        }
        let now = self.now();
        // Detach from parents and drop own attachment edges.
        for (parent, child) in self.att_by_child.get(did) {
            self.attachments.remove(&(parent, child), now);
        }
        for (parent, child) in self.att_by_parent.get(did) {
            self.attachments.remove(&(parent, child), now);
        }
        self.dids.remove(did, now);
        let _ = self.name_tombstones.insert(
            NameTombstone { key: did.clone(), deleted_at: now },
            now,
        );
        self.metrics.incr("dids.erased", 1);
        let _ = d;
        Ok(())
    }

    // ------------------------------------------------------------------
    // archives (§2.2)
    // ------------------------------------------------------------------

    /// Register `constituent` as content of archive file `archive` (e.g.
    /// a ZIP). Resolving replicas of the constituent will use the
    /// archive's replicas.
    pub fn register_constituent(&self, archive: &DidKey, constituent: &DidKey) -> Result<()> {
        let a = self.get_did(archive)?;
        let c = self.get_did(constituent)?;
        if a.did_type != DidType::File || c.did_type != DidType::File {
            return Err(RucioError::UnsupportedOperation(
                "archives and constituents must be files".into(),
            ));
        }
        self.dids.update(constituent, self.now(), |d| {
            d.constituent_of = Some(archive.clone())
        });
        Ok(())
    }
}

fn glob_to_regex(glob: &str) -> regex::Regex {
    let mut pattern = String::from("^");
    for c in glob.chars() {
        match c {
            '*' => pattern.push_str(".*"),
            '?' => pattern.push('.'),
            c if "\\.+()[]{}|^$".contains(c) => {
                pattern.push('\\');
                pattern.push(c);
            }
            c => pattern.push(c),
        }
    }
    pattern.push('$');
    regex::Regex::new(&pattern).unwrap_or_else(|_| regex::Regex::new("^$").unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Catalog;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        c.add_account("alice", AccountType::User, "a@x").unwrap();
        c.add_scope("data18", "root").unwrap();
        c
    }

    fn add_files(c: &Catalog, scope: &str, prefix: &str, n: usize) -> Vec<DidKey> {
        (0..n)
            .map(|i| {
                let name = format!("{prefix}.{i:04}");
                c.add_file(scope, &name, "root", 1000 + i as u64, "aabbccdd", None)
                    .unwrap();
                DidKey::new(scope, &name)
            })
            .collect()
    }

    #[test]
    fn list_dids_page_walks_scope_in_order() {
        let c = catalog();
        c.add_scope("other", "root").unwrap();
        add_files(&c, "data18", "f", 25);
        add_files(&c, "other", "g", 5); // must never leak into data18 pages
        let mut names = Vec::new();
        let mut cursor: Option<String> = None;
        let mut pages = 0;
        loop {
            let (rows, next) = c.list_dids_page("data18", cursor.as_deref(), 10);
            assert!(rows.iter().all(|d| d.key.scope == "data18"));
            names.extend(rows.into_iter().map(|d| d.key.name));
            pages += 1;
            match next {
                Some(n) => cursor = Some(n),
                None => break,
            }
            assert!(pages < 50);
        }
        let expect: Vec<String> = (0..25).map(|i| format!("f.{i:04}")).collect();
        assert_eq!(names, expect, "paged walk is complete + name-ordered");
        assert_eq!(pages, 3);
        // empty scope: one empty page
        c.add_scope("empty", "root").unwrap();
        let (rows, next) = c.list_dids_page("empty", None, 10);
        assert!(rows.is_empty() && next.is_none());
    }

    #[test]
    fn fig1_hierarchy() {
        // Reproduce the paper's Fig 1 shape: containers of containers of
        // datasets of files, with overlap.
        let c = catalog();
        c.add_container("data18", "experiment", "root").unwrap();
        c.add_container("data18", "detector_data", "root").unwrap();
        c.add_dataset("data18", "dataset_f5f6", "root").unwrap();
        let files = add_files(&c, "data18", "f", 2);
        let exp = DidKey::new("data18", "experiment");
        let det = DidKey::new("data18", "detector_data");
        let ds = DidKey::new("data18", "dataset_f5f6");
        c.attach(&exp, &det).unwrap();
        c.attach(&det, &ds).unwrap();
        c.attach(&ds, &files[0]).unwrap();
        c.attach(&ds, &files[1]).unwrap();
        // Alice's analysis dataset shares F6 (overlapping DIDs).
        c.add_dataset("user.alice", "alices_analysis", "alice").unwrap();
        let ana = DidKey::new("user.alice", "alices_analysis");
        c.attach(&ana, &files[1]).unwrap();

        let resolved = c.resolve_files(&exp);
        assert_eq!(resolved.len(), 2);
        assert_eq!(c.resolve_files(&ana).len(), 1);
        assert_eq!(c.list_parents(&files[1]).len(), 2);
        let anc = c.ancestors(&files[0]);
        assert!(anc.contains(&exp) && anc.contains(&det) && anc.contains(&ds));
    }

    #[test]
    fn type_rules_enforced() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        c.add_container("data18", "cont", "root").unwrap();
        let files = add_files(&c, "data18", "f", 1);
        let ds = DidKey::new("data18", "ds");
        let cont = DidKey::new("data18", "cont");
        // dataset cannot hold datasets; container cannot hold files
        assert!(c.attach(&cont, &files[0]).is_err());
        assert!(c.attach(&files[0], &ds).is_err());
        assert!(c.attach(&ds, &cont).is_err());
        // legal edges
        c.attach(&ds, &files[0]).unwrap();
        c.attach(&cont, &ds).unwrap();
    }

    #[test]
    fn cycles_rejected() {
        let c = catalog();
        c.add_container("data18", "a", "root").unwrap();
        c.add_container("data18", "b", "root").unwrap();
        let a = DidKey::new("data18", "a");
        let b = DidKey::new("data18", "b");
        c.attach(&a, &b).unwrap();
        assert!(c.attach(&b, &a).is_err());
        assert!(c.attach(&a, &a).is_err());
    }

    #[test]
    fn closed_and_monotonic_flags() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let files = add_files(&c, "data18", "f", 3);
        c.attach(&ds, &files[0]).unwrap();
        // monotonic prevents detach but allows attach
        c.set_monotonic(&ds).unwrap();
        c.attach(&ds, &files[1]).unwrap();
        assert!(c.detach(&ds, &files[0]).is_err());
        // closed prevents attach
        c.close(&ds).unwrap();
        assert!(c.attach(&ds, &files[2]).is_err());
        assert!(c.detach(&ds, &files[0]).is_err());
    }

    #[test]
    fn names_are_forever() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 1);
        c.erase_did(&files[0]).unwrap();
        // §2.2: the name can never be reused.
        assert!(c
            .add_file("data18", "f.0000", "root", 1, "00000000", None)
            .is_err());
    }

    #[test]
    fn guid_uniqueness() {
        let c = catalog();
        c.add_file("data18", "g1", "root", 1, "x", Some("GUID-123")).unwrap();
        assert!(c.add_file("data18", "g2", "root", 1, "x", Some("GUID-123")).is_err());
        c.add_file("data18", "g3", "root", 1, "x", Some("GUID-456")).unwrap();
    }

    #[test]
    fn suppression_hides_from_listing() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 2);
        c.set_suppressed(&files[0], true).unwrap();
        let listed = c.list_dids("data18", None, None, false);
        assert_eq!(listed.len(), 1);
        let all = c.list_dids("data18", None, None, true);
        assert_eq!(all.len(), 2);
        // deep check: content listing of collections can include suppressed
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        c.attach(&ds, &files[0]).unwrap();
        assert_eq!(c.list_content(&ds, false).len(), 0);
        assert_eq!(c.list_content(&ds, true).len(), 1);
    }

    #[test]
    fn glob_listing() {
        let c = catalog();
        add_files(&c, "data18", "raw", 3);
        add_files(&c, "data18", "aod", 2);
        assert_eq!(c.list_dids("data18", Some("raw.*"), None, false).len(), 3);
        assert_eq!(c.list_dids("data18", Some("*.0001"), None, false).len(), 2);
        assert_eq!(
            c.list_dids("data18", None, Some(DidType::File), false).len(),
            5
        );
    }

    #[test]
    fn naming_schema_enforced() {
        let mut cfg = crate::common::config::Config::new();
        cfg.set("naming", "schema", "^(raw|aod)\\.[0-9]+$");
        let c = Catalog::new(crate::common::clock::Clock::sim_at(0), cfg);
        c.add_scope("data18", "root").unwrap();
        assert!(c.add_file("data18", "raw.001", "root", 1, "x", None).is_ok());
        assert!(c.add_file("data18", "freeform", "root", 1, "x", None).is_err());
    }

    #[test]
    fn metadata_round_trip() {
        let c = catalog();
        let files = add_files(&c, "data18", "f", 1);
        c.set_metadata(&files[0], "datatype", "RAW").unwrap();
        c.set_metadata(&files[0], "run", "358031").unwrap();
        let m = c.get_metadata(&files[0]).unwrap();
        assert_eq!(m["datatype"], "RAW");
        assert_eq!(m["run"], "358031");
    }

    #[test]
    fn archive_constituents() {
        let c = catalog();
        c.add_file("data18", "archive.zip", "root", 1000, "x", None).unwrap();
        c.add_file("data18", "inner.root", "root", 400, "y", None).unwrap();
        let arch = DidKey::new("data18", "archive.zip");
        let inner = DidKey::new("data18", "inner.root");
        c.register_constituent(&arch, &inner).unwrap();
        assert_eq!(c.get_did(&inner).unwrap().constituent_of, Some(arch.clone()));
        // collections cannot be archives
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        assert!(c.register_constituent(&ds, &inner).is_err());
    }

    #[test]
    fn did_bytes_aggregates() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let files = add_files(&c, "data18", "f", 3); // 1000+1001+1002
        for f in &files {
            c.attach(&ds, f).unwrap();
        }
        assert_eq!(c.did_bytes(&ds), 3003);
    }
}
