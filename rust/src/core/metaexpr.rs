//! The DID metadata filter language, `meta-expr` (paper §2.2 metadata +
//! §2.5 subscription filters): typed comparisons over a DID's metadata
//! map, glob matching on the DID name, and type selection, combined with
//! `AND` / `OR` / `NOT`.
//!
//! Grammar (recursive descent):
//! ```text
//! expr   := and ('OR' and)*
//! and    := not ('AND' not)*
//! not    := 'NOT' not | atom
//! atom   := '(' expr ')' | '*'
//!         | 'name' ('='|'!=') GLOB           DID-name glob (* and ?)
//!         | 'type' ('='|'!=') DIDTYPE        FILE | DATASET | CONTAINER
//!         | IDENT op VALUE                   typed metadata comparison
//! op     := '=' | '!=' | '<' | '<=' | '>' | '>='
//! VALUE  := WORD | "quoted string"           lexically typed (see below)
//! ```
//! `&`, `|` and `!` are accepted as operator spellings; the canonical
//! printer emits the word forms, fully parenthesized, so
//! `parse(print(e)) == e` (property-tested below).
//!
//! Values are *typed* ([`MetaValue`]): a bare `true`/`false` is a bool,
//! `358031` an integer, `13.6` a float, anything else (or any quoted
//! value) a string. Ordered comparisons (`<` `<=` `>` `>=`) require a
//! numeric literal and only match numeric stored values; equality is
//! value-based across int/float (`run=13` ≡ `run=13.0`) and type-exact
//! otherwise. A comparison on a missing key never matches — except
//! `!=`, which treats "absent" as "not equal".

use std::cmp::Ordering;
use std::ops::Bound;

use crate::common::error::{Result, RucioError};

use super::types::DidType;

// ---------------------------------------------------------------------
// typed metadata values
// ---------------------------------------------------------------------

/// A typed metadata value. The total order groups values as
/// bool < numeric < string; integers and floats order *numerically*
/// against each other (so one inverted-index range covers a mixed-typed
/// numeric key), with `Int(n) < Float(n as f64)` breaking exact ties —
/// equality therefore stays type-exact.
#[derive(Debug, Clone)]
pub enum MetaValue {
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
}

impl MetaValue {
    /// Parse a raw string the way the REST/CLI surface does: lexical
    /// typing. `"true"`/`"false"` → bool; an `i64` → int; a finite
    /// numeric literal → float; everything else → string.
    pub fn parse_lexical(s: &str) -> MetaValue {
        match s {
            "true" => return MetaValue::Bool(true),
            "false" => return MetaValue::Bool(false),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return MetaValue::Int(i);
        }
        // Guard the float path against `inf` / `nan` spellings (Rust's
        // f64 parser accepts them; the catalog stores only finite floats).
        if s.chars().all(|c| c.is_ascii_digit() || matches!(c, '+' | '-' | '.' | 'e' | 'E')) {
            if let Ok(f) = s.parse::<f64>() {
                if f.is_finite() {
                    return MetaValue::Float(canonical_f64(f));
                }
            }
        }
        MetaValue::Str(s.to_string())
    }

    pub fn is_numeric(&self) -> bool {
        matches!(self, MetaValue::Int(_) | MetaValue::Float(_))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            MetaValue::Int(i) => Some(*i as f64),
            MetaValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            MetaValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            MetaValue::Bool(_) => "bool",
            MetaValue::Int(_) => "int",
            MetaValue::Float(_) => "float",
            MetaValue::Str(_) => "str",
        }
    }

    /// The smallest value that is numerically equal to `f` under the
    /// MetaValue order (`Int(n)` sorts before `Float(n)`): the inclusive
    /// lower bound for `>=` / exclusive upper bound for `<` index ranges.
    fn numeric_floor(f: f64) -> MetaValue {
        if f.fract() == 0.0 && (i64::MIN as f64..=i64::MAX as f64).contains(&f) {
            MetaValue::Int(f as i64)
        } else {
            MetaValue::Float(f)
        }
    }

    /// Index-range bounds over the numeric band for `key OP v`, expressed
    /// in the MetaValue total order. Evaluation uses the *same* bounds
    /// ([`CmpOp::ord_matches`] / [`MetaValue::eq_matches`]), so a planner
    /// range lookup and a full scan agree on every row by construction.
    /// `Eq` yields the *equality band* `[Int(f), Float(f)]` — both typed
    /// representations of one numeric value (so `run=13` and `run=13.0`
    /// find the same rows regardless of which surface wrote them).
    pub fn numeric_band(op: CmpOp, v: &MetaValue) -> Option<(Bound<MetaValue>, Bound<MetaValue>)> {
        // `-0.0` must collapse to `0.0` here: the total order separates
        // them (total_cmp), so an uncanonicalized `-0.0` would build an
        // inverted Eq band (`Int(0) > Float(-0.0)`) and panic the
        // planner's BTreeMap range. Storage canonicalizes too; this
        // covers programmatically built expressions.
        let f = canonical_f64(v.as_f64()?);
        // All finite numerics sort within [Float(-inf), Float(+inf)].
        let lo_all = Bound::Included(MetaValue::Float(f64::NEG_INFINITY));
        let hi_all = Bound::Included(MetaValue::Float(f64::INFINITY));
        Some(match op {
            CmpOp::Ge => (Bound::Included(MetaValue::numeric_floor(f)), hi_all),
            // `Float(f)` is the largest value numerically equal to f, so
            // excluding it starts strictly above the whole equality band.
            CmpOp::Gt => (Bound::Excluded(MetaValue::Float(f)), hi_all),
            CmpOp::Le => (lo_all, Bound::Included(MetaValue::Float(f))),
            CmpOp::Lt => (lo_all, Bound::Excluded(MetaValue::numeric_floor(f))),
            CmpOp::Eq => {
                let mut lo = MetaValue::numeric_floor(f);
                // An exact i64 beyond 2^53 may round *up* into `f`; the
                // query's own integer must still sit inside its equality
                // band, so widen the lower bound down to it.
                if let (MetaValue::Int(i), MetaValue::Int(j)) = (v, &lo) {
                    if i < j {
                        lo = MetaValue::Int(*i);
                    }
                }
                (Bound::Included(lo), Bound::Included(MetaValue::Float(f)))
            }
            CmpOp::Ne => return None,
        })
    }

    /// Equality semantics of the language: numerics compare by *exact*
    /// value across `Int`/`Float` (the two typings of `13` are one
    /// number, and i64s beyond f64's 2^53 integer precision never
    /// conflate with their neighbors); everything else is type-exact.
    /// The `Eq` index band is a superset of this relation — both
    /// executors re-evaluate candidates with this exact test, so band
    /// over-inclusion is filtered identically and planner≡scan holds.
    pub fn eq_matches(&self, other: &MetaValue) -> bool {
        use MetaValue::*;
        match (self, other) {
            (Int(a), Int(b)) => a == b,
            (Float(a), Float(b)) => a == b,
            (Int(a), Float(b)) | (Float(b), Int(a)) => {
                // exact cross-type equality: the float must be an
                // integer, inside i64 range, and convert to exactly `a`
                b.fract() == 0.0
                    && (i64::MIN as f64..i64::MAX as f64).contains(b)
                    && *b as i64 == *a
            }
            _ => self == other,
        }
    }

    fn within(&self, lo: &Bound<MetaValue>, hi: &Bound<MetaValue>) -> bool {
        let above = match lo {
            Bound::Included(b) => *self >= *b,
            Bound::Excluded(b) => *self > *b,
            Bound::Unbounded => true,
        };
        let below = match hi {
            Bound::Included(b) => *self <= *b,
            Bound::Excluded(b) => *self < *b,
            Bound::Unbounded => true,
        };
        above && below
    }
}

impl Ord for MetaValue {
    fn cmp(&self, other: &Self) -> Ordering {
        use MetaValue::*;
        let class = |v: &MetaValue| match v {
            Bool(_) => 0u8,
            Int(_) | Float(_) => 1,
            Str(_) => 2,
        };
        match (self, other) {
            (Bool(a), Bool(b)) => a.cmp(b),
            (Int(a), Int(b)) => a.cmp(b),
            (Str(a), Str(b)) => a.cmp(b),
            (Float(a), Float(b)) => a.total_cmp(b),
            (Int(a), Float(b)) => (*a as f64).total_cmp(b).then(Ordering::Less),
            (Float(a), Int(b)) => a.total_cmp(&(*b as f64)).then(Ordering::Greater),
            (a, b) => class(a).cmp(&class(b)),
        }
    }
}

impl PartialOrd for MetaValue {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for MetaValue {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for MetaValue {}

impl std::fmt::Display for MetaValue {
    /// Canonical value printing: re-parsing the printed form with
    /// [`MetaValue::parse_lexical`] (bare) or the expression lexer
    /// (quoted) yields the same typed value.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetaValue::Bool(b) => write!(f, "{b}"),
            MetaValue::Int(i) => write!(f, "{i}"),
            MetaValue::Float(x) => {
                if x.fract() == 0.0 {
                    write!(f, "{x:.1}") // keep the dot so it re-parses as float
                } else {
                    write!(f, "{x}")
                }
            }
            MetaValue::Str(s) => {
                if is_bare_word(s) && matches!(MetaValue::parse_lexical(s), MetaValue::Str(_)) {
                    write!(f, "{s}")
                } else {
                    // quoted: always a string, whatever the content
                    write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
                }
            }
        }
    }
}

/// Collapse `-0.0` to `0.0` — the two are numerically equal but
/// distinct under `f64::total_cmp`, and the index order must agree with
/// the equality semantics. Every storage and parse entry point runs
/// floats through this.
pub(crate) fn canonical_f64(f: f64) -> f64 {
    if f == 0.0 {
        0.0
    } else {
        f
    }
}

/// Word characters the lexer accepts in a bare (unquoted) value.
fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.' | '/' | '+' | ':' | '*' | '?')
}

fn is_bare_word(s: &str) -> bool {
    !s.is_empty() && s.chars().all(is_word_char) && !is_keyword(s)
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s.to_ascii_uppercase().as_str(),
        "AND" | "OR" | "NOT"
    )
}

/// Can `key` appear on the left of a comparison? The virtual keys
/// (`name`, `type`) and the language keywords are reserved — a stored
/// pair under such a key could never be queried (the lexer would read
/// `or=x` as an operator) and would break the canonical printer's
/// parse∘print contract. `set_metadata` enforces this at write time.
pub fn is_reserved_key(key: &str) -> bool {
    is_keyword(key) || matches!(key, "name" | "type")
}

// ---------------------------------------------------------------------
// AST
// ---------------------------------------------------------------------

/// Comparison operators of the language.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

impl CmpOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            CmpOp::Eq => "=",
            CmpOp::Ne => "!=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        }
    }

    /// Ordered-comparison semantics shared by the scan evaluator and the
    /// index planner: non-numeric stored values never match; numeric
    /// values match iff they fall inside [`MetaValue::numeric_band`].
    pub fn ord_matches(&self, actual: &MetaValue, v: &MetaValue) -> bool {
        if !actual.is_numeric() {
            return false;
        }
        match MetaValue::numeric_band(*self, v) {
            Some((lo, hi)) => actual.within(&lo, &hi),
            None => false,
        }
    }
}

/// A parsed `meta-expr`.
#[derive(Debug, Clone, PartialEq)]
pub enum MetaExpr {
    /// `*` — matches every DID.
    Any,
    /// `name=<glob>`: glob over the DID name (`*` and `?`).
    NameGlob(String),
    /// `type=FILE|DATASET|CONTAINER`.
    TypeIs(DidType),
    /// `key OP value` over the typed metadata map.
    Cmp(String, CmpOp, MetaValue),
    Not(Box<MetaExpr>),
    And(Box<MetaExpr>, Box<MetaExpr>),
    Or(Box<MetaExpr>, Box<MetaExpr>),
}

impl std::fmt::Display for MetaExpr {
    /// Canonical printer: word operators, fully parenthesized compounds —
    /// unambiguous, and a fixpoint of `print ∘ parse`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetaExpr::Any => write!(f, "*"),
            MetaExpr::NameGlob(g) => write!(f, "name={g}"),
            MetaExpr::TypeIs(t) => write!(f, "type={}", t.as_str()),
            MetaExpr::Cmp(k, op, v) => write!(f, "{k}{}{v}", op.as_str()),
            MetaExpr::Not(e) => write!(f, "NOT {e}"),
            MetaExpr::And(a, b) => write!(f, "({a} AND {b})"),
            MetaExpr::Or(a, b) => write!(f, "({a} OR {b})"),
        }
    }
}

// ---------------------------------------------------------------------
// lexer + parser
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Word(String),          // bare identifier or value
    Quoted(String),        // "..." — always a string value
    Op(CmpOp),
    And,
    Or,
    Not,
    LParen,
    RParen,
    Star,
}

fn lex(input: &str) -> Result<Vec<Tok>> {
    let bytes: Vec<char> = input.chars().collect();
    let mut toks = Vec::new();
    let mut i = 0;
    let err = |i: usize, what: &str| {
        RucioError::InvalidMetaExpression(format!("{what} at {i} in '{input}'"))
    };
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            '(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            '&' => {
                toks.push(Tok::And);
                i += 1;
            }
            '|' => {
                toks.push(Tok::Or);
                i += 1;
            }
            '=' => {
                toks.push(Tok::Op(CmpOp::Eq));
                i += 1;
            }
            '!' => {
                if bytes.get(i + 1) == Some(&'=') {
                    toks.push(Tok::Op(CmpOp::Ne));
                    i += 2;
                } else {
                    toks.push(Tok::Not);
                    i += 1;
                }
            }
            '<' => {
                if bytes.get(i + 1) == Some(&'=') {
                    toks.push(Tok::Op(CmpOp::Le));
                    i += 2;
                } else {
                    toks.push(Tok::Op(CmpOp::Lt));
                    i += 1;
                }
            }
            '>' => {
                if bytes.get(i + 1) == Some(&'=') {
                    toks.push(Tok::Op(CmpOp::Ge));
                    i += 2;
                } else {
                    toks.push(Tok::Op(CmpOp::Gt));
                    i += 1;
                }
            }
            '"' => {
                let mut s = String::new();
                i += 1;
                loop {
                    match bytes.get(i) {
                        None => return Err(err(i, "unterminated quote")),
                        Some('"') => {
                            i += 1;
                            break;
                        }
                        Some('\\') => match bytes.get(i + 1) {
                            Some(&e) => {
                                s.push(e);
                                i += 2;
                            }
                            None => return Err(err(i, "trailing backslash")),
                        },
                        Some(&ch) => {
                            s.push(ch);
                            i += 1;
                        }
                    }
                }
                toks.push(Tok::Quoted(s));
            }
            c if is_word_char(c) => {
                let start = i;
                while i < bytes.len() && is_word_char(bytes[i]) {
                    i += 1;
                }
                let word: String = bytes[start..i].iter().collect();
                match word.to_ascii_uppercase().as_str() {
                    "AND" => toks.push(Tok::And),
                    "OR" => toks.push(Tok::Or),
                    "NOT" => toks.push(Tok::Not),
                    _ if word == "*" => toks.push(Tok::Star),
                    _ => toks.push(Tok::Word(word)),
                }
            }
            other => return Err(err(i, &format!("unexpected character '{other}'"))),
        }
    }
    Ok(toks)
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
    input: String,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: &str) -> RucioError {
        RucioError::InvalidMetaExpression(format!("{msg} in '{}'", self.input))
    }

    fn expr(&mut self) -> Result<MetaExpr> {
        let mut left = self.and()?;
        while self.peek() == Some(&Tok::Or) {
            self.next();
            let right = self.and()?;
            left = MetaExpr::Or(Box::new(left), Box::new(right));
        }
        Ok(left)
    }

    fn and(&mut self) -> Result<MetaExpr> {
        let mut left = self.not()?;
        while self.peek() == Some(&Tok::And) {
            self.next();
            let right = self.not()?;
            left = MetaExpr::And(Box::new(left), Box::new(right));
        }
        Ok(left)
    }

    fn not(&mut self) -> Result<MetaExpr> {
        if self.peek() == Some(&Tok::Not) {
            self.next();
            return Ok(MetaExpr::Not(Box::new(self.not()?)));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<MetaExpr> {
        match self.next() {
            Some(Tok::LParen) => {
                let e = self.expr()?;
                if self.next() != Some(Tok::RParen) {
                    return Err(self.err("missing ')'"));
                }
                Ok(e)
            }
            Some(Tok::Star) => Ok(MetaExpr::Any),
            Some(Tok::Word(key)) => {
                let op = match self.next() {
                    Some(Tok::Op(op)) => op,
                    _ => return Err(self.err(&format!("expected comparison after '{key}'"))),
                };
                let (raw, quoted) = match self.next() {
                    Some(Tok::Word(w)) => (w, false),
                    Some(Tok::Quoted(q)) => (q, true),
                    Some(Tok::Star) => ("*".to_string(), false),
                    _ => {
                        return Err(self.err(&format!(
                            "expected value after '{key}{}'",
                            op.as_str()
                        )))
                    }
                };
                self.typed_atom(key, op, raw, quoted)
            }
            other => Err(self.err(&format!("unexpected token {other:?}"))),
        }
    }

    /// Build the atom, routing the virtual keys `name` / `type` and
    /// enforcing operator/type compatibility.
    fn typed_atom(&self, key: String, op: CmpOp, raw: String, quoted: bool) -> Result<MetaExpr> {
        if key == "name" {
            if quoted {
                return Err(self.err("name takes a bare glob, not a quoted string"));
            }
            let atom = MetaExpr::NameGlob(raw);
            return match op {
                CmpOp::Eq => Ok(atom),
                CmpOp::Ne => Ok(MetaExpr::Not(Box::new(atom))),
                _ => Err(self.err("name supports only = and !=")),
            };
        }
        if key == "type" {
            let t = match raw.to_ascii_uppercase().as_str() {
                "FILE" => DidType::File,
                "DATASET" => DidType::Dataset,
                "CONTAINER" => DidType::Container,
                other => return Err(self.err(&format!("unknown DID type '{other}'"))),
            };
            let atom = MetaExpr::TypeIs(t);
            return match op {
                CmpOp::Eq => Ok(atom),
                CmpOp::Ne => Ok(MetaExpr::Not(Box::new(atom))),
                _ => Err(self.err("type supports only = and !=")),
            };
        }
        let value = if quoted {
            MetaValue::Str(raw)
        } else {
            MetaValue::parse_lexical(&raw)
        };
        if matches!(op, CmpOp::Lt | CmpOp::Le | CmpOp::Gt | CmpOp::Ge) && !value.is_numeric() {
            return Err(self.err(&format!(
                "ordered comparison on '{key}' needs a numeric literal, got {}",
                value.type_name()
            )));
        }
        Ok(MetaExpr::Cmp(key, op, value))
    }
}

/// Parse a `meta-expr` string to an AST.
pub fn parse(input: &str) -> Result<MetaExpr> {
    let trimmed = input.trim();
    if trimmed.is_empty() {
        return Err(RucioError::InvalidMetaExpression("empty expression".into()));
    }
    let toks = lex(trimmed)?;
    let mut p = Parser { toks, pos: 0, input: trimmed.to_string() };
    let e = p.expr()?;
    if p.pos != p.toks.len() {
        return Err(p.err("trailing tokens"));
    }
    Ok(e)
}

// ---------------------------------------------------------------------
// evaluation + normalization
// ---------------------------------------------------------------------

/// Glob matching for DID names: `*` (any run) and `?` (any one char),
/// everything else literal. Iterative two-pointer algorithm — no
/// backtracking blowup, no regex involved.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let t: Vec<char> = text.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None; // (pattern idx after *, text idx)
    while ti < t.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some((pi + 1, ti));
            pi += 1;
        } else if let Some((sp, st)) = star {
            // backtrack: let the last * swallow one more character
            pi = sp;
            ti = st + 1;
            star = Some((sp, st + 1));
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// The row shape the evaluator needs — avoids coupling the language to
/// the full `Did` row (the workload/tests can evaluate over lightweight
/// views).
pub trait MetaSource {
    fn did_name(&self) -> &str;
    fn did_type(&self) -> DidType;
    fn meta_get(&self, key: &str) -> Option<&MetaValue>;
}

impl MetaSource for super::types::Did {
    fn did_name(&self) -> &str {
        &self.key.name
    }

    fn did_type(&self) -> DidType {
        self.did_type
    }

    fn meta_get(&self, key: &str) -> Option<&MetaValue> {
        self.meta.get(key)
    }
}

impl MetaExpr {
    /// Evaluate against one DID.
    pub fn matches<S: MetaSource + ?Sized>(&self, did: &S) -> bool {
        match self {
            MetaExpr::Any => true,
            MetaExpr::NameGlob(g) => glob_match(g, did.did_name()),
            MetaExpr::TypeIs(t) => did.did_type() == *t,
            MetaExpr::Cmp(key, op, v) => match did.meta_get(key) {
                None => *op == CmpOp::Ne,
                Some(actual) => match op {
                    CmpOp::Eq => actual.eq_matches(v),
                    CmpOp::Ne => !actual.eq_matches(v),
                    ordered => ordered.ord_matches(actual, v),
                },
            },
            MetaExpr::Not(e) => !e.matches(did),
            MetaExpr::And(a, b) => a.matches(did) && b.matches(did),
            MetaExpr::Or(a, b) => a.matches(did) || b.matches(did),
        }
    }

    /// Negation normal form: push `NOT` inward through `AND`/`OR`
    /// (De Morgan), cancel double negations, and flip `=`/`!=`. After
    /// normalization `NOT` wraps only atoms it cannot flip (name globs,
    /// type tests, ordered comparisons — those are *not* complements of
    /// each other on missing keys). Evaluation is unchanged
    /// (property-tested below); the planner sees more positive conjuncts.
    pub fn normalize(&self) -> MetaExpr {
        match self {
            MetaExpr::And(a, b) => {
                MetaExpr::And(Box::new(a.normalize()), Box::new(b.normalize()))
            }
            MetaExpr::Or(a, b) => MetaExpr::Or(Box::new(a.normalize()), Box::new(b.normalize())),
            MetaExpr::Not(inner) => match &**inner {
                // ¬(A ∧ B) = ¬A ∨ ¬B
                MetaExpr::And(a, b) => MetaExpr::Or(
                    Box::new(MetaExpr::Not(a.clone()).normalize()),
                    Box::new(MetaExpr::Not(b.clone()).normalize()),
                ),
                // ¬(A ∨ B) = ¬A ∧ ¬B
                MetaExpr::Or(a, b) => MetaExpr::And(
                    Box::new(MetaExpr::Not(a.clone()).normalize()),
                    Box::new(MetaExpr::Not(b.clone()).normalize()),
                ),
                // ¬¬A = A
                MetaExpr::Not(e) => e.normalize(),
                // = and != are exact complements (including missing keys)
                MetaExpr::Cmp(k, CmpOp::Eq, v) => {
                    MetaExpr::Cmp(k.clone(), CmpOp::Ne, v.clone())
                }
                MetaExpr::Cmp(k, CmpOp::Ne, v) => {
                    MetaExpr::Cmp(k.clone(), CmpOp::Eq, v.clone())
                }
                // ordered comparisons are NOT complements on missing /
                // non-numeric values — keep the NOT
                other => MetaExpr::Not(Box::new(other.normalize())),
            },
            atom => atom.clone(),
        }
    }

    /// The positive `AND`-conjuncts of the normalized expression — what
    /// the planner inspects for indexable predicates. `a AND (b AND c)`
    /// yields `[a, b, c]`; anything under `OR`/`NOT` is opaque.
    pub fn conjuncts(&self) -> Vec<&MetaExpr> {
        let mut out = Vec::new();
        fn walk<'a>(e: &'a MetaExpr, out: &mut Vec<&'a MetaExpr>) {
            match e {
                MetaExpr::And(a, b) => {
                    walk(a, out);
                    walk(b, out);
                }
                other => out.push(other),
            }
        }
        walk(self, &mut out);
        out
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::common::proptest::{forall, Gen};
    use std::collections::BTreeMap;

    /// Lightweight evaluator row for language-level tests (also reused by
    /// the planner≡scan property suite in `dids_api`).
    pub struct Row {
        name: String,
        did_type: DidType,
        meta: BTreeMap<String, MetaValue>,
    }

    impl MetaSource for Row {
        fn did_name(&self) -> &str {
            &self.name
        }
        fn did_type(&self) -> DidType {
            self.did_type
        }
        fn meta_get(&self, key: &str) -> Option<&MetaValue> {
            self.meta.get(key)
        }
    }

    fn row(name: &str, t: DidType, pairs: &[(&str, MetaValue)]) -> Row {
        Row {
            name: name.to_string(),
            did_type: t,
            meta: pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        }
    }

    fn raw_dataset() -> Row {
        row(
            "data18_13TeV.00358031.physics_Main",
            DidType::Dataset,
            &[
                ("datatype", MetaValue::Str("RAW".into())),
                ("run", MetaValue::Int(358031)),
                ("lumi", MetaValue::Float(13.6)),
                ("good", MetaValue::Bool(true)),
            ],
        )
    }

    #[test]
    fn lexical_typing() {
        assert_eq!(MetaValue::parse_lexical("true"), MetaValue::Bool(true));
        assert_eq!(MetaValue::parse_lexical("358031"), MetaValue::Int(358031));
        assert_eq!(MetaValue::parse_lexical("-42"), MetaValue::Int(-42));
        assert_eq!(MetaValue::parse_lexical("13.6"), MetaValue::Float(13.6));
        assert_eq!(MetaValue::parse_lexical("1e3"), MetaValue::Float(1000.0));
        assert_eq!(MetaValue::parse_lexical("RAW"), MetaValue::Str("RAW".into()));
        // inf/nan spellings stay strings (catalog stores finite floats)
        assert_eq!(MetaValue::parse_lexical("inf"), MetaValue::Str("inf".into()));
        assert_eq!(MetaValue::parse_lexical("NaN"), MetaValue::Str("NaN".into()));
        // negative zero collapses to canonical zero (total_cmp separates
        // them; an uncanonical -0.0 would invert the Eq index band)
        match MetaValue::parse_lexical("-0.0") {
            MetaValue::Float(f) => assert!(f.is_sign_positive() && f == 0.0),
            other => panic!("-0.0 must parse as canonical Float(0.0), got {other:?}"),
        }
    }

    #[test]
    fn huge_int_equality_stays_exact_beyond_f64_precision() {
        // 2^53+3 is exactly representable in i64 but rounds UP to 2^53+4
        // in f64 — the equality band must still contain the exact key so
        // the planner's index probe agrees with the evaluator
        let i = (1i64 << 53) + 3;
        assert_ne!((i as f64) as i64, i, "test premise: f64 rounding moves the value");
        let (lo, hi) = MetaValue::numeric_band(CmpOp::Eq, &MetaValue::Int(i)).unwrap();
        assert!(MetaValue::Int(i).within(&lo, &hi), "exact key inside its own band");
        assert!(MetaValue::Int(i).eq_matches(&MetaValue::Int(i)));
        let d = row("x", DidType::File, &[("run", MetaValue::Int(i))]);
        assert!(parse(&format!("run={i}")).unwrap().matches(&d));
        assert!(!parse("run=1").unwrap().matches(&d));
        // ...and neighbors that collapse to the same f64 do NOT conflate:
        // equality is exact even where the band over-includes (the
        // evaluator filters candidates with the exact test)
        let tc = (1i64 << 53) as f64; // 2^53, exactly representable
        assert!(!MetaValue::Int(i).eq_matches(&MetaValue::Int(i + 1)));
        assert!(!MetaValue::Int(1 << 53).eq_matches(&MetaValue::Int((1 << 53) + 1)));
        let d53 = row("x", DidType::File, &[("run", MetaValue::Int(1 << 53))]);
        assert!(!parse(&format!("run={}", (1i64 << 53) + 1)).unwrap().matches(&d53));
        assert!(parse(&format!("run={}", 1i64 << 53)).unwrap().matches(&d53));
        // exact cross-type equality at the same magnitude
        assert!(MetaValue::Int(1 << 53).eq_matches(&MetaValue::Float(tc)));
        assert!(!MetaValue::Int((1 << 53) + 1).eq_matches(&MetaValue::Float(tc)));
        // and != is its exact complement
        assert!(parse(&format!("run!={}", (1i64 << 53) + 1)).unwrap().matches(&d53));
    }

    #[test]
    fn negative_zero_filters_are_safe_and_match_zero() {
        // `run=-0.0` must not panic the band builder and must match both
        // typed zeros (regression: inverted BTreeMap range)
        let (lo, hi) =
            MetaValue::numeric_band(CmpOp::Eq, &MetaValue::Float(-0.0)).unwrap();
        assert!(MetaValue::Int(0).within(&lo, &hi));
        assert!(MetaValue::Float(0.0).within(&lo, &hi));
        let d = row("x", DidType::File, &[("run", MetaValue::Int(0))]);
        assert!(parse("run=-0.0").unwrap().matches(&d));
        assert!(parse("run=0").unwrap().matches(&d));
        assert!(parse("run>=-0.0").unwrap().matches(&d));
        assert!(!parse("run<-0.0").unwrap().matches(&d));
    }

    #[test]
    fn value_order_groups_types_and_numerics_mix() {
        use MetaValue::*;
        let mut vs = vec![
            Str("a".into()),
            Float(2.5),
            Int(3),
            Bool(false),
            Int(2),
            Float(3.0),
            Bool(true),
            Str("RAW".into()),
        ];
        vs.sort();
        assert_eq!(
            vs,
            vec![
                Bool(false),
                Bool(true),
                Int(2),
                Float(2.5),
                Int(3),
                Float(3.0),
                Str("RAW".into()),
                Str("a".into()),
            ]
        );
        // equality is type-exact even where the order interleaves
        assert_ne!(Int(3), Float(3.0));
        assert_eq!(Int(3), Int(3));
    }

    #[test]
    fn the_issue_example_parses_and_matches() {
        let e = parse("datatype=RAW AND run>=358000 AND name=data18_13TeV.*").unwrap();
        assert!(e.matches(&raw_dataset()));
        let mut other = raw_dataset();
        other.meta.insert("run".into(), MetaValue::Int(300000));
        assert!(!e.matches(&other));
        let mut renamed = raw_dataset();
        renamed.name = "mc20_13TeV.999.sim".into();
        assert!(!e.matches(&renamed));
    }

    #[test]
    fn operator_semantics() {
        let d = raw_dataset();
        for (expr, want) in [
            ("datatype=RAW", true),
            ("datatype=AOD", false),
            ("datatype!=AOD", true),
            ("run>358030", true),
            ("run>358031", false),
            ("run>=358031", true),
            ("run<358032", true),
            ("run<=358030", false),
            ("lumi>13", true),
            ("lumi<13.7", true),
            ("good=true", true),
            ("good=false", false),
            ("missing=x", false),
            ("missing!=x", true), // absent counts as "not equal"
            ("datatype>5", false), // ordered op on a string value: no match
            ("type=DATASET", true),
            ("type=FILE", false),
            ("type!=FILE", true),
            ("name=*physics*", true),
            ("name=*.00358031.*", true),
            ("name!=*physics*", false),
            ("*", true),
            ("NOT datatype=AOD", true),
            ("datatype=RAW AND (run<100 OR lumi>10)", true),
            ("NOT (datatype=RAW AND run>=358000)", false),
            // symbol spellings
            ("datatype=RAW & run>=358000", true),
            ("datatype=AOD | lumi>13", true),
            ("!datatype=AOD", true),
        ] {
            let e = parse(expr).unwrap_or_else(|err| panic!("parse '{expr}': {err}"));
            assert_eq!(e.matches(&d), want, "{expr}");
        }
    }

    #[test]
    fn numeric_equality_is_value_based_strings_type_exact() {
        // one number, two typings: int-typed and float-typed stores both
        // answer `run=3` and `run=3.0` (whatever surface wrote them)
        for stored in [MetaValue::Int(3), MetaValue::Float(3.0)] {
            let d = row("x", DidType::File, &[("run", stored)]);
            assert!(parse("run=3").unwrap().matches(&d));
            assert!(parse("run=3.0").unwrap().matches(&d));
            assert!(!parse("run!=3").unwrap().matches(&d));
            assert!(!parse("run=3.5").unwrap().matches(&d));
            assert!(parse("run>=3.0").unwrap().matches(&d), "ordered ops are numeric");
            assert!(parse("run<=3").unwrap().matches(&d));
        }
        // quoted values are strings even when they look numeric — and
        // strings never numerically equal a number
        let s = row("x", DidType::File, &[("v", MetaValue::Str("42".into()))]);
        assert!(parse("v=\"42\"").unwrap().matches(&s));
        assert!(!parse("v=42").unwrap().matches(&s));
        // bools are type-exact too
        let b = row("x", DidType::File, &[("ok", MetaValue::Bool(true))]);
        assert!(parse("ok=true").unwrap().matches(&b));
        assert!(!parse("ok=1").unwrap().matches(&b));
    }

    #[test]
    fn malformed_expressions_error() {
        for bad in [
            "",
            "   ",
            "datatype=",
            "=RAW",
            "(datatype=RAW",
            "datatype=RAW)",
            "datatype=RAW AND",
            "AND datatype=RAW",
            "run>RAW",          // ordered op needs a numeric literal
            "run>\"5\"",        // quoted is a string
            "name<abc",         // name: only = and !=
            "type=BLOB",        // unknown DID type
            "a=b=c",
            "datatype RAW",
            "NOT",
            "a=b @@ c=d",
            "x=\"unterminated",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn glob_matching() {
        assert!(glob_match("*", "anything"));
        assert!(glob_match("*", ""));
        assert!(glob_match("raw.*", "raw.0001"));
        assert!(!glob_match("raw.*", "aod.0001"));
        assert!(glob_match("*.0001", "raw.0001"));
        assert!(glob_match("a*b*c", "a-x-b-y-c"));
        assert!(!glob_match("a*b*c", "a-x-b-y"));
        assert!(glob_match("f.????", "f.0001"));
        assert!(!glob_match("f.????", "f.001"));
        assert!(glob_match("data18_13TeV.*", "data18_13TeV.00358031.physics_Main"));
        assert!(!glob_match("", "x"));
        assert!(glob_match("", ""));
    }

    // ------------------------------------------------------------------
    // property tests (mirror the rseexpr suite style)
    // ------------------------------------------------------------------

    const KEYS: &[&str] = &["datatype", "run", "lumi", "good", "stream", "events"];

    fn gen_value(g: &mut Gen) -> MetaValue {
        match g.usize(0, 5) {
            0 => MetaValue::Bool(g.bool()),
            1 => MetaValue::Int(g.i64(-1000, 1_000_000)),
            2 => {
                // keep floats in the well-behaved band (finite, printable)
                let f = (g.i64(-100_000, 100_000) as f64) / 8.0;
                MetaValue::Float(f)
            }
            3 => MetaValue::Str(g.ident(1..8)),
            // strings that stress the printer: numeric-looking + quotable
            _ => MetaValue::Str(match g.usize(0, 4) {
                0 => g.u64(0, 999).to_string(),
                1 => "true".to_string(),
                2 => format!("has space {}", g.ident(1..4)),
                _ => format!("q\"uote\\{}", g.ident(1..4)),
            }),
        }
    }

    pub fn gen_expr(g: &mut Gen, depth: usize) -> MetaExpr {
        if depth == 0 || g.chance(0.35) {
            match g.usize(0, 8) {
                0 => MetaExpr::Any,
                1 => MetaExpr::NameGlob(format!("{}*{}", g.ident(1..4), g.ident(1..4))),
                2 => MetaExpr::TypeIs(*g.pick(&[
                    DidType::File,
                    DidType::Dataset,
                    DidType::Container,
                ])),
                3..=5 => MetaExpr::Cmp(
                    g.pick(KEYS).to_string(),
                    *g.pick(&[CmpOp::Eq, CmpOp::Ne]),
                    gen_value(g),
                ),
                _ => MetaExpr::Cmp(
                    g.pick(KEYS).to_string(),
                    *g.pick(&[CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge]),
                    if g.bool() {
                        MetaValue::Int(g.i64(-100, 1_000_000))
                    } else {
                        MetaValue::Float((g.i64(-8000, 8_000_000) as f64) / 8.0)
                    },
                ),
            }
        } else {
            let a = Box::new(gen_expr(g, depth - 1));
            match g.usize(0, 3) {
                0 => MetaExpr::And(a, Box::new(gen_expr(g, depth - 1))),
                1 => MetaExpr::Or(a, Box::new(gen_expr(g, depth - 1))),
                _ => MetaExpr::Not(a),
            }
        }
    }

    pub fn gen_row(g: &mut Gen) -> Row {
        let mut meta = BTreeMap::new();
        for key in KEYS {
            if g.chance(0.6) {
                meta.insert(key.to_string(), gen_value(g));
            }
        }
        Row {
            name: format!("{}.{}", g.ident(1..6), g.u64(0, 10_000)),
            did_type: *g.pick(&[DidType::File, DidType::Dataset, DidType::Container]),
            meta,
        }
    }

    #[test]
    fn prop_print_parse_round_trip() {
        forall(400, |g| {
            let ast = gen_expr(g, 3);
            let printed = ast.to_string();
            let reparsed = parse(&printed)
                .unwrap_or_else(|e| panic!("printed '{printed}' must reparse: {e}"));
            assert_eq!(reparsed, ast, "parse∘print is identity for '{printed}'");
            assert_eq!(reparsed.to_string(), printed, "printer fixpoint");
        });
    }

    #[test]
    fn prop_normalize_preserves_semantics_and_pushes_not_down() {
        fn not_only_on_atoms(e: &MetaExpr) -> bool {
            match e {
                MetaExpr::And(a, b) | MetaExpr::Or(a, b) => {
                    not_only_on_atoms(a) && not_only_on_atoms(b)
                }
                MetaExpr::Not(inner) => matches!(
                    &**inner,
                    MetaExpr::Any | MetaExpr::NameGlob(_) | MetaExpr::TypeIs(_)
                        | MetaExpr::Cmp(..)
                ),
                _ => true,
            }
        }
        forall(300, |g| {
            let ast = gen_expr(g, 4);
            let norm = ast.normalize();
            assert!(not_only_on_atoms(&norm), "NOT pushed to atoms: {norm}");
            // normalization is idempotent
            assert_eq!(norm.normalize(), norm);
            // and observationally equal on random rows
            for _ in 0..8 {
                let r = gen_row(g);
                assert_eq!(
                    ast.matches(&r),
                    norm.matches(&r),
                    "'{ast}' vs normalized '{norm}' diverge on {:?}",
                    r.meta
                );
            }
        });
    }

    #[test]
    fn prop_de_morgan_laws_hold() {
        forall(200, |g| {
            let a = gen_expr(g, 2);
            let b = gen_expr(g, 2);
            let not_and = MetaExpr::Not(Box::new(MetaExpr::And(
                Box::new(a.clone()),
                Box::new(b.clone()),
            )));
            let or_nots = MetaExpr::Or(
                Box::new(MetaExpr::Not(Box::new(a.clone()))),
                Box::new(MetaExpr::Not(Box::new(b.clone()))),
            );
            let not_or = MetaExpr::Not(Box::new(MetaExpr::Or(
                Box::new(a.clone()),
                Box::new(b.clone()),
            )));
            let and_nots = MetaExpr::And(
                Box::new(MetaExpr::Not(Box::new(a))),
                Box::new(MetaExpr::Not(Box::new(b))),
            );
            for _ in 0..6 {
                let r = gen_row(g);
                assert_eq!(not_and.matches(&r), or_nots.matches(&r), "¬(A∧B) = ¬A∨¬B");
                assert_eq!(not_or.matches(&r), and_nots.matches(&r), "¬(A∨B) = ¬A∧¬B");
            }
        });
    }

    #[test]
    fn prop_malformed_inputs_error_not_panic() {
        forall(500, |g| {
            // arbitrary printable garbage: parse must return, never panic
            let s = g.string(0..24);
            let _ = parse(&s);
        });
    }

    #[test]
    fn prop_ordered_ops_agree_with_band_bounds() {
        // the evaluator's ordered-comparison semantics and the planner's
        // index bounds are the same function — spot-check the equality
        // band edges where Int/Float interleave
        forall(200, |g| {
            let n = g.i64(-50, 50);
            let stored = [
                MetaValue::Int(n),
                MetaValue::Float(n as f64),
                MetaValue::Float(n as f64 + 0.5),
            ];
            for v in [MetaValue::Int(n), MetaValue::Float(n as f64)] {
                for op in [CmpOp::Lt, CmpOp::Le, CmpOp::Gt, CmpOp::Ge] {
                    for s in &stored {
                        let via_band = op.ord_matches(s, &v);
                        let direct = {
                            let (sf, vf) = (s.as_f64().unwrap(), v.as_f64().unwrap());
                            match op {
                                CmpOp::Lt => sf < vf,
                                CmpOp::Le => sf <= vf,
                                CmpOp::Gt => sf > vf,
                                CmpOp::Ge => sf >= vf,
                                _ => unreachable!(),
                            }
                        };
                        assert_eq!(via_band, direct, "{s:?} {op:?} {v:?}");
                    }
                }
            }
        });
    }
}
