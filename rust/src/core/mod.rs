//! The Rucio core (paper §2 concepts + §3.3 "the core which represents the
//! abstraction of all Rucio concepts").
//!
//! [`Catalog`] owns every table of the persistence layer (paper §3.6
//! describes >40 tables; the essential ones are here) and implements the
//! whole state machine: namespace, accounts/auth, RSEs, replicas, rules,
//! locks, requests, quotas, subscriptions. Daemons and the REST server
//! share one `Arc<Catalog>`; all mutation goes through its methods so the
//! invariants (lock tallies, usage accounting, availability derivation)
//! hold everywhere.

pub mod accounts_api;
pub mod dids_api;
pub mod metaexpr;
pub mod replicas_api;
pub mod rse;
pub mod rse_api;
pub mod rseexpr;
pub mod rules_api;
pub mod subscriptions;
pub mod types;

use std::sync::Mutex;

use crate::analytics::metrics::Metrics;
use crate::common::clock::{Clock, EpochMs};
use crate::common::config::Config;
use crate::common::idgen::IdGen;
use crate::common::prng::Prng;
use crate::db::{Index, MultiIndex, Registry, Table};
use crate::jsonx::Json;

use metaexpr::MetaValue;
use rse::{Distance, Rse};
use subscriptions::Subscription;
use types::*;

/// The system state: all tables + indexes + id generation + metrics.
pub struct Catalog {
    pub clock: Clock,
    pub cfg: Config,
    pub metrics: Metrics,
    pub(crate) ids: IdGen,
    pub(crate) rng: Mutex<Prng>,
    pub(crate) token_salt: u64,

    // --- accounts & auth (paper §2.3, §4.1)
    pub accounts: Table<Account>,
    pub identities: Table<Identity>,
    pub tokens: Table<Token>,

    // --- namespace (paper §2.2)
    pub scopes: Table<Scope>,
    pub dids: Table<Did>,
    pub attachments: Table<Attachment>,
    pub name_tombstones: Table<NameTombstone>,
    pub att_by_parent: Index<Attachment, DidKey>,
    pub att_by_child: Index<Attachment, DidKey>,
    pub dids_by_expiry: Index<Did, EpochMs>,
    /// DIDs per scope — O(1) scope sizes for the query planner's
    /// index-vs-scan cost gate.
    pub dids_by_scope: Index<Did, String>,
    /// Per-key inverted metadata index: `(scope, key, typed value)` →
    /// DIDs. Scope leads the index key, so the `meta-expr` planner's
    /// equality probes and numeric ranges return *scope-local* candidate
    /// sets — a hot value in one scope can never bloat another scope's
    /// queries. Maintained by the table on every mutation path
    /// (back-filled on `set_metadata`, cleaned on `erase_did`).
    pub meta_index: MultiIndex<Did, (String, String, MetaValue)>,

    // --- storage (paper §2.4)
    pub rses: Table<Rse>,
    pub distances: Table<Distance>,

    // --- replicas
    pub replicas: Table<Replica>,
    pub bad_replicas: Table<BadReplica>,
    pub replicas_by_did: Index<Replica, DidKey>,
    /// Partial index: only tombstoned replicas, keyed (rse, tombstone) —
    /// the reaper's work queue.
    pub replicas_by_tombstone: Index<Replica, (String, EpochMs)>,

    // --- rules & locks (paper §2.5)
    pub rules: Table<Rule>,
    pub locks: Table<ReplicaLock>,
    pub rules_by_state: Index<Rule, RuleState>,
    pub rules_by_did: Index<Rule, DidKey>,
    /// Partial index on rules with an expiry (judge-cleaner queue).
    pub rules_by_expiry: Index<Rule, EpochMs>,
    pub locks_by_replica: Index<ReplicaLock, (String, DidKey)>,
    pub locks_by_rule: Index<ReplicaLock, u64>,
    /// All locks on a DID across rules and RSEs (lost-file cleanup).
    pub locks_by_did: Index<ReplicaLock, DidKey>,

    // --- transfer requests (paper §4.2)
    pub requests: Table<TransferRequest>,
    pub requests_by_state: Index<TransferRequest, RequestState>,
    /// Partial index of non-terminal requests by destination — dedup so
    /// two rules needing the same (file, rse) share one transfer.
    pub requests_by_dest: Index<TransferRequest, (String, DidKey)>,

    // --- quota (paper §2.5)
    pub limits: Table<AccountLimit>,
    pub usages: Table<AccountUsage>,

    // --- subscriptions (paper §2.5)
    pub subscriptions: Table<Subscription>,

    // --- messaging outbox (paper §4.5; hermes drains this)
    pub outbox: Table<OutboxMessage>,

    // --- popularity (traces, §4.3/§6.1)
    pub popularity: Table<Popularity>,

    /// Table registry for monitoring probes.
    pub registry: Registry,
}

impl Catalog {
    pub fn new(clock: Clock, cfg: Config) -> Self {
        let seed = cfg.get_i64("common", "seed", 42) as u64;
        // §3.6 sharded storage: `[db] shards` sets the per-table shard
        // count (ordering semantics are shard-count invariant).
        let shards = cfg.get_i64("db", "shards", crate::db::DEFAULT_SHARDS as i64).max(1) as usize;
        let attachments = Table::new("attachments").with_shards(shards);
        let att_by_parent = Index::new(|a: &Attachment| Some(a.parent.clone()));
        let att_by_child = Index::new(|a: &Attachment| Some(a.child.clone()));
        attachments.add_index(&att_by_parent).unwrap();
        attachments.add_index(&att_by_child).unwrap();

        let dids = Table::new("dids").with_shards(shards);
        let dids_by_expiry = Index::new(|d: &Did| d.expired_at);
        dids.add_index(&dids_by_expiry).unwrap();
        let dids_by_scope = Index::new(|d: &Did| Some(d.key.scope.clone()));
        dids.add_index(&dids_by_scope).unwrap();
        let meta_index = MultiIndex::new(|d: &Did| {
            d.meta
                .iter()
                .map(|(k, v)| (d.key.scope.clone(), k.clone(), v.clone()))
                .collect()
        });
        dids.add_multi_index(&meta_index).unwrap();

        let replicas = Table::new("replicas").with_shards(shards);
        let replicas_by_did = Index::new(|r: &Replica| Some(r.did.clone()));
        let replicas_by_tombstone =
            Index::new(|r: &Replica| r.tombstone.map(|t| (r.rse.clone(), t)));
        replicas.add_index(&replicas_by_did).unwrap();
        replicas.add_index(&replicas_by_tombstone).unwrap();

        let rules = Table::new("rules").with_shards(shards).with_history();
        let rules_by_state = Index::new(|r: &Rule| Some(r.state));
        let rules_by_did = Index::new(|r: &Rule| Some(r.did.clone()));
        let rules_by_expiry = Index::new(|r: &Rule| r.expires_at);
        rules.add_index(&rules_by_state).unwrap();
        rules.add_index(&rules_by_did).unwrap();
        rules.add_index(&rules_by_expiry).unwrap();

        let locks = Table::new("locks").with_shards(shards);
        let locks_by_replica = Index::new(|l: &ReplicaLock| Some((l.rse.clone(), l.did.clone())));
        let locks_by_rule = Index::new(|l: &ReplicaLock| Some(l.rule_id));
        let locks_by_did = Index::new(|l: &ReplicaLock| Some(l.did.clone()));
        locks.add_index(&locks_by_replica).unwrap();
        locks.add_index(&locks_by_rule).unwrap();
        locks.add_index(&locks_by_did).unwrap();

        let requests = Table::new("requests").with_shards(shards).with_history();
        let requests_by_state = Index::new(|r: &TransferRequest| Some(r.state));
        let requests_by_dest = Index::new(|r: &TransferRequest| {
            if matches!(
                r.state,
                RequestState::Waiting
                    | RequestState::Queued
                    | RequestState::Submitted
                    | RequestState::Retry
            ) {
                Some((r.dst_rse.clone(), r.did.clone()))
            } else {
                None
            }
        });
        requests.add_index(&requests_by_state).unwrap();
        requests.add_index(&requests_by_dest).unwrap();

        let catalog = Catalog {
            clock,
            cfg,
            metrics: Metrics::new(),
            ids: IdGen::new(),
            rng: Mutex::new(Prng::new(seed)),
            token_salt: seed ^ 0xDEAD_BEEF_CAFE,
            accounts: Table::new("accounts").with_shards(shards),
            identities: Table::new("identities").with_shards(shards),
            tokens: Table::new("tokens").with_shards(shards),
            scopes: Table::new("scopes").with_shards(shards),
            dids,
            attachments,
            name_tombstones: Table::new("name_tombstones").with_shards(shards),
            att_by_parent,
            att_by_child,
            dids_by_expiry,
            dids_by_scope,
            meta_index,
            rses: Table::new("rses").with_shards(shards),
            distances: Table::new("distances").with_shards(shards),
            replicas,
            bad_replicas: Table::new("bad_replicas").with_shards(shards),
            replicas_by_did,
            replicas_by_tombstone,
            rules,
            locks,
            rules_by_state,
            rules_by_did,
            rules_by_expiry,
            locks_by_replica,
            locks_by_rule,
            locks_by_did,
            requests,
            requests_by_state,
            requests_by_dest,
            limits: Table::new("account_limits").with_shards(shards),
            usages: Table::new("account_usage").with_shards(shards),
            subscriptions: Table::new("subscriptions").with_shards(shards),
            outbox: Table::new("outbox").with_shards(shards),
            popularity: Table::new("popularity").with_shards(shards),
            registry: Registry::new(),
        };
        catalog.register_tables();
        catalog.bootstrap();
        catalog
    }

    /// Wire every table into the monitoring [`Registry`] so probes and
    /// analytics reports observe live row counts (paper §4.6).
    fn register_tables(&self) {
        let r = &self.registry;
        r.register(self.accounts.name(), self.accounts.len_counter());
        r.register(self.identities.name(), self.identities.len_counter());
        r.register(self.tokens.name(), self.tokens.len_counter());
        r.register(self.scopes.name(), self.scopes.len_counter());
        r.register(self.dids.name(), self.dids.len_counter());
        r.register(self.attachments.name(), self.attachments.len_counter());
        r.register(self.name_tombstones.name(), self.name_tombstones.len_counter());
        r.register(self.rses.name(), self.rses.len_counter());
        r.register(self.distances.name(), self.distances.len_counter());
        r.register(self.replicas.name(), self.replicas.len_counter());
        r.register(self.bad_replicas.name(), self.bad_replicas.len_counter());
        r.register(self.rules.name(), self.rules.len_counter());
        r.register(self.locks.name(), self.locks.len_counter());
        r.register(self.requests.name(), self.requests.len_counter());
        r.register(self.limits.name(), self.limits.len_counter());
        r.register(self.usages.name(), self.usages.len_counter());
        r.register(self.subscriptions.name(), self.subscriptions.len_counter());
        r.register(self.outbox.name(), self.outbox.len_counter());
        r.register(self.popularity.name(), self.popularity.len_counter());
    }

    /// Default catalog for tests: real clock, empty config, plus the
    /// `root` account.
    pub fn new_for_tests() -> Self {
        Catalog::new(Clock::sim_at(1_600_000_000_000), Config::new())
    }

    fn bootstrap(&self) {
        let now = self.clock.now_ms();
        // The root account always exists (paper §4.3: detector data is
        // "protected ... by replication rules issued by the root account").
        let _ = self.accounts.insert(
            Account {
                name: "root".into(),
                account_type: AccountType::Service,
                email: "rucio-admin@example.org".into(),
                created_at: now,
                suspended: false,
                admin: true,
            },
            now,
        );
        let _ = self.scopes.insert(
            Scope { name: "root".into(), account: "root".into(), created_at: now },
            now,
        );
    }

    pub fn now(&self) -> EpochMs {
        self.clock.now_ms()
    }

    pub(crate) fn next_id(&self) -> u64 {
        self.ids.next()
    }

    /// Queue an event for hermes (paper §4.5: "every component can schedule
    /// messages for delivery").
    pub fn notify(&self, event_type: &str, payload: Json) {
        let now = self.now();
        let id = self.next_id();
        let _ = self.outbox.insert(
            OutboxMessage { id, event_type: event_type.to_string(), payload, created_at: now },
            now,
        );
        self.metrics.incr("messages.queued", 1);
    }

    /// Namespace statistics (the §5.3 scale numbers).
    pub fn namespace_stats(&self) -> NamespaceStats {
        let mut stats = NamespaceStats::default();
        self.dids.for_each(|d| match d.did_type {
            DidType::File => stats.files += 1,
            DidType::Dataset => stats.datasets += 1,
            DidType::Container => stats.containers += 1,
        });
        stats.replicas = self.replicas.len() as u64;
        stats.rses = self.rses.len() as u64;
        stats.rules = self.rules.len() as u64;
        stats.bytes_managed = self.replicas.fold(0u64, |acc, r| acc + r.bytes);
        stats
    }
}

/// Aggregate namespace counts (paper §5.3).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct NamespaceStats {
    pub containers: u64,
    pub datasets: u64,
    pub files: u64,
    pub replicas: u64,
    pub rses: u64,
    pub rules: u64,
    pub bytes_managed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_creates_root() {
        let c = Catalog::new_for_tests();
        assert!(c.accounts.get(&"root".to_string()).is_some());
        assert!(c.scopes.get(&"root".to_string()).is_some());
        let root = c.accounts.get(&"root".to_string()).unwrap();
        assert!(root.admin);
    }

    #[test]
    fn notify_fills_outbox() {
        let c = Catalog::new_for_tests();
        c.notify("rule-ok", Json::obj().with("rule_id", 1));
        assert_eq!(c.outbox.len(), 1);
        assert_eq!(c.metrics.counter("messages.queued"), 1);
    }

    #[test]
    fn stats_empty_catalog() {
        let c = Catalog::new_for_tests();
        let s = c.namespace_stats();
        assert_eq!(s.files, 0);
        assert_eq!(s.replicas, 0);
        assert_eq!(s.rses, 0);
    }

    #[test]
    fn registry_sees_live_table_counts() {
        let c = Catalog::new_for_tests();
        let snap = c.registry.snapshot();
        // every table is wired in, and bootstrap rows are visible
        assert_eq!(snap["accounts"], 1, "root account");
        assert_eq!(snap["scopes"], 1, "root scope");
        assert_eq!(snap["dids"], 0);
        assert!(snap.len() >= 19, "all catalog tables registered: {snap:?}");
        c.add_scope("data18", "root").unwrap();
        c.add_file("data18", "f1", "root", 10, "x", None).unwrap();
        let snap = c.registry.snapshot();
        assert_eq!(snap["scopes"], 2);
        assert_eq!(snap["dids"], 1);
    }

    #[test]
    fn shard_count_config_is_respected() {
        let mut cfg = Config::new();
        cfg.set("db", "shards", "3");
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
        assert_eq!(c.replicas.shard_count(), 3);
        assert_eq!(c.rules.shard_count(), 3);
        assert!(c.accounts.get(&"root".to_string()).is_some());
    }
}
