//! The Rucio core (paper §2 concepts + §3.3 "the core which represents the
//! abstraction of all Rucio concepts").
//!
//! [`Catalog`] owns every table of the persistence layer (paper §3.6
//! describes >40 tables; the essential ones are here) and implements the
//! whole state machine: namespace, accounts/auth, RSEs, replicas, rules,
//! locks, requests, quotas, subscriptions. Daemons and the REST server
//! share one `Arc<Catalog>`; all mutation goes through its methods so the
//! invariants (lock tallies, usage accounting, availability derivation)
//! hold everywhere.

pub mod accounts_api;
pub mod dids_api;
pub mod metaexpr;
pub mod persist;
pub mod replicas_api;
pub mod rse;
pub mod rse_api;
pub mod rseexpr;
pub mod rules_api;
pub mod subscriptions;
pub mod types;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::analytics::metrics::Metrics;
use crate::common::clock::{Clock, EpochMs};
use crate::common::config::Config;
use crate::common::error::{Result, RucioError};
use crate::common::idgen::IdGen;
use crate::common::prng::Prng;
use crate::db::wal::{self, CheckpointStats, CompactStats, RecoverStats, WalOptions};
use crate::db::{CheckpointSweep, Index, MultiIndex, Registry, Table};
use crate::jsonx::Json;

use metaexpr::MetaValue;
use rse::{Distance, Rse};
use subscriptions::Subscription;
use types::*;

/// The system state: all tables + indexes + id generation + metrics.
pub struct Catalog {
    pub clock: Clock,
    pub cfg: Config,
    pub metrics: Metrics,
    pub(crate) ids: IdGen,
    pub(crate) rng: Mutex<Prng>,
    pub(crate) token_salt: u64,

    // --- accounts & auth (paper §2.3, §4.1)
    pub accounts: Table<Account>,
    pub identities: Table<Identity>,
    /// Login hot path: `(identity, auth_type)` → candidate identity rows,
    /// so authentication never scans the whole identities table.
    pub identities_by_key: Index<Identity, (String, AuthType)>,
    pub tokens: Table<Token>,

    // --- namespace (paper §2.2)
    pub scopes: Table<Scope>,
    pub dids: Table<Did>,
    pub attachments: Table<Attachment>,
    pub name_tombstones: Table<NameTombstone>,
    pub att_by_parent: Index<Attachment, DidKey>,
    pub att_by_child: Index<Attachment, DidKey>,
    pub dids_by_expiry: Index<Did, EpochMs>,
    /// DIDs per scope — O(1) scope sizes for the query planner's
    /// index-vs-scan cost gate.
    pub dids_by_scope: Index<Did, String>,
    /// Per-key inverted metadata index: `(scope, key, typed value)` →
    /// DIDs. Scope leads the index key, so the `meta-expr` planner's
    /// equality probes and numeric ranges return *scope-local* candidate
    /// sets — a hot value in one scope can never bloat another scope's
    /// queries. Maintained by the table on every mutation path
    /// (back-filled on `set_metadata`, cleaned on `erase_did`).
    pub meta_index: MultiIndex<Did, (String, String, MetaValue)>,

    // --- storage (paper §2.4)
    pub rses: Table<Rse>,
    pub distances: Table<Distance>,

    // --- replicas
    pub replicas: Table<Replica>,
    pub bad_replicas: Table<BadReplica>,
    pub replicas_by_did: Index<Replica, DidKey>,
    /// Partial index: only tombstoned replicas, keyed (rse, tombstone) —
    /// the reaper's work queue.
    pub replicas_by_tombstone: Index<Replica, (String, EpochMs)>,

    // --- rules & locks (paper §2.5)
    pub rules: Table<Rule>,
    pub locks: Table<ReplicaLock>,
    pub rules_by_state: Index<Rule, RuleState>,
    pub rules_by_did: Index<Rule, DidKey>,
    /// Partial index on rules with an expiry (judge-cleaner queue).
    pub rules_by_expiry: Index<Rule, EpochMs>,
    pub locks_by_replica: Index<ReplicaLock, (String, DidKey)>,
    pub locks_by_rule: Index<ReplicaLock, u64>,
    /// All locks on a DID across rules and RSEs (lost-file cleanup).
    pub locks_by_did: Index<ReplicaLock, DidKey>,

    // --- transfer requests (paper §4.2)
    pub requests: Table<TransferRequest>,
    pub requests_by_state: Index<TransferRequest, RequestState>,
    /// Partial index of non-terminal requests by destination — dedup so
    /// two rules needing the same (file, rse) share one transfer.
    pub requests_by_dest: Index<TransferRequest, (String, DidKey)>,

    // --- quota (paper §2.5)
    pub limits: Table<AccountLimit>,
    pub usages: Table<AccountUsage>,

    // --- subscriptions (paper §2.5)
    pub subscriptions: Table<Subscription>,

    // --- messaging outbox (paper §4.5; hermes drains this)
    pub outbox: Table<OutboxMessage>,

    // --- popularity (traces, §4.3/§6.1)
    pub popularity: Table<Popularity>,
    /// Decayed access heat per DID (§6.1 placement signal; see [`Heat`]).
    pub heat: Table<Heat>,

    /// Table registry for monitoring probes.
    pub registry: Registry,
}

/// Run `$body` once per catalog table, with `$t` bound to each table in
/// turn — the durability plumbing (attach / recover / register) is
/// identical per table but monomorphizes per row type.
macro_rules! with_all_tables {
    ($cat:expr, $t:ident => $body:expr) => {{
        {
            let $t = &$cat.accounts;
            $body
        }
        {
            let $t = &$cat.identities;
            $body
        }
        {
            let $t = &$cat.tokens;
            $body
        }
        {
            let $t = &$cat.scopes;
            $body
        }
        {
            let $t = &$cat.dids;
            $body
        }
        {
            let $t = &$cat.attachments;
            $body
        }
        {
            let $t = &$cat.name_tombstones;
            $body
        }
        {
            let $t = &$cat.rses;
            $body
        }
        {
            let $t = &$cat.distances;
            $body
        }
        {
            let $t = &$cat.replicas;
            $body
        }
        {
            let $t = &$cat.bad_replicas;
            $body
        }
        {
            let $t = &$cat.rules;
            $body
        }
        {
            let $t = &$cat.locks;
            $body
        }
        {
            let $t = &$cat.requests;
            $body
        }
        {
            let $t = &$cat.limits;
            $body
        }
        {
            let $t = &$cat.usages;
            $body
        }
        {
            let $t = &$cat.subscriptions;
            $body
        }
        {
            let $t = &$cat.outbox;
            $body
        }
        {
            let $t = &$cat.popularity;
            $body
        }
        {
            let $t = &$cat.heat;
            $body
        }
    }};
}

impl Catalog {
    /// Fresh catalog. With `[db] wal_dir` configured, durability starts
    /// *clean*: any persistence state already in the directory is
    /// discarded and every table begins logging to a new WAL (use
    /// [`Catalog::open`] / [`Catalog::open_with`] to recover instead).
    pub fn new(clock: Clock, cfg: Config) -> Self {
        let catalog = Catalog::build(clock, cfg);
        if let Some(dir) = catalog.wal_dir() {
            catalog.reset_durability_dir(&dir).expect("wipe [db] wal_dir");
            catalog.attach_durability(&dir).expect("attach durability");
        }
        catalog.bootstrap();
        catalog
    }

    /// Construct tables + indexes + registry wiring (no bootstrap rows,
    /// no durability) — shared by [`Catalog::new`] and [`Catalog::open_with`].
    fn build(clock: Clock, cfg: Config) -> Self {
        let seed = cfg.get_i64("common", "seed", 42) as u64;
        // §3.6 sharded storage: `[db] shards` sets the per-table shard
        // count (ordering semantics are shard-count invariant).
        let shards = cfg.get_i64("db", "shards", crate::db::DEFAULT_SHARDS as i64).max(1) as usize;
        let attachments = Table::new("attachments").with_shards(shards);
        let att_by_parent = Index::new(|a: &Attachment| Some(a.parent.clone()));
        let att_by_child = Index::new(|a: &Attachment| Some(a.child.clone()));
        attachments.add_index(&att_by_parent).unwrap();
        attachments.add_index(&att_by_child).unwrap();

        let dids = Table::new("dids").with_shards(shards);
        let dids_by_expiry = Index::new(|d: &Did| d.expired_at);
        dids.add_index(&dids_by_expiry).unwrap();
        let dids_by_scope = Index::new(|d: &Did| Some(d.key.scope.clone()));
        dids.add_index(&dids_by_scope).unwrap();
        let meta_index = MultiIndex::new(|d: &Did| {
            d.meta
                .iter()
                .map(|(k, v)| (d.key.scope.clone(), k.clone(), v.clone()))
                .collect()
        });
        dids.add_multi_index(&meta_index).unwrap();

        let replicas = Table::new("replicas").with_shards(shards);
        let replicas_by_did = Index::new(|r: &Replica| Some(r.did.clone()));
        let replicas_by_tombstone =
            Index::new(|r: &Replica| r.tombstone.map(|t| (r.rse.clone(), t)));
        replicas.add_index(&replicas_by_did).unwrap();
        replicas.add_index(&replicas_by_tombstone).unwrap();

        let rules = Table::new("rules").with_shards(shards).with_history();
        let rules_by_state = Index::new(|r: &Rule| Some(r.state));
        let rules_by_did = Index::new(|r: &Rule| Some(r.did.clone()));
        let rules_by_expiry = Index::new(|r: &Rule| r.expires_at);
        rules.add_index(&rules_by_state).unwrap();
        rules.add_index(&rules_by_did).unwrap();
        rules.add_index(&rules_by_expiry).unwrap();

        let locks = Table::new("locks").with_shards(shards);
        let locks_by_replica = Index::new(|l: &ReplicaLock| Some((l.rse.clone(), l.did.clone())));
        let locks_by_rule = Index::new(|l: &ReplicaLock| Some(l.rule_id));
        let locks_by_did = Index::new(|l: &ReplicaLock| Some(l.did.clone()));
        locks.add_index(&locks_by_replica).unwrap();
        locks.add_index(&locks_by_rule).unwrap();
        locks.add_index(&locks_by_did).unwrap();

        let requests = Table::new("requests").with_shards(shards).with_history();
        let requests_by_state = Index::new(|r: &TransferRequest| Some(r.state));
        let requests_by_dest = Index::new(|r: &TransferRequest| {
            if matches!(
                r.state,
                RequestState::Waiting
                    | RequestState::Queued
                    | RequestState::Submitted
                    | RequestState::Retry
            ) {
                Some((r.dst_rse.clone(), r.did.clone()))
            } else {
                None
            }
        });
        requests.add_index(&requests_by_state).unwrap();
        requests.add_index(&requests_by_dest).unwrap();

        let identities = Table::new("identities").with_shards(shards);
        let identities_by_key =
            Index::new(|i: &Identity| Some((i.identity.clone(), i.auth_type)));
        identities.add_index(&identities_by_key).unwrap();

        let catalog = Catalog {
            clock,
            cfg,
            metrics: Metrics::new(),
            ids: IdGen::new(),
            rng: Mutex::new(Prng::new(seed)),
            token_salt: seed ^ 0xDEAD_BEEF_CAFE,
            accounts: Table::new("accounts").with_shards(shards),
            identities,
            identities_by_key,
            tokens: Table::new("tokens").with_shards(shards),
            scopes: Table::new("scopes").with_shards(shards),
            dids,
            attachments,
            name_tombstones: Table::new("name_tombstones").with_shards(shards),
            att_by_parent,
            att_by_child,
            dids_by_expiry,
            dids_by_scope,
            meta_index,
            rses: Table::new("rses").with_shards(shards),
            distances: Table::new("distances").with_shards(shards),
            replicas,
            bad_replicas: Table::new("bad_replicas").with_shards(shards),
            replicas_by_did,
            replicas_by_tombstone,
            rules,
            locks,
            rules_by_state,
            rules_by_did,
            rules_by_expiry,
            locks_by_replica,
            locks_by_rule,
            locks_by_did,
            requests,
            requests_by_state,
            requests_by_dest,
            limits: Table::new("account_limits").with_shards(shards),
            usages: Table::new("account_usage").with_shards(shards),
            subscriptions: Table::new("subscriptions").with_shards(shards),
            outbox: Table::new("outbox").with_shards(shards),
            popularity: Table::new("popularity").with_shards(shards),
            heat: Table::new("heat").with_shards(shards),
            registry: Registry::new(),
        };
        catalog.register_tables();
        catalog
    }

    // ------------------------------------------------------------------
    // durability (paper §3.6: the catalog survives process death)
    // ------------------------------------------------------------------

    /// The configured durability directory, if any (`[db] wal_dir`).
    pub fn wal_dir(&self) -> Option<PathBuf> {
        self.cfg
            .get("db", "wal_dir")
            .filter(|s| !s.is_empty())
            .map(PathBuf::from)
    }

    /// Is this catalog logging to a WAL?
    pub fn durable(&self) -> bool {
        self.wal_dir().is_some()
    }

    fn wal_options(&self) -> WalOptions {
        WalOptions {
            fsync: self.cfg.get_bool("db", "fsync", false),
            group_commit: self.cfg.get_bool("db", "group_commit", true),
            leader: self.cfg.get_bool("db", "wal_leader", true),
        }
    }

    /// Attach a WAL to every table (continuing any existing log file)
    /// and register the type-erased persistence handles with the
    /// monitoring registry so `Registry::checkpoint_all` covers the
    /// whole store. With `[db] memory_budget` set (> 0, a per-table
    /// hot-row count), every table runs in paged mode: the checkpointer
    /// evicts least-recently-used shards to their snapshot files to keep
    /// hot rows under the budget.
    fn attach_durability(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let opts = self.wal_options();
        let budget = self.cfg.get_i64("db", "memory_budget", 0).max(0) as usize;
        with_all_tables!(self, t => t.attach_wal(dir, opts)?);
        with_all_tables!(self, t => t.set_memory_budget(budget));
        with_all_tables!(self, t => self.registry.register_persist(Arc::new(t.clone())));
        Ok(())
    }

    /// Remove prior persistence state (`*.wal`, `*.snap`, `*.tmp`,
    /// `MANIFEST`) from the durability dir — the fresh-boot path of
    /// [`Catalog::new`]. Only known file classes are touched.
    fn reset_durability_dir(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "MANIFEST"
                || name.ends_with(".wal")
                || name.ends_with(".snap")
                || name.ends_with(".tmp")
            {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(())
    }

    /// Recover every table from `dir` (snapshot + WAL suffix); the
    /// catalog must be freshly built (empty tables). Returns aggregate
    /// stats across tables.
    fn recover_all(&self, dir: &Path) -> Result<RecoverStats> {
        let mut total = RecoverStats::default();
        with_all_tables!(self, t => {
            let s = t.recover_from_dir(dir)?;
            total.snapshot_rows += s.snapshot_rows;
            total.replayed_records += s.replayed_records;
            total.replayed_ops += s.replayed_ops;
            total.torn_tail |= s.torn_tail;
        });
        Ok(total)
    }

    /// Cold-boot a catalog from a durability directory with an explicit
    /// clock + config (`[db] wal_dir` must point at `dir`'s state). All
    /// primary/secondary/multi indexes are rebuilt during the load, WALs
    /// are re-attached (continuing where the crashed process stopped),
    /// and the id generator is bumped past every persisted id so nothing
    /// is ever re-issued. An empty directory cold-boots a fresh catalog
    /// (bootstrap rows included), so `open` is safe as a first boot too.
    pub fn open_with(clock: Clock, cfg: Config) -> Result<Catalog> {
        let t0 = std::time::Instant::now();
        let catalog = Catalog::build(clock, cfg);
        let dir = catalog
            .wal_dir()
            .ok_or_else(|| RucioError::ConfigError("[db] wal_dir not configured".into()))?;
        std::fs::create_dir_all(&dir)?;
        let stats = catalog.recover_all(&dir)?;
        // Each WAL is scanned twice on a cold boot: once here for the
        // replay, once inside `Wal::open` to restore counters and drop
        // any torn tail. Checkpoints keep the logs short, so the second
        // pass is cheap relative to the snapshot load.
        catalog.attach_durability(&dir)?;
        // No-op when the root rows were recovered: the duplicate-key
        // check fires before any WAL append.
        catalog.bootstrap();
        let manifest_next = wal::read_frames(&dir.join("MANIFEST"))
            .ok()
            .and_then(|frames| frames.first().and_then(|m| m.opt_u64("next_id")))
            .unwrap_or(1);
        catalog.ids.bump_to(manifest_next.max(catalog.max_used_id() + 1));
        // Recovery loads every row hot; with a memory budget configured,
        // spill back down before serving so boot RSS is bounded too.
        catalog.enforce_memory_budgets();
        let ms = t0.elapsed().as_millis() as u64;
        catalog.metrics.gauge_set("db.recovery_ms", ms);
        catalog.metrics.gauge_set("db.recovered_rows", stats.snapshot_rows as u64);
        catalog.metrics.gauge_set("db.recovery_replayed_ops", stats.replayed_ops);
        if stats.torn_tail {
            catalog.metrics.incr("db.recovery_torn_tails", 1);
        }
        crate::log_info!(
            "catalog recovered from {}: {} snapshot rows, {} replayed ops, {} ms{}",
            dir.display(),
            stats.snapshot_rows,
            stats.replayed_ops,
            ms,
            if stats.torn_tail { " (torn WAL tail discarded)" } else { "" }
        );
        Ok(catalog)
    }

    /// Cold-boot from a durability directory with a real clock and
    /// default config.
    pub fn open(dir: &Path) -> Result<Catalog> {
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        Catalog::open_with(Clock::real(), cfg)
    }

    /// Checkpoint every table (barrier + dirty-shard snapshot + WAL
    /// truncation via the registry's persistence handles) and write the
    /// `MANIFEST` (id high-water mark — tokens embed allocated ids that
    /// no table scan can see after expiry). The sweep is best-effort per
    /// table: a failing table is reported in the returned
    /// [`CheckpointSweep`] while every other table still checkpoints.
    /// The checkpointer daemon drives this on `[db] checkpoint_interval`.
    pub fn checkpoint_sweep(&self) -> Result<CheckpointSweep> {
        let dir = self
            .wal_dir()
            .ok_or_else(|| RucioError::ConfigError("[db] wal_dir not configured".into()))?;
        let sweep = self.registry.checkpoint_all();
        let manifest = Json::obj()
            .with("k", "manifest")
            .with("next_id", self.ids.peek())
            .with("at", self.now());
        wal::write_frames_atomic(&dir.join("MANIFEST"), &[manifest], self.wal_options().fsync)?;
        self.metrics.incr("db.checkpoints", 1);
        Ok(sweep)
    }

    /// [`Catalog::checkpoint_sweep`], strict: any per-table failure is
    /// promoted to an error (after the full sweep still ran). Returns
    /// the stats of tables actually snapshotted; clean tables are
    /// skipped and absent from the map.
    pub fn checkpoint_all(&self) -> Result<std::collections::BTreeMap<String, CheckpointStats>> {
        let sweep = self.checkpoint_sweep()?;
        if let Some((name, e)) = sweep.errors.into_iter().next() {
            return Err(RucioError::DatabaseError(format!(
                "checkpoint of table {name} failed: {e}"
            )));
        }
        Ok(sweep.tables)
    }

    /// Compact every table's WAL whose log has grown past `min_bytes`:
    /// drop snapshot-covered records, fold the live suffix to the last
    /// op per key. Driven by the checkpointer between checkpoints.
    pub fn compact_wals(&self, min_bytes: u64) -> std::collections::BTreeMap<String, CompactStats> {
        self.registry.compact_wals(min_bytes)
    }

    /// Evict LRU shards of over-budget tables to disk (paged mode; see
    /// `[db] memory_budget`). Returns the number of shards evicted.
    pub fn enforce_memory_budgets(&self) -> usize {
        self.registry.enforce_budgets()
    }

    /// Highest id present in any id-keyed table (recovery fence for the
    /// id generator).
    fn max_used_id(&self) -> u64 {
        let mut m = 0u64;
        if let Some(k) = self.rules.keys().last() {
            m = m.max(*k);
        }
        if let Some(k) = self.requests.keys().last() {
            m = m.max(*k);
        }
        if let Some(k) = self.subscriptions.keys().last() {
            m = m.max(*k);
        }
        if let Some(k) = self.outbox.keys().last() {
            m = m.max(*k);
        }
        m
    }

    /// Wire every table into the monitoring [`Registry`] so probes and
    /// analytics reports observe live row counts (paper §4.6).
    fn register_tables(&self) {
        let r = &self.registry;
        r.register(self.accounts.name(), self.accounts.len_counter());
        r.register(self.identities.name(), self.identities.len_counter());
        r.register(self.tokens.name(), self.tokens.len_counter());
        r.register(self.scopes.name(), self.scopes.len_counter());
        r.register(self.dids.name(), self.dids.len_counter());
        r.register(self.attachments.name(), self.attachments.len_counter());
        r.register(self.name_tombstones.name(), self.name_tombstones.len_counter());
        r.register(self.rses.name(), self.rses.len_counter());
        r.register(self.distances.name(), self.distances.len_counter());
        r.register(self.replicas.name(), self.replicas.len_counter());
        r.register(self.bad_replicas.name(), self.bad_replicas.len_counter());
        r.register(self.rules.name(), self.rules.len_counter());
        r.register(self.locks.name(), self.locks.len_counter());
        r.register(self.requests.name(), self.requests.len_counter());
        r.register(self.limits.name(), self.limits.len_counter());
        r.register(self.usages.name(), self.usages.len_counter());
        r.register(self.subscriptions.name(), self.subscriptions.len_counter());
        r.register(self.outbox.name(), self.outbox.len_counter());
        r.register(self.popularity.name(), self.popularity.len_counter());
        r.register(self.heat.name(), self.heat.len_counter());
        with_all_tables!(self, t => r.register_contention(t.name(), t.contention_probe()));
    }

    /// Default catalog for tests: real clock, empty config, plus the
    /// `root` account.
    pub fn new_for_tests() -> Self {
        Catalog::new(Clock::sim_at(1_600_000_000_000), Config::new())
    }

    fn bootstrap(&self) {
        let now = self.clock.now_ms();
        // The root account always exists (paper §4.3: detector data is
        // "protected ... by replication rules issued by the root account").
        let _ = self.accounts.insert(
            Account {
                name: "root".into(),
                account_type: AccountType::Service,
                email: "rucio-admin@example.org".into(),
                created_at: now,
                suspended: false,
                admin: true,
                vo: DEFAULT_VO.into(),
            },
            now,
        );
        let _ = self.scopes.insert(
            Scope {
                name: "root".into(),
                account: "root".into(),
                created_at: now,
                vo: DEFAULT_VO.into(),
            },
            now,
        );
    }

    pub fn now(&self) -> EpochMs {
        self.clock.now_ms()
    }

    pub(crate) fn next_id(&self) -> u64 {
        self.ids.next()
    }

    /// Queue an event for hermes (paper §4.5: "every component can schedule
    /// messages for delivery").
    pub fn notify(&self, event_type: &str, payload: Json) {
        let now = self.now();
        let id = self.next_id();
        let _ = self.outbox.insert(
            OutboxMessage { id, event_type: event_type.to_string(), payload, created_at: now },
            now,
        );
        self.metrics.incr("messages.queued", 1);
    }

    /// Namespace statistics (the §5.3 scale numbers).
    pub fn namespace_stats(&self) -> NamespaceStats {
        let mut stats = NamespaceStats::default();
        self.dids.for_each(|d| match d.did_type {
            DidType::File => stats.files += 1,
            DidType::Dataset => stats.datasets += 1,
            DidType::Container => stats.containers += 1,
        });
        stats.replicas = self.replicas.len() as u64;
        stats.rses = self.rses.len() as u64;
        stats.rules = self.rules.len() as u64;
        stats.bytes_managed = self.replicas.fold(0u64, |acc, r| acc + r.bytes);
        stats
    }
}

/// Aggregate namespace counts (paper §5.3).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct NamespaceStats {
    pub containers: u64,
    pub datasets: u64,
    pub files: u64,
    pub replicas: u64,
    pub rses: u64,
    pub rules: u64,
    pub bytes_managed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_creates_root() {
        let c = Catalog::new_for_tests();
        assert!(c.accounts.get(&"root".to_string()).is_some());
        assert!(c.scopes.get(&"root".to_string()).is_some());
        let root = c.accounts.get(&"root".to_string()).unwrap();
        assert!(root.admin);
    }

    #[test]
    fn notify_fills_outbox() {
        let c = Catalog::new_for_tests();
        c.notify("rule-ok", Json::obj().with("rule_id", 1));
        assert_eq!(c.outbox.len(), 1);
        assert_eq!(c.metrics.counter("messages.queued"), 1);
    }

    #[test]
    fn stats_empty_catalog() {
        let c = Catalog::new_for_tests();
        let s = c.namespace_stats();
        assert_eq!(s.files, 0);
        assert_eq!(s.replicas, 0);
        assert_eq!(s.rses, 0);
    }

    #[test]
    fn registry_sees_live_table_counts() {
        let c = Catalog::new_for_tests();
        let snap = c.registry.snapshot();
        // every table is wired in, and bootstrap rows are visible
        assert_eq!(snap["accounts"], 1, "root account");
        assert_eq!(snap["scopes"], 1, "root scope");
        assert_eq!(snap["dids"], 0);
        assert!(snap.len() >= 20, "all catalog tables registered: {snap:?}");
        c.add_scope("data18", "root").unwrap();
        c.add_file("data18", "f1", "root", 10, "x", None).unwrap();
        let snap = c.registry.snapshot();
        assert_eq!(snap["scopes"], 2);
        assert_eq!(snap["dids"], 1);
    }

    #[test]
    fn shard_count_config_is_respected() {
        let mut cfg = Config::new();
        cfg.set("db", "shards", "3");
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg);
        assert_eq!(c.replicas.shard_count(), 3);
        assert_eq!(c.rules.shard_count(), 3);
        assert!(c.accounts.get(&"root".to_string()).is_some());
    }

    #[test]
    fn durable_catalog_cold_boots_from_disk() {
        let dir = std::env::temp_dir()
            .join(format!("rucio-core-open-{}", std::process::id()));
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        let c = Catalog::new(Clock::sim_at(1_600_000_000_000), cfg.clone());
        assert!(c.durable());
        c.add_scope("s", "root").unwrap();
        c.add_file("s", "f1", "root", 10, "x", None).unwrap();
        let ck = c.checkpoint_all().unwrap();
        // Incremental sweeps only touch dirty tables; the mutated ones
        // (plus everything bootstrap wrote) must be in the cut.
        assert!(
            ck.contains_key("dids") && ck.contains_key("accounts") && ck.contains_key("scopes"),
            "dirty tables checkpointed: {:?}",
            ck.keys().collect::<Vec<_>>()
        );
        c.add_file("s", "f2", "root", 20, "y", None).unwrap(); // post-ckpt: WAL only
        let r = Catalog::open_with(Clock::sim_at(c.now()), cfg).unwrap();
        assert!(r.accounts.get(&"root".to_string()).is_some(), "bootstrap rows recovered");
        assert_eq!(r.dids.len(), 2, "snapshot + WAL suffix both applied");
        assert_eq!(r.dids_by_scope.get(&"s".to_string()).len(), 2, "index rebuilt");
        assert!(r.ids.peek() >= c.ids.peek(), "ids are never re-issued after recovery");
        // the recovered catalog keeps logging: a new row survives another boot
        r.add_file("s", "f3", "root", 30, "z", None).unwrap();
        let r2 = Catalog::open_with(Clock::sim_at(r.now()), cfg_for(&dir)).unwrap();
        assert_eq!(r2.dids.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn cfg_for(dir: &std::path::Path) -> Config {
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        cfg
    }
}
