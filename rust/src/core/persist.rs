//! [`Durable`] (WAL/snapshot JSON codec) implementations for every
//! catalog row type — the schema half of the §3.6 persistence layer.
//!
//! Encoding notes:
//! * Plain integers ride as JSON numbers (exact below 2^53 — file sizes,
//!   timestamps and ids never approach that). The one exception is
//!   [`MetaValue::Int`], whose contract includes exact i64s beyond 2^53
//!   (PR 3's planner tests) — it is string-encoded.
//! * Floats ([`MetaValue::Float`]) use Rust's shortest-round-trip
//!   `Display`, re-canonicalized on decode (`-0.0` → `0.0`) so the
//!   inverted index order survives a restart byte-for-byte.
//! * Subscription filters persist their `meta-expr` through the
//!   canonical printer; `parse(print(e)) == e` is property-tested in
//!   [`crate::core::metaexpr`].
//! * Tuple keys encode as JSON arrays (the `Remove` side of the log).
//!
//! Every codec is exercised by the round-trip tests below and, end to
//! end, by the crash-recovery equivalence suite in `rust/tests/recovery.rs`.

use std::collections::BTreeMap;

use crate::common::error::{Result, RucioError};
use crate::db::wal::Durable;
use crate::jsonx::Json;

use super::metaexpr::{self, MetaValue};
use super::rse::{Distance, PathAlgorithm, Protocol, Rse};
use super::subscriptions::{Subscription, SubscriptionFilter, SubscriptionRule};
use super::types::*;

// ---------------------------------------------------------------------
// field helpers
// ---------------------------------------------------------------------

fn bad(what: &str) -> RucioError {
    RucioError::JsonError(format!("persist: {what}"))
}

fn req_string(j: &Json, k: &str) -> Result<String> {
    Ok(j.req_str(k)?.to_string())
}

fn opt_string(j: &Json, k: &str) -> Option<String> {
    j.opt_str(k).map(str::to_string)
}

fn req_bool(j: &Json, k: &str) -> Result<bool> {
    j.opt_bool(k).ok_or_else(|| bad(&format!("missing bool field '{k}'")))
}

fn req_u32(j: &Json, k: &str) -> Result<u32> {
    Ok(j.req_u64(k)? as u32)
}

fn req_u8(j: &Json, k: &str) -> Result<u8> {
    Ok(j.req_u64(k)? as u8)
}

fn arr_item<'a>(j: &'a Json, i: usize) -> Result<&'a Json> {
    j.as_arr()
        .and_then(|a| a.get(i))
        .ok_or_else(|| bad(&format!("key tuple missing element {i}")))
}

fn str_item(j: &Json, i: usize) -> Result<String> {
    arr_item(j, i)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| bad(&format!("key tuple element {i} not a string")))
}

fn u64_item(j: &Json, i: usize) -> Result<u64> {
    arr_item(j, i)?
        .as_u64()
        .ok_or_else(|| bad(&format!("key tuple element {i} not a u64")))
}

// ---------------------------------------------------------------------
// shared value codecs
// ---------------------------------------------------------------------

fn didkey_to_json(k: &DidKey) -> Json {
    Json::obj().with("s", k.scope.as_str()).with("n", k.name.as_str())
}

fn didkey_from_json(j: &Json) -> Result<DidKey> {
    Ok(DidKey { scope: req_string(j, "s")?, name: req_string(j, "n")? })
}

fn opt_didkey_from_json(j: Option<&Json>) -> Result<Option<DidKey>> {
    match j {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(didkey_from_json(v)?)),
    }
}

/// Typed metadata value: tagged so lexical typing never re-runs on
/// recovery (a string `"358031"` must come back a string, not an int),
/// with `Int` string-encoded for exactness past 2^53.
fn metavalue_to_json(v: &MetaValue) -> Json {
    match v {
        MetaValue::Bool(b) => Json::obj().with("t", "b").with("v", *b),
        MetaValue::Int(i) => Json::obj().with("t", "i").with("v", i.to_string()),
        MetaValue::Float(f) => Json::obj().with("t", "f").with("v", format!("{f}")),
        MetaValue::Str(s) => Json::obj().with("t", "s").with("v", s.as_str()),
    }
}

fn metavalue_from_json(j: &Json) -> Result<MetaValue> {
    match j.req_str("t")? {
        "b" => Ok(MetaValue::Bool(req_bool(j, "v")?)),
        "i" => {
            let v = j.req_str("v")?;
            Ok(MetaValue::Int(
                v.parse::<i64>().map_err(|e| bad(&format!("bad int meta '{v}': {e}")))?,
            ))
        }
        "f" => {
            let v = j.req_str("v")?;
            Ok(MetaValue::Float(metaexpr::canonical_f64(
                v.parse::<f64>().map_err(|e| bad(&format!("bad float meta '{v}': {e}")))?,
            )))
        }
        "s" => Ok(MetaValue::Str(req_string(j, "v")?)),
        other => Err(bad(&format!("unknown meta value type '{other}'"))),
    }
}

fn meta_to_json(m: &BTreeMap<String, MetaValue>) -> Json {
    let mut out = BTreeMap::new();
    for (k, v) in m {
        out.insert(k.clone(), metavalue_to_json(v));
    }
    Json::Obj(out)
}

fn meta_from_json(j: &Json) -> Result<BTreeMap<String, MetaValue>> {
    let obj = j.as_obj().ok_or_else(|| bad("meta must be an object"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        out.insert(k.clone(), metavalue_from_json(v)?);
    }
    Ok(out)
}

fn string_map_to_json(m: &BTreeMap<String, String>) -> Json {
    let mut out = BTreeMap::new();
    for (k, v) in m {
        out.insert(k.clone(), Json::Str(v.clone()));
    }
    Json::Obj(out)
}

fn string_map_from_json(j: &Json) -> Result<BTreeMap<String, String>> {
    let obj = j.as_obj().ok_or_else(|| bad("attribute map must be an object"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        out.insert(
            k.clone(),
            v.as_str().map(str::to_string).ok_or_else(|| bad("attribute not a string"))?,
        );
    }
    Ok(out)
}

fn string_vec_from_json(j: &Json, what: &str) -> Result<Vec<String>> {
    j.as_arr()
        .ok_or_else(|| bad(&format!("{what} must be an array")))?
        .iter()
        .map(|x| x.as_str().map(str::to_string).ok_or_else(|| bad(&format!("{what} element"))))
        .collect()
}

fn opt_string_vec_from_json(j: Option<&Json>, what: &str) -> Result<Option<Vec<String>>> {
    match j {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(string_vec_from_json(v, what)?)),
    }
}

// ---------------------------------------------------------------------
// enum codecs (all via the catalog's canonical string spellings)
// ---------------------------------------------------------------------

fn did_type_from(s: &str) -> Result<DidType> {
    match s {
        "FILE" => Ok(DidType::File),
        "DATASET" => Ok(DidType::Dataset),
        "CONTAINER" => Ok(DidType::Container),
        other => Err(bad(&format!("unknown did type '{other}'"))),
    }
}

fn availability_from(s: &str) -> Result<Availability> {
    match s {
        "AVAILABLE" => Ok(Availability::Available),
        "LOST" => Ok(Availability::Lost),
        "DELETED" => Ok(Availability::Deleted),
        other => Err(bad(&format!("unknown availability '{other}'"))),
    }
}

fn replica_state_from(s: &str) -> Result<ReplicaState> {
    match s {
        "AVAILABLE" => Ok(ReplicaState::Available),
        "COPYING" => Ok(ReplicaState::Copying),
        "BAD" => Ok(ReplicaState::Bad),
        "SUSPICIOUS" => Ok(ReplicaState::Suspicious),
        other => Err(bad(&format!("unknown replica state '{other}'"))),
    }
}

fn rule_state_from(s: &str) -> Result<RuleState> {
    match s {
        "OK" => Ok(RuleState::Ok),
        "REPLICATING" => Ok(RuleState::Replicating),
        "STUCK" => Ok(RuleState::Stuck),
        "SUSPENDED" => Ok(RuleState::Suspended),
        other => Err(bad(&format!("unknown rule state '{other}'"))),
    }
}

fn lock_state_to(s: LockState) -> &'static str {
    match s {
        LockState::Ok => "OK",
        LockState::Replicating => "REPLICATING",
        LockState::Stuck => "STUCK",
    }
}

fn lock_state_from(s: &str) -> Result<LockState> {
    match s {
        "OK" => Ok(LockState::Ok),
        "REPLICATING" => Ok(LockState::Replicating),
        "STUCK" => Ok(LockState::Stuck),
        other => Err(bad(&format!("unknown lock state '{other}'"))),
    }
}

fn account_type_to(t: AccountType) -> &'static str {
    match t {
        AccountType::User => "USER",
        AccountType::Group => "GROUP",
        AccountType::Service => "SERVICE",
    }
}

fn account_type_from(s: &str) -> Result<AccountType> {
    match s {
        "USER" => Ok(AccountType::User),
        "GROUP" => Ok(AccountType::Group),
        "SERVICE" => Ok(AccountType::Service),
        other => Err(bad(&format!("unknown account type '{other}'"))),
    }
}

fn auth_type_from(s: &str) -> Result<AuthType> {
    AuthType::parse(s).ok_or_else(|| bad(&format!("unknown auth type '{s}'")))
}

fn path_algorithm_to(a: &PathAlgorithm) -> &'static str {
    match a {
        PathAlgorithm::HashDeterministic => "hash",
        PathAlgorithm::FlatDeterministic => "flat",
        PathAlgorithm::NonDeterministic => "nondet",
    }
}

fn path_algorithm_from(s: &str) -> Result<PathAlgorithm> {
    match s {
        "hash" => Ok(PathAlgorithm::HashDeterministic),
        "flat" => Ok(PathAlgorithm::FlatDeterministic),
        "nondet" => Ok(PathAlgorithm::NonDeterministic),
        other => Err(bad(&format!("unknown path algorithm '{other}'"))),
    }
}

fn request_state_from(s: &str) -> Result<RequestState> {
    RequestState::parse(s).ok_or_else(|| bad(&format!("unknown request state '{s}'")))
}

// ---------------------------------------------------------------------
// row codecs
// ---------------------------------------------------------------------

impl Durable for Did {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("key", didkey_to_json(&self.key))
            .with("did_type", self.did_type.as_str())
            .with("account", self.account.as_str())
            .with("bytes", self.bytes)
            .with("adler32", self.adler32.as_str())
            .with("md5", self.md5.clone())
            .with("guid", self.guid.clone())
            .with("open", self.open)
            .with("monotonic", self.monotonic)
            .with("suppressed", self.suppressed)
            .with("availability", self.availability.as_str())
            .with("meta", meta_to_json(&self.meta))
            .with("created_at", self.created_at)
            .with("expired_at", self.expired_at)
            .with("constituent_of", self.constituent_of.as_ref().map(didkey_to_json))
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Did {
            key: didkey_from_json(j.get("key").ok_or_else(|| bad("did without key"))?)?,
            did_type: did_type_from(j.req_str("did_type")?)?,
            account: req_string(j, "account")?,
            bytes: j.req_u64("bytes")?,
            adler32: req_string(j, "adler32")?,
            md5: opt_string(j, "md5"),
            guid: opt_string(j, "guid"),
            open: req_bool(j, "open")?,
            monotonic: req_bool(j, "monotonic")?,
            suppressed: req_bool(j, "suppressed")?,
            availability: availability_from(j.req_str("availability")?)?,
            meta: meta_from_json(j.get("meta").ok_or_else(|| bad("did without meta"))?)?,
            created_at: j.req_i64("created_at")?,
            expired_at: j.opt_i64("expired_at"),
            constituent_of: opt_didkey_from_json(j.get("constituent_of"))?,
        })
    }

    fn key_to_json(key: &DidKey) -> Json {
        didkey_to_json(key)
    }

    fn key_from_json(j: &Json) -> Result<DidKey> {
        didkey_from_json(j)
    }
}

impl Durable for Attachment {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("parent", didkey_to_json(&self.parent))
            .with("child", didkey_to_json(&self.child))
            .with("created_at", self.created_at)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Attachment {
            parent: didkey_from_json(j.get("parent").ok_or_else(|| bad("attachment parent"))?)?,
            child: didkey_from_json(j.get("child").ok_or_else(|| bad("attachment child"))?)?,
            created_at: j.req_i64("created_at")?,
        })
    }

    fn key_to_json(key: &(DidKey, DidKey)) -> Json {
        Json::Arr(vec![didkey_to_json(&key.0), didkey_to_json(&key.1)])
    }

    fn key_from_json(j: &Json) -> Result<(DidKey, DidKey)> {
        Ok((didkey_from_json(arr_item(j, 0)?)?, didkey_from_json(arr_item(j, 1)?)?))
    }
}

impl Durable for NameTombstone {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("key", didkey_to_json(&self.key))
            .with("deleted_at", self.deleted_at)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(NameTombstone {
            key: didkey_from_json(j.get("key").ok_or_else(|| bad("tombstone key"))?)?,
            deleted_at: j.req_i64("deleted_at")?,
        })
    }

    fn key_to_json(key: &DidKey) -> Json {
        didkey_to_json(key)
    }

    fn key_from_json(j: &Json) -> Result<DidKey> {
        didkey_from_json(j)
    }
}

impl Durable for Replica {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("rse", self.rse.as_str())
            .with("did", didkey_to_json(&self.did))
            .with("bytes", self.bytes)
            .with("state", self.state.as_str())
            .with("pfn", self.pfn.as_str())
            .with("lock_count", self.lock_count)
            .with("tombstone", self.tombstone)
            .with("accessed_at", self.accessed_at)
            .with("created_at", self.created_at)
            .with("error_count", self.error_count)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Replica {
            rse: req_string(j, "rse")?,
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("replica did"))?)?,
            bytes: j.req_u64("bytes")?,
            state: replica_state_from(j.req_str("state")?)?,
            pfn: req_string(j, "pfn")?,
            lock_count: req_u32(j, "lock_count")?,
            tombstone: j.opt_i64("tombstone"),
            accessed_at: j.req_i64("accessed_at")?,
            created_at: j.req_i64("created_at")?,
            error_count: req_u32(j, "error_count")?,
        })
    }

    fn key_to_json(key: &(String, DidKey)) -> Json {
        Json::Arr(vec![Json::Str(key.0.clone()), didkey_to_json(&key.1)])
    }

    fn key_from_json(j: &Json) -> Result<(String, DidKey)> {
        Ok((str_item(j, 0)?, didkey_from_json(arr_item(j, 1)?)?))
    }
}

impl Durable for Rule {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id)
            .with("account", self.account.as_str())
            .with("did", didkey_to_json(&self.did))
            .with("rse_expression", self.rse_expression.as_str())
            .with("copies", self.copies)
            .with("state", self.state.as_str())
            .with("locks_ok", self.locks_ok)
            .with("locks_replicating", self.locks_replicating)
            .with("locks_stuck", self.locks_stuck)
            .with("expires_at", self.expires_at)
            .with("weight", self.weight.clone())
            .with("activity", self.activity.as_str())
            .with("created_at", self.created_at)
            .with("updated_at", self.updated_at)
            .with("child_rule", self.child_rule)
            .with("subscription_id", self.subscription_id)
            .with("purge_replicas", self.purge_replicas)
            .with("stuck_at", self.stuck_at)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Rule {
            id: j.req_u64("id")?,
            account: req_string(j, "account")?,
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("rule did"))?)?,
            rse_expression: req_string(j, "rse_expression")?,
            copies: req_u32(j, "copies")?,
            state: rule_state_from(j.req_str("state")?)?,
            locks_ok: req_u32(j, "locks_ok")?,
            locks_replicating: req_u32(j, "locks_replicating")?,
            locks_stuck: req_u32(j, "locks_stuck")?,
            expires_at: j.opt_i64("expires_at"),
            weight: opt_string(j, "weight"),
            activity: req_string(j, "activity")?,
            created_at: j.req_i64("created_at")?,
            updated_at: j.req_i64("updated_at")?,
            child_rule: j.opt_u64("child_rule"),
            subscription_id: j.opt_u64("subscription_id"),
            purge_replicas: req_bool(j, "purge_replicas")?,
            stuck_at: j.opt_i64("stuck_at"),
        })
    }

    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }

    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| bad("rule key not a u64"))
    }
}

impl Durable for ReplicaLock {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("rule_id", self.rule_id)
            .with("rse", self.rse.as_str())
            .with("did", didkey_to_json(&self.did))
            .with("state", lock_state_to(self.state))
            .with("bytes", self.bytes)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(ReplicaLock {
            rule_id: j.req_u64("rule_id")?,
            rse: req_string(j, "rse")?,
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("lock did"))?)?,
            state: lock_state_from(j.req_str("state")?)?,
            bytes: j.req_u64("bytes")?,
        })
    }

    fn key_to_json(key: &(u64, String, DidKey)) -> Json {
        Json::Arr(vec![
            Json::from(key.0),
            Json::Str(key.1.clone()),
            didkey_to_json(&key.2),
        ])
    }

    fn key_from_json(j: &Json) -> Result<(u64, String, DidKey)> {
        Ok((u64_item(j, 0)?, str_item(j, 1)?, didkey_from_json(arr_item(j, 2)?)?))
    }
}

impl Durable for TransferRequest {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id)
            .with("did", didkey_to_json(&self.did))
            .with("dst_rse", self.dst_rse.as_str())
            .with("rule_id", self.rule_id)
            .with("bytes", self.bytes)
            .with("adler32", self.adler32.as_str())
            .with("activity", self.activity.as_str())
            .with("state", self.state.as_str())
            .with("attempts", self.attempts)
            .with("priority", self.priority as u32)
            .with("path", self.path.clone())
            .with("hop", self.hop)
            .with("src_rse", self.src_rse.clone())
            .with("external_id", self.external_id)
            .with("fts_server", self.fts_server.map(|x| x as u64))
            .with("created_at", self.created_at)
            .with("updated_at", self.updated_at)
            .with("retry_after", self.retry_after)
            .with("last_error", self.last_error.clone())
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(TransferRequest {
            id: j.req_u64("id")?,
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("request did"))?)?,
            dst_rse: req_string(j, "dst_rse")?,
            rule_id: j.req_u64("rule_id")?,
            bytes: j.req_u64("bytes")?,
            adler32: req_string(j, "adler32")?,
            activity: req_string(j, "activity")?,
            state: request_state_from(j.req_str("state")?)?,
            attempts: req_u32(j, "attempts")?,
            priority: req_u8(j, "priority")?,
            path: opt_string_vec_from_json(j.get("path"), "request path")?,
            hop: req_u32(j, "hop")?,
            src_rse: opt_string(j, "src_rse"),
            external_id: j.opt_u64("external_id"),
            fts_server: j.opt_u64("fts_server").map(|x| x as usize),
            created_at: j.req_i64("created_at")?,
            updated_at: j.req_i64("updated_at")?,
            retry_after: j.opt_i64("retry_after"),
            last_error: opt_string(j, "last_error"),
        })
    }

    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }

    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| bad("request key not a u64"))
    }
}

impl Durable for Account {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("name", self.name.as_str())
            .with("account_type", account_type_to(self.account_type))
            .with("email", self.email.as_str())
            .with("created_at", self.created_at)
            .with("suspended", self.suspended)
            .with("admin", self.admin)
            .with("vo", self.vo.as_str())
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Account {
            name: req_string(j, "name")?,
            account_type: account_type_from(j.req_str("account_type")?)?,
            email: req_string(j, "email")?,
            created_at: j.req_i64("created_at")?,
            suspended: req_bool(j, "suspended")?,
            admin: req_bool(j, "admin")?,
            // pre-multi-VO WALs/snapshots carry no vo: default VO
            vo: opt_string(j, "vo").unwrap_or_else(|| DEFAULT_VO.to_string()),
        })
    }

    fn key_to_json(key: &String) -> Json {
        Json::Str(key.clone())
    }

    fn key_from_json(j: &Json) -> Result<String> {
        j.as_str().map(str::to_string).ok_or_else(|| bad("account key not a string"))
    }
}

impl Durable for Identity {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("identity", self.identity.as_str())
            .with("auth_type", self.auth_type.as_str())
            .with("account", self.account.as_str())
            .with("secret", self.secret.clone())
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Identity {
            identity: req_string(j, "identity")?,
            auth_type: auth_type_from(j.req_str("auth_type")?)?,
            account: req_string(j, "account")?,
            secret: opt_string(j, "secret"),
        })
    }

    fn key_to_json(key: &(String, AuthType, String)) -> Json {
        Json::Arr(vec![
            Json::Str(key.0.clone()),
            Json::Str(key.1.as_str().to_string()),
            Json::Str(key.2.clone()),
        ])
    }

    fn key_from_json(j: &Json) -> Result<(String, AuthType, String)> {
        Ok((str_item(j, 0)?, auth_type_from(&str_item(j, 1)?)?, str_item(j, 2)?))
    }
}

impl Durable for Token {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("token", self.token.as_str())
            .with("account", self.account.as_str())
            .with("expires_at", self.expires_at)
            .with("issued_at", self.issued_at)
            .with("vo", self.vo.as_str())
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Token {
            token: req_string(j, "token")?,
            account: req_string(j, "account")?,
            expires_at: j.req_i64("expires_at")?,
            issued_at: j.req_i64("issued_at")?,
            vo: opt_string(j, "vo").unwrap_or_else(|| DEFAULT_VO.to_string()),
        })
    }

    fn key_to_json(key: &String) -> Json {
        Json::Str(key.clone())
    }

    fn key_from_json(j: &Json) -> Result<String> {
        j.as_str().map(str::to_string).ok_or_else(|| bad("token key not a string"))
    }
}

impl Durable for AccountLimit {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("account", self.account.as_str())
            .with("rse", self.rse.as_str())
            .with("bytes", self.bytes)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(AccountLimit {
            account: req_string(j, "account")?,
            rse: req_string(j, "rse")?,
            bytes: j.req_u64("bytes")?,
        })
    }

    fn key_to_json(key: &(String, String)) -> Json {
        Json::Arr(vec![Json::Str(key.0.clone()), Json::Str(key.1.clone())])
    }

    fn key_from_json(j: &Json) -> Result<(String, String)> {
        Ok((str_item(j, 0)?, str_item(j, 1)?))
    }
}

impl Durable for AccountUsage {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("account", self.account.as_str())
            .with("rse", self.rse.as_str())
            .with("bytes", self.bytes)
            .with("files", self.files)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(AccountUsage {
            account: req_string(j, "account")?,
            rse: req_string(j, "rse")?,
            bytes: j.req_u64("bytes")?,
            files: j.req_u64("files")?,
        })
    }

    fn key_to_json(key: &(String, String)) -> Json {
        Json::Arr(vec![Json::Str(key.0.clone()), Json::Str(key.1.clone())])
    }

    fn key_from_json(j: &Json) -> Result<(String, String)> {
        Ok((str_item(j, 0)?, str_item(j, 1)?))
    }
}

impl Durable for OutboxMessage {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id)
            .with("event_type", self.event_type.as_str())
            .with("payload", self.payload.clone())
            .with("created_at", self.created_at)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(OutboxMessage {
            id: j.req_u64("id")?,
            event_type: req_string(j, "event_type")?,
            payload: j.get("payload").cloned().unwrap_or(Json::Null),
            created_at: j.req_i64("created_at")?,
        })
    }

    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }

    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| bad("outbox key not a u64"))
    }
}

impl Durable for BadReplica {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("rse", self.rse.as_str())
            .with("did", didkey_to_json(&self.did))
            .with("reason", self.reason.as_str())
            .with("declared_by", self.declared_by.as_str())
            .with("declared_at", self.declared_at)
            .with("resolved", self.resolved)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(BadReplica {
            rse: req_string(j, "rse")?,
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("bad-replica did"))?)?,
            reason: req_string(j, "reason")?,
            declared_by: req_string(j, "declared_by")?,
            declared_at: j.req_i64("declared_at")?,
            resolved: req_bool(j, "resolved")?,
        })
    }

    fn key_to_json(key: &(String, DidKey)) -> Json {
        Json::Arr(vec![Json::Str(key.0.clone()), didkey_to_json(&key.1)])
    }

    fn key_from_json(j: &Json) -> Result<(String, DidKey)> {
        Ok((str_item(j, 0)?, didkey_from_json(arr_item(j, 1)?)?))
    }
}

impl Durable for Scope {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("name", self.name.as_str())
            .with("account", self.account.as_str())
            .with("created_at", self.created_at)
            .with("vo", self.vo.as_str())
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Scope {
            name: req_string(j, "name")?,
            account: req_string(j, "account")?,
            created_at: j.req_i64("created_at")?,
            vo: opt_string(j, "vo").unwrap_or_else(|| DEFAULT_VO.to_string()),
        })
    }

    fn key_to_json(key: &String) -> Json {
        Json::Str(key.clone())
    }

    fn key_from_json(j: &Json) -> Result<String> {
        j.as_str().map(str::to_string).ok_or_else(|| bad("scope key not a string"))
    }
}

impl Durable for Popularity {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("did", didkey_to_json(&self.did))
            .with("accesses", self.accesses)
            .with("last_access", self.last_access)
            .with("window_accesses", self.window_accesses)
            .with("window_start", self.window_start)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Popularity {
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("popularity did"))?)?,
            accesses: j.req_u64("accesses")?,
            last_access: j.req_i64("last_access")?,
            window_accesses: j.req_u64("window_accesses")?,
            window_start: j.req_i64("window_start")?,
        })
    }

    fn key_to_json(key: &DidKey) -> Json {
        didkey_to_json(key)
    }

    fn key_from_json(j: &Json) -> Result<DidKey> {
        didkey_from_json(j)
    }
}

impl Durable for Heat {
    fn row_to_json(&self) -> Json {
        // f64 scores survive the round trip exactly: the JSON writer
        // emits Rust's shortest-round-trip representation.
        Json::obj()
            .with("did", didkey_to_json(&self.did))
            .with("score", self.score)
            .with("updated_at", self.updated_at)
            .with("accesses", self.accesses)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Heat {
            did: didkey_from_json(j.get("did").ok_or_else(|| bad("heat did"))?)?,
            score: j.get("score").and_then(Json::as_f64).ok_or_else(|| bad("heat score"))?,
            updated_at: j.req_i64("updated_at")?,
            accesses: j.req_u64("accesses")?,
        })
    }

    fn key_to_json(key: &DidKey) -> Json {
        didkey_to_json(key)
    }

    fn key_from_json(j: &Json) -> Result<DidKey> {
        didkey_from_json(j)
    }
}

fn protocol_to_json(p: &Protocol) -> Json {
    Json::obj()
        .with("scheme", p.scheme.as_str())
        .with("hostname", p.hostname.as_str())
        .with("port", p.port as u32)
        .with("prefix", p.prefix.as_str())
        .with("read_priority", p.read_priority as u32)
        .with("write_priority", p.write_priority as u32)
        .with("delete_priority", p.delete_priority as u32)
        .with("tpc_priority", p.tpc_priority as u32)
}

fn protocol_from_json(j: &Json) -> Result<Protocol> {
    Ok(Protocol {
        scheme: req_string(j, "scheme")?,
        hostname: req_string(j, "hostname")?,
        port: j.req_u64("port")? as u16,
        prefix: req_string(j, "prefix")?,
        read_priority: req_u8(j, "read_priority")?,
        write_priority: req_u8(j, "write_priority")?,
        delete_priority: req_u8(j, "delete_priority")?,
        tpc_priority: req_u8(j, "tpc_priority")?,
    })
}

impl Durable for Rse {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("name", self.name.as_str())
            .with("is_tape", self.is_tape)
            .with("volatile", self.volatile)
            .with("path_algorithm", path_algorithm_to(&self.path_algorithm))
            .with("availability_read", self.availability_read)
            .with("availability_write", self.availability_write)
            .with("availability_delete", self.availability_delete)
            .with("attributes", string_map_to_json(&self.attributes))
            .with("protocols", Json::Arr(self.protocols.iter().map(protocol_to_json).collect()))
            .with("created_at", self.created_at)
            .with("deleted", self.deleted)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        let protocols = j
            .get("protocols")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("rse without protocols"))?
            .iter()
            .map(protocol_from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Rse {
            name: req_string(j, "name")?,
            is_tape: req_bool(j, "is_tape")?,
            volatile: req_bool(j, "volatile")?,
            path_algorithm: path_algorithm_from(j.req_str("path_algorithm")?)?,
            availability_read: req_bool(j, "availability_read")?,
            availability_write: req_bool(j, "availability_write")?,
            availability_delete: req_bool(j, "availability_delete")?,
            attributes: string_map_from_json(
                j.get("attributes").ok_or_else(|| bad("rse without attributes"))?,
            )?,
            protocols,
            created_at: j.req_i64("created_at")?,
            deleted: req_bool(j, "deleted")?,
        })
    }

    fn key_to_json(key: &String) -> Json {
        Json::Str(key.clone())
    }

    fn key_from_json(j: &Json) -> Result<String> {
        j.as_str().map(str::to_string).ok_or_else(|| bad("rse key not a string"))
    }
}

impl Durable for Distance {
    fn row_to_json(&self) -> Json {
        Json::obj()
            .with("src", self.src.as_str())
            .with("dst", self.dst.as_str())
            .with("ranking", self.ranking)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        Ok(Distance {
            src: req_string(j, "src")?,
            dst: req_string(j, "dst")?,
            ranking: req_u32(j, "ranking")?,
        })
    }

    fn key_to_json(key: &(String, String)) -> Json {
        Json::Arr(vec![Json::Str(key.0.clone()), Json::Str(key.1.clone())])
    }

    fn key_from_json(j: &Json) -> Result<(String, String)> {
        Ok((str_item(j, 0)?, str_item(j, 1)?))
    }
}

impl Durable for Subscription {
    fn row_to_json(&self) -> Json {
        let rules: Vec<Json> = self
            .rules
            .iter()
            .map(|r| {
                Json::obj()
                    .with("rse_expression", r.rse_expression.as_str())
                    .with("copies", r.copies)
                    .with("lifetime_ms", r.lifetime_ms)
                    .with("activity", r.activity.as_str())
            })
            .collect();
        Json::obj()
            .with("id", self.id)
            .with("name", self.name.as_str())
            .with("account", self.account.as_str())
            .with("scopes", self.filter.scopes.clone())
            .with(
                "did_types",
                self.filter
                    .did_types
                    .iter()
                    .map(|t| t.as_str().to_string())
                    .collect::<Vec<_>>(),
            )
            // the canonical printer; parse(print(e)) == e is
            // property-tested in core::metaexpr
            .with("expr", self.filter.expr.as_ref().map(|e| e.to_string()))
            .with("rules", Json::Arr(rules))
            .with("enabled", self.enabled)
            .with("created_at", self.created_at)
            .with("matched", self.matched)
    }

    fn row_from_json(j: &Json) -> Result<Self> {
        let did_types = j
            .get("did_types")
            .ok_or_else(|| bad("subscription without did_types"))
            .and_then(|v| string_vec_from_json(v, "did_types"))?
            .iter()
            .map(|s| did_type_from(s))
            .collect::<Result<Vec<_>>>()?;
        let rules = j
            .get("rules")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("subscription without rules"))?
            .iter()
            .map(|r| {
                Ok(SubscriptionRule {
                    rse_expression: req_string(r, "rse_expression")?,
                    copies: req_u32(r, "copies")?,
                    lifetime_ms: r.opt_i64("lifetime_ms"),
                    activity: req_string(r, "activity")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Subscription {
            id: j.req_u64("id")?,
            name: req_string(j, "name")?,
            account: req_string(j, "account")?,
            filter: SubscriptionFilter {
                scopes: j
                    .get("scopes")
                    .ok_or_else(|| bad("subscription without scopes"))
                    .and_then(|v| string_vec_from_json(v, "scopes"))?,
                did_types,
                expr: j.opt_str("expr").map(metaexpr::parse).transpose()?,
            },
            rules,
            enabled: req_bool(j, "enabled")?,
            created_at: j.req_i64("created_at")?,
            matched: j.req_u64("matched")?,
        })
    }

    fn key_to_json(key: &u64) -> Json {
        Json::from(*key)
    }

    fn key_from_json(j: &Json) -> Result<u64> {
        j.as_u64().ok_or_else(|| bad("subscription key not a u64"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Row;

    /// Round-trip a row through its JSON codec and assert the encoding
    /// is a fixpoint (and the key survives independently).
    fn rt<V: Durable>(v: &V) {
        let j = v.row_to_json();
        // the serialized form survives a text round-trip through jsonx
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j, "jsonx round trip for {text}");
        let back = V::row_from_json(&j).unwrap();
        assert_eq!(back.row_to_json(), j, "codec fixpoint");
        assert!(back.key() == v.key(), "key survives the row codec");
        let kj = V::key_to_json(&v.key());
        let kb = V::key_from_json(&kj).unwrap();
        assert!(kb == v.key(), "key codec round trip");
    }

    fn key() -> DidKey {
        DidKey::new("data18", "raw.0001")
    }

    #[test]
    fn did_round_trip_with_typed_meta() {
        let mut meta = BTreeMap::new();
        meta.insert("run".to_string(), MetaValue::Int(358_031));
        meta.insert("big".to_string(), MetaValue::Int(i64::MAX - 1));
        meta.insert("neg".to_string(), MetaValue::Int(i64::MIN + 1));
        meta.insert("eff".to_string(), MetaValue::Float(0.1 + 0.2));
        meta.insert("zero".to_string(), MetaValue::Float(-0.0));
        meta.insert("ok".to_string(), MetaValue::Bool(true));
        meta.insert("lexint".to_string(), MetaValue::Str("358031".to_string()));
        let did = Did {
            key: key(),
            did_type: DidType::Dataset,
            account: "root".into(),
            bytes: 123_456_789_000,
            adler32: "11e60398".into(),
            md5: Some("d41d8cd98f00b204e9800998ecf8427e".into()),
            guid: None,
            open: true,
            monotonic: false,
            suppressed: false,
            availability: Availability::Available,
            meta,
            created_at: 1_600_000_000_123,
            expired_at: Some(1_700_000_000_000),
            constituent_of: Some(DidKey::new("data18", "archive.zip")),
        };
        rt(&did);
        // typed meta decodes to the same variants, not re-lexed
        let back = Did::row_from_json(&did.row_to_json()).unwrap();
        assert_eq!(back.meta["run"], MetaValue::Int(358_031));
        assert_eq!(back.meta["big"], MetaValue::Int(i64::MAX - 1));
        assert!(matches!(back.meta["lexint"], MetaValue::Str(_)), "string stays string");
        match back.meta["zero"] {
            MetaValue::Float(f) => assert!(f == 0.0 && f.is_sign_positive(), "-0 canonicalized"),
            ref other => panic!("expected float, got {other:?}"),
        }
    }

    #[test]
    fn replica_rule_lock_request_round_trips() {
        rt(&Replica {
            rse: "CERN-DISK".into(),
            did: key(),
            bytes: 42,
            state: ReplicaState::Copying,
            pfn: "/data18/aa/bb/raw.0001".into(),
            lock_count: 3,
            tombstone: Some(1_600_000_100_000),
            accessed_at: 7,
            created_at: 6,
            error_count: 1,
        });
        rt(&Rule {
            id: 17,
            account: "root".into(),
            did: key(),
            rse_expression: "tier=1&type=disk".into(),
            copies: 2,
            state: RuleState::Replicating,
            locks_ok: 1,
            locks_replicating: 2,
            locks_stuck: 0,
            expires_at: None,
            weight: Some("freespace".into()),
            activity: "T0 Export".into(),
            created_at: 1,
            updated_at: 2,
            child_rule: Some(19),
            subscription_id: None,
            purge_replicas: true,
            stuck_at: Some(99),
        });
        rt(&ReplicaLock {
            rule_id: 17,
            rse: "CERN-DISK".into(),
            did: key(),
            state: LockState::Stuck,
            bytes: 42,
        });
        rt(&TransferRequest {
            id: 5,
            did: key(),
            dst_rse: "BNL-TAPE".into(),
            rule_id: 17,
            bytes: 42,
            adler32: "11e60398".into(),
            activity: "Production".into(),
            state: RequestState::Submitted,
            attempts: 2,
            priority: PRIORITY_BOOSTED,
            path: Some(vec!["CERN-DISK".into(), "FZK-DISK".into(), "BNL-TAPE".into()]),
            hop: 1,
            src_rse: Some("CERN-DISK".into()),
            external_id: Some(4242),
            fts_server: Some(1),
            created_at: 1,
            updated_at: 2,
            retry_after: None,
            last_error: Some("checksum mismatch: boom".into()),
        });
        // direct transfer: no path
        rt(&TransferRequest {
            id: 6,
            did: key(),
            dst_rse: "BNL-TAPE".into(),
            rule_id: 17,
            bytes: 1,
            adler32: "x".into(),
            activity: "Analysis".into(),
            state: RequestState::Waiting,
            attempts: 0,
            priority: PRIORITY_NORMAL,
            path: None,
            hop: 0,
            src_rse: None,
            external_id: None,
            fts_server: None,
            created_at: 0,
            updated_at: 0,
            retry_after: Some(50),
            last_error: None,
        });
    }

    #[test]
    fn account_identity_token_quota_round_trips() {
        rt(&Account {
            name: "alice".into(),
            account_type: AccountType::User,
            email: "alice@cern.ch".into(),
            created_at: 3,
            suspended: false,
            admin: false,
            vo: "atlas".into(),
        });
        rt(&Identity {
            identity: "CN=Alice/O=CERN".into(),
            auth_type: AuthType::X509,
            account: "alice".into(),
            secret: None,
        });
        rt(&Identity {
            identity: "alice".into(),
            auth_type: AuthType::UserPass,
            account: "alice".into(),
            secret: Some("deadbeef".into()),
        });
        rt(&Token {
            token: "alice-0123456789abcdef".into(),
            account: "alice".into(),
            expires_at: 10,
            issued_at: 5,
            vo: "atlas".into(),
        });
        rt(&AccountLimit { account: "alice".into(), rse: "CERN-DISK".into(), bytes: 1u64 << 40 });
        rt(&AccountUsage {
            account: "alice".into(),
            rse: "CERN-DISK".into(),
            bytes: 7,
            files: 2,
        });
    }

    /// WALs and snapshots written before the multi-VO change carry no
    /// `vo` key: accounts, tokens, and scopes must decode into the
    /// default VO rather than failing recovery.
    #[test]
    fn pre_multi_vo_rows_decode_into_default_vo() {
        let acc = Account::row_from_json(
            &Json::parse(
                concat!(
                    r#"{"name":"alice","account_type":"USER","email":"","#,
                    r#""created_at":1,"suspended":false,"admin":false}"#
                ),
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(acc.vo, crate::core::types::DEFAULT_VO);
        let tok = Token::row_from_json(
            &Json::parse(
                r#"{"token":"alice-01","account":"alice","expires_at":10,"issued_at":5}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(tok.vo, crate::core::types::DEFAULT_VO);
        let sc = Scope::row_from_json(
            &Json::parse(r#"{"name":"data18","account":"root","created_at":0}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(sc.vo, crate::core::types::DEFAULT_VO);
    }

    #[test]
    fn namespace_and_misc_round_trips() {
        rt(&Attachment { parent: DidKey::new("data18", "ds"), child: key(), created_at: 1 });
        rt(&NameTombstone { key: key(), deleted_at: 9 });
        rt(&Scope { name: "data18".into(), account: "root".into(), created_at: 0, vo: "def".into() });
        rt(&Popularity {
            did: key(),
            accesses: 12,
            last_access: 10,
            window_accesses: 3,
            window_start: 8,
        });
        // a fractional (decayed) score must survive the text round trip
        rt(&Heat { did: key(), score: 4.734_621_993_117, updated_at: 11, accesses: 12 });
        rt(&BadReplica {
            rse: "UK-T2-1".into(),
            did: key(),
            reason: "bit rot".into(),
            declared_by: "auditor".into(),
            declared_at: 4,
            resolved: false,
        });
        rt(&OutboxMessage {
            id: 77,
            event_type: "transfer-done".into(),
            payload: Json::obj().with("rule_id", 17).with("nested", Json::Arr(vec![
                Json::Null,
                Json::Bool(true),
                Json::Str("x\ny".into()),
            ])),
            created_at: 2,
        });
        rt(&Distance { src: "A".into(), dst: "B".into(), ranking: 3 });
    }

    #[test]
    fn rse_round_trip_with_protocols_and_attributes() {
        let mut rse = Rse::new("CERN-PROD", 123).with_attr("tier", "0").with_tape();
        rse.path_algorithm = PathAlgorithm::NonDeterministic;
        rse.availability_write = false;
        rse.volatile = true;
        rse.deleted = true;
        rt(&rse);
        let back = Rse::row_from_json(&rse.row_to_json()).unwrap();
        assert_eq!(back.attr("tier"), Some("0"));
        assert_eq!(back.protocols.len(), rse.protocols.len());
        assert_eq!(back.protocols[0].port, rse.protocols[0].port);
        assert_eq!(back.path_algorithm, PathAlgorithm::NonDeterministic);
    }

    #[test]
    fn subscription_round_trip_with_meta_expr() {
        let filter = SubscriptionFilter {
            scopes: vec!["data18".into()],
            did_types: vec![DidType::Dataset, DidType::File],
            expr: Some(
                metaexpr::parse("datatype=RAW AND run>=358000 AND name=data18*").unwrap(),
            ),
        };
        let sub = Subscription {
            id: 9,
            name: "raw-to-tape".into(),
            account: "root".into(),
            filter,
            rules: vec![
                SubscriptionRule {
                    rse_expression: "tape".into(),
                    copies: 1,
                    lifetime_ms: None,
                    activity: "T0 Export".into(),
                },
                SubscriptionRule {
                    rse_expression: "tier=1".into(),
                    copies: 2,
                    lifetime_ms: Some(86_400_000),
                    activity: "Data Consolidation".into(),
                },
            ],
            enabled: true,
            created_at: 5,
            matched: 42,
        };
        rt(&sub);
        let back = Subscription::row_from_json(&sub.row_to_json()).unwrap();
        assert_eq!(back.filter.expr, sub.filter.expr, "meta-expr survives via printer");
        assert_eq!(back.rules.len(), 2);
        assert_eq!(back.rules[1].lifetime_ms, Some(86_400_000));
        // a filter without expr round-trips to None, not Any
        let bare = Subscription { filter: SubscriptionFilter::default(), ..sub };
        let back = Subscription::row_from_json(&bare.row_to_json()).unwrap();
        assert!(back.filter.expr.is_none());
    }

    #[test]
    fn malformed_inputs_error_instead_of_panicking() {
        assert!(Did::row_from_json(&Json::obj()).is_err());
        assert!(Rule::row_from_json(&Json::obj().with("id", 1)).is_err());
        assert!(Rse::key_from_json(&Json::Num(3.0)).is_err());
        assert!(Replica::key_from_json(&Json::Arr(vec![Json::Str("A".into())])).is_err());
        assert!(metavalue_from_json(&Json::obj().with("t", "i").with("v", "xx")).is_err());
        assert!(metavalue_from_json(&Json::obj().with("t", "?").with("v", "1")).is_err());
        assert!(did_type_from("BLOB").is_err());
        assert!(lock_state_from("NOPE").is_err());
        assert!(path_algorithm_from("magic").is_err());
    }

    #[test]
    fn float_meta_values_survive_exactly() {
        for f in [0.1 + 0.2, 1.0 / 3.0, f64::MAX, f64::MIN_POSITIVE, -123.456e-78] {
            let v = MetaValue::Float(f);
            let back = metavalue_from_json(&metavalue_to_json(&v)).unwrap();
            match back {
                MetaValue::Float(g) => assert!(g == f, "float {f} survived as {g}"),
                other => panic!("expected float, got {other:?}"),
            }
        }
    }
}
