//! Replica catalog operations: registration, state transitions,
//! tombstones, access traces, bad/suspicious handling (paper §2.4, §4.3,
//! §4.4).

use std::collections::{BTreeMap, BTreeSet};

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};

use super::types::*;
use super::Catalog;

/// One replica in a bulk registration ([`Catalog::add_replicas_bulk`]).
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub did: DidKey,
    pub state: ReplicaState,
    /// Required for non-deterministic RSEs, optional otherwise.
    pub pfn: Option<String>,
}

impl ReplicaSpec {
    pub fn new(did: DidKey, state: ReplicaState) -> Self {
        ReplicaSpec { did, state, pfn: None }
    }

    pub fn with_pfn(mut self, pfn: &str) -> Self {
        self.pfn = Some(pfn.to_string());
        self
    }
}

impl Catalog {
    /// Register a replica for an existing file DID. For deterministic RSEs
    /// the pfn comes from lfn2pfn; for non-deterministic RSEs the caller
    /// must provide it ("continue to provide full paths", §2.4).
    pub fn add_replica(
        &self,
        rse: &str,
        did: &DidKey,
        state: ReplicaState,
        pfn: Option<&str>,
    ) -> Result<Replica> {
        let d = self.get_did(did)?;
        if d.did_type != DidType::File {
            return Err(RucioError::UnsupportedOperation(format!(
                "{did} is not a file"
            )));
        }
        let r = self.get_rse(rse)?;
        let pfn = match (pfn, r.lfn2pfn(&did.scope, &did.name)) {
            (Some(p), _) => p.to_string(),
            (None, Some(p)) => p,
            (None, None) => {
                return Err(RucioError::InvalidValue(format!(
                    "RSE {rse} is non-deterministic: pfn required"
                )))
            }
        };
        let now = self.now();
        let replica = Replica {
            rse: rse.to_string(),
            did: did.clone(),
            bytes: d.bytes,
            state,
            pfn,
            lock_count: 0,
            tombstone: if state == ReplicaState::Available {
                // Unprotected from birth until a rule locks it (§2.5) —
                // but with the cache grace period, so in-flight transfers
                // sourcing from it are not starved by the reaper.
                Some(now + self.cfg.get_duration_ms("reaper", "tombstone_grace", 24 * 3_600_000))
            } else {
                None
            },
            accessed_at: now,
            created_at: now,
            error_count: 0,
        };
        self.replicas.insert(replica.clone(), now)?;
        if state == ReplicaState::Available {
            self.refresh_availability(did);
        }
        self.metrics.incr("replicas.added", 1);
        Ok(replica)
    }

    /// Register many replicas on one RSE in a single batched commit
    /// (paper §3.6 bulk operations; the `POST /replicas/bulk` route).
    /// Validation happens up front and the table insert is atomic: on any
    /// bad spec (unknown DID, collection DID, missing pfn, duplicate) the
    /// whole call fails with no partial state. Returns the number of
    /// replicas registered (rows move into the table — no hot-path clone;
    /// fetch individual rows back via [`Catalog::get_replica`]).
    pub fn add_replicas_bulk(&self, rse: &str, specs: &[ReplicaSpec]) -> Result<usize> {
        let r = self.get_rse(rse)?;
        let now = self.now();
        let grace = self.cfg.get_duration_ms("reaper", "tombstone_grace", 24 * 3_600_000);
        let mut rows: Vec<Replica> = Vec::with_capacity(specs.len());
        for spec in specs {
            let d = self.get_did(&spec.did)?;
            if d.did_type != DidType::File {
                return Err(RucioError::UnsupportedOperation(format!(
                    "{} is not a file",
                    spec.did
                )));
            }
            let pfn = match (&spec.pfn, r.lfn2pfn(&spec.did.scope, &spec.did.name)) {
                (Some(p), _) => p.clone(),
                (None, Some(p)) => p,
                (None, None) => {
                    return Err(RucioError::InvalidValue(format!(
                        "RSE {rse} is non-deterministic: pfn required"
                    )))
                }
            };
            rows.push(Replica {
                rse: rse.to_string(),
                did: spec.did.clone(),
                bytes: d.bytes,
                state: spec.state,
                pfn,
                lock_count: 0,
                tombstone: if spec.state == ReplicaState::Available {
                    Some(now + grace)
                } else {
                    None
                },
                accessed_at: now,
                created_at: now,
                error_count: 0,
            });
        }
        let added = self.replicas.insert_bulk(rows, now)?;
        for spec in specs {
            if spec.state == ReplicaState::Available {
                self.refresh_availability(&spec.did);
            }
        }
        self.metrics.incr("replicas.added", added as u64);
        Ok(added)
    }

    /// Remove many replicas in one batched commit (the reaper's drain
    /// path). Missing keys are skipped; availability is re-derived once
    /// per affected DID. Returns the removed rows.
    pub fn remove_replicas_bulk(&self, keys: &[(String, DidKey)]) -> Vec<Replica> {
        if keys.is_empty() {
            return Vec::new();
        }
        let now = self.now();
        let removed = self.replicas.remove_bulk(keys, now);
        let mut seen: BTreeSet<DidKey> = BTreeSet::new();
        for rep in &removed {
            if seen.insert(rep.did.clone()) {
                self.refresh_availability(&rep.did);
            }
        }
        self.metrics.incr("replicas.removed", removed.len() as u64);
        removed
    }

    // ------------------------------------------------------------------
    // bulk transfer-request state transitions (conveyor drain path)
    // ------------------------------------------------------------------

    /// Promote every due RETRY request back to QUEUED in one batched
    /// commit (the conveyor submitter's pre-pass).
    pub fn promote_due_retries(&self, now: EpochMs) -> usize {
        let due: Vec<u64> = self
            .requests_by_state
            .get(&RequestState::Retry)
            .into_iter()
            .filter(|id| {
                self.requests
                    .get(id)
                    .map(|r| r.retry_after.map(|t| t <= now).unwrap_or(true))
                    .unwrap_or(false)
            })
            .collect();
        if due.is_empty() {
            return 0;
        }
        // State-machine gated: a request canceled (or completed) between
        // the index snapshot and this commit must not be resurrected.
        let mut promoted = 0;
        self.requests.update_bulk(&due, now, |r| {
            if let Ok(next) = request_transition(r.state, RequestEvent::RetryDue) {
                r.state = next;
                r.retry_after = None;
                promoted += 1;
            }
        });
        promoted
    }

    /// Flip a picked batch of requests to SUBMITTED with their chosen
    /// source RSE and FTS server, in one commit. Only legally submittable
    /// rows flip (the state machine guards against racing transitions).
    pub fn mark_requests_submitted(&self, picks: &[(u64, String, usize)], now: EpochMs) {
        if picks.is_empty() {
            return;
        }
        let by_id: BTreeMap<u64, (&str, usize)> = picks
            .iter()
            .map(|(id, src, fts)| (*id, (src.as_str(), *fts)))
            .collect();
        let ids: Vec<u64> = picks.iter().map(|(id, _, _)| *id).collect();
        self.requests.update_bulk(&ids, now, |r| {
            if let Some((src, fts)) = by_id.get(&r.id) {
                if let Ok(next) = request_transition(r.state, RequestEvent::Submit) {
                    r.state = next;
                    r.src_rse = Some((*src).to_string());
                    r.fts_server = Some(*fts);
                    r.updated_at = now;
                }
            }
        });
    }

    /// Admission release (the throttler's commit path): flip a batch of
    /// WAITING requests to QUEUED in one batched commit, recording the
    /// throttler's estimated source as a hint on the row — later ticks
    /// charge the link budget from the hint instead of re-ranking every
    /// admitted request (the submitter overwrites it with its actual
    /// pick at submission). Returns how many actually flipped.
    pub fn release_waiting_requests(
        &self,
        releases: &[(u64, Option<String>)],
        now: EpochMs,
    ) -> usize {
        if releases.is_empty() {
            return 0;
        }
        let hints: BTreeMap<u64, &Option<String>> =
            releases.iter().map(|(id, hint)| (*id, hint)).collect();
        let ids: Vec<u64> = releases.iter().map(|(id, _)| *id).collect();
        let mut released = 0;
        self.requests.update_bulk(&ids, now, |r| {
            if let Ok(next) = request_transition(r.state, RequestEvent::Release) {
                r.state = next;
                r.updated_at = now;
                if let Some(Some(hint)) = hints.get(&r.id) {
                    r.src_rse = Some(hint.clone());
                }
                released += 1;
            }
        });
        self.metrics.incr("throttler.released", released as u64);
        released
    }

    /// Record a planned multi-hop chain on a request (submitter, after
    /// the path planner ran). The chain starts at hop 0.
    pub fn set_request_path(&self, request_id: u64, path: Vec<String>) {
        let now = self.now();
        self.requests.update(&request_id, now, |r| {
            r.path = Some(path);
            r.hop = 0;
            r.updated_at = now;
        });
        self.metrics.incr("conveyor.multihop.planned", 1);
    }

    /// Raise a request's scheduling priority (`POST /requests/{id}/boost`):
    /// a still-WAITING request bypasses the throttler queue immediately,
    /// and every submission from here on (the next hop, any retry, the
    /// pending submission of a QUEUED request) carries the boosted
    /// priority into FTS, which starts it first on a contended link.
    /// Limitation: a job already handed to FTS keeps the priority it was
    /// submitted with — the catalog has no handle on the transfer tool's
    /// internal queue (matching upstream, where reshuffling an in-flight
    /// FTS job is not possible either).
    pub fn boost_request(&self, request_id: u64) -> Result<TransferRequest> {
        let now = self.now();
        let req = self
            .requests
            .get(&request_id)
            .ok_or_else(|| RucioError::RequestNotFound(request_id.to_string()))?;
        if req.state.is_terminal() {
            return Err(RucioError::InvalidValue(format!(
                "request {request_id} is terminal ({})",
                req.state.as_str()
            )));
        }
        self.requests.update(&request_id, now, |r| {
            r.priority = PRIORITY_BOOSTED;
            if let Ok(next) = request_transition(r.state, RequestEvent::Release) {
                r.state = next;
            }
            r.updated_at = now;
        });
        self.metrics.incr("requests.boosted", 1);
        self.requests
            .get(&request_id)
            .ok_or_else(|| RucioError::RequestNotFound(request_id.to_string()))
    }

    /// Ensure a staging stub exists for a multi-hop chain: an unlocked
    /// COPYING replica at the intermediate RSE that the hop's transfer
    /// will fill. An existing replica row (any state) is reused — with
    /// its tombstone cleared, so a previous chain's reaper marker cannot
    /// delete the new chain's hop source from under it (it is re-set when
    /// this chain completes or unwinds).
    pub fn ensure_staging_stub(&self, rse: &str, did: &DidKey) -> Result<Replica> {
        let key = (rse.to_string(), did.clone());
        if let Some(rep) = self.replicas.get(&key) {
            if rep.tombstone.is_some() {
                let now = self.now();
                return Ok(self
                    .replicas
                    .update(&key, now, |r| r.tombstone = None)
                    .unwrap_or(rep));
            }
            return Ok(rep);
        }
        // Fresh stub: born through the regular registration path (one
        // place constructs replica rows); non-deterministic staging RSEs
        // get a synthetic staging pfn.
        let r = self.get_rse(rse)?;
        let pfn = r
            .lfn2pfn(&did.scope, &did.name)
            .unwrap_or_else(|| format!("/staging/{}/{}", did.scope, did.name));
        let rep = self.add_replica(rse, did, ReplicaState::Copying, Some(&pfn))?;
        self.metrics.incr("conveyor.multihop.stubs_created", 1);
        Ok(rep)
    }

    /// Record the FTS external ids of a submitted batch in one commit.
    pub fn record_external_ids(&self, pairs: &[(u64, u64)], now: EpochMs) {
        if pairs.is_empty() {
            return;
        }
        let by_id: BTreeMap<u64, u64> = pairs.iter().copied().collect();
        let ids: Vec<u64> = pairs.iter().map(|(id, _)| *id).collect();
        self.requests.update_bulk(&ids, now, |r| {
            if let Some(ext) = by_id.get(&r.id) {
                r.external_id = Some(*ext);
            }
        });
    }

    pub fn get_replica(&self, rse: &str, did: &DidKey) -> Result<Replica> {
        self.replicas
            .get(&(rse.to_string(), did.clone()))
            .ok_or_else(|| RucioError::ReplicaNotFound(format!("{did} @ {rse}")))
    }

    /// All replicas of a DID. For archive constituents this resolves to
    /// the archive's replicas (§2.2: "the appropriate archive files will
    /// be used instead").
    pub fn list_replicas(&self, did: &DidKey) -> Vec<Replica> {
        let direct: Vec<Replica> = self
            .replicas_by_did
            .get(did)
            .into_iter()
            .filter_map(|k| self.replicas.get(&k))
            .collect();
        if direct.is_empty() {
            if let Ok(d) = self.get_did(did) {
                if let Some(archive) = d.constituent_of {
                    return self.list_replicas(&archive);
                }
            }
        }
        direct
    }

    /// Available replicas only (download/transfer source candidates).
    pub fn available_replicas(&self, did: &DidKey) -> Vec<Replica> {
        self.list_replicas(did)
            .into_iter()
            .filter(|r| r.state == ReplicaState::Available)
            .collect()
    }

    /// Rank source replicas by distance to `dst_rse` (§2.4: "distance
    /// influences the sorting of files when considering sources").
    /// Unconnected sources and RSEs whose read availability is switched
    /// off (outage / decommissioning) are excluded.
    pub fn ranked_sources(&self, did: &DidKey, dst_rse: &str) -> Vec<(Replica, u32)> {
        let mut sources: Vec<(Replica, u32)> = self
            .available_replicas(did)
            .into_iter()
            .filter(|r| r.rse != dst_rse)
            .filter(|r| {
                self.get_rse(&r.rse)
                    .map(|x| x.availability_read)
                    .unwrap_or(false)
            })
            .filter_map(|r| self.distance(&r.rse, dst_rse).map(|d| (r, d)))
            .collect();
        sources.sort_by_key(|(r, d)| (*d, r.rse.clone()));
        sources
    }

    /// Flip a replica to Available (transfer-finisher / upload path).
    pub fn replica_available(&self, rse: &str, did: &DidKey) -> Result<()> {
        self.get_replica(rse, did)?;
        let now = self.now();
        self.replicas.update(&(rse.to_string(), did.clone()), now, |r| {
            r.state = ReplicaState::Available;
            r.error_count = 0;
        });
        self.refresh_availability(did);
        Ok(())
    }

    /// Record an access (trace ingestion): bumps replica access time and
    /// DID popularity (LRU + placement signals, §4.3/§6.1).
    pub fn touch_replica(&self, rse: &str, did: &DidKey) {
        let now = self.now();
        self.replicas.update(&(rse.to_string(), did.clone()), now, |r| {
            r.accessed_at = now;
        });
        self.touch_popularity(did, now);
        // Dataset-level popularity: bump immediate parents too.
        for parent in self.list_parents(did) {
            self.touch_popularity(&parent, now);
        }
    }

    /// Record a *write* access (upload/put traces): refreshes the replica
    /// access timestamp — so freshly written data is not an immediate LRU
    /// victim — without bumping DID popularity. Popularity is a *read*
    /// signal (§4.3 LRU deletion, §6.1 placement); folding writes into it
    /// would inflate the very data that has never been read.
    pub fn touch_replica_access(&self, rse: &str, did: &DidKey) {
        let now = self.now();
        self.replicas.update(&(rse.to_string(), did.clone()), now, |r| {
            r.accessed_at = now;
        });
    }

    pub(crate) fn touch_popularity(&self, did: &DidKey, now: EpochMs) {
        let window = self.cfg.get_duration_ms("popularity", "window", 14 * 24 * 3_600_000);
        if self.popularity.contains(did) {
            self.popularity.update(did, now, |p| {
                p.accesses += 1;
                p.last_access = now;
                if now - p.window_start > window {
                    p.window_accesses = 1;
                    p.window_start = now;
                } else {
                    p.window_accesses += 1;
                }
            });
        } else {
            let _ = self.popularity.insert(
                Popularity {
                    did: did.clone(),
                    accesses: 1,
                    last_access: now,
                    window_accesses: 1,
                    window_start: now,
                },
                now,
            );
        }
        self.touch_heat(did, now);
    }

    /// Fold one read access into the decayed heat score. Always called
    /// from [`Catalog::touch_popularity`] so the lifetime access tallies
    /// of the two tables stay in lock-step (a checked invariant).
    fn touch_heat(&self, did: &DidKey, now: EpochMs) {
        let half_life = self.heat_half_life_ms();
        if self.heat.contains(did) {
            self.heat.update(did, now, |h| {
                h.score = decay_score(h.score, h.updated_at, now, half_life) + 1.0;
                h.updated_at = now;
                h.accesses += 1;
            });
        } else {
            let _ = self.heat.insert(
                Heat { did: did.clone(), score: 1.0, updated_at: now, accesses: 1 },
                now,
            );
        }
    }

    /// The configured heat half-life (`[heat] half_life`, default 24h).
    pub fn heat_half_life_ms(&self) -> i64 {
        self.cfg.get_duration_ms("heat", "half_life", 24 * 3_600_000)
    }

    /// Current decayed heat score for a DID (0.0 if never read).
    pub fn heat_score(&self, did: &DidKey, now: EpochMs) -> f64 {
        let half_life = self.heat_half_life_ms();
        self.heat.get(did).map(|h| h.score_at(now, half_life)).unwrap_or(0.0)
    }

    /// The `n` hottest DIDs by decayed score at `now`, hottest first
    /// (score ties broken by DID for determinism). Entries whose score
    /// has decayed below `floor` are skipped.
    pub fn hottest_dids(&self, now: EpochMs, n: usize, floor: f64) -> Vec<(DidKey, f64)> {
        let half_life = self.heat_half_life_ms();
        let mut hot: Vec<(DidKey, f64)> = self.heat.fold(Vec::new(), |mut acc, h| {
            let s = h.score_at(now, half_life);
            if s >= floor {
                acc.push((h.did.clone(), s));
            }
            acc
        });
        hot.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then_with(|| a.0.cmp(&b.0)));
        hot.truncate(n);
        hot
    }

    /// Declare a replica suspicious (download failure, checksum mismatch).
    /// Escalates to Bad after `suspicious_threshold` strikes (§2.4: "the
    /// replica will be flagged as suspicious"; §4.4).
    pub fn declare_suspicious(&self, rse: &str, did: &DidKey, reason: &str) -> Result<()> {
        let threshold = self.cfg.get_i64("replicas", "suspicious_threshold", 3) as u32;
        let rep = self.get_replica(rse, did)?;
        let now = self.now();
        if rep.error_count + 1 >= threshold {
            self.declare_bad(rse, did, reason, "system")?;
        } else {
            self.replicas.update(&(rse.to_string(), did.clone()), now, |r| {
                r.error_count += 1;
                r.state = ReplicaState::Suspicious;
            });
            self.metrics.incr("replicas.suspicious", 1);
        }
        Ok(())
    }

    /// Declare a replica bad (privileged accounts or Rucio itself, §4.4);
    /// the necromancer daemon recovers it.
    pub fn declare_bad(&self, rse: &str, did: &DidKey, reason: &str, by: &str) -> Result<()> {
        self.get_replica(rse, did)?;
        let now = self.now();
        self.replicas.update(&(rse.to_string(), did.clone()), now, |r| {
            r.state = ReplicaState::Bad;
        });
        // A bad replica can no longer back its locks: flip them STUCK in
        // the same operation, so no rule ever sits in OK on top of a bad
        // copy (system invariant; the necromancer relocates them later).
        self.stick_locks_on_replica(rse, did, now);
        self.bad_replicas.upsert(
            BadReplica {
                rse: rse.to_string(),
                did: did.clone(),
                reason: reason.to_string(),
                declared_by: by.to_string(),
                declared_at: now,
                resolved: false,
            },
            now,
        );
        self.refresh_availability(did);
        self.metrics.incr("replicas.declared_bad", 1);
        self.notify(
            "bad-replica",
            crate::jsonx::Json::obj()
                .with("rse", rse)
                .with("scope", did.scope.as_str())
                .with("name", did.name.as_str())
                .with("reason", reason),
        );
        Ok(())
    }

    /// Physically-gone replica removal (reaper success path / necromancer
    /// last-copy handling). Adjusts DID availability.
    pub fn remove_replica(&self, rse: &str, did: &DidKey) -> Result<Replica> {
        let now = self.now();
        let rep = self
            .replicas
            .remove(&(rse.to_string(), did.clone()), now)
            .ok_or_else(|| RucioError::ReplicaNotFound(format!("{did} @ {rse}")))?;
        self.refresh_availability(did);
        self.metrics.incr("replicas.removed", 1);
        Ok(rep)
    }

    /// Derive and store the availability attribute (§2.2: available /
    /// lost / deleted is "a derived attribute from the contents of the
    /// Rucio replica catalog").
    pub(crate) fn refresh_availability(&self, did: &DidKey) {
        let has_available = self
            .list_replicas(did)
            .iter()
            .any(|r| r.state == ReplicaState::Available);
        let has_rules = !self.rules_by_did.get(did).is_empty()
            || self
                .ancestors(did)
                .iter()
                .any(|a| !self.rules_by_did.get(a).is_empty());
        let availability = if has_available {
            Availability::Available
        } else if has_rules {
            Availability::Lost
        } else {
            Availability::Deleted
        };
        self.dids.update(did, self.now(), |d| d.availability = availability);
    }

    /// Replicas eligible for deletion on an RSE: tombstone ≤ now
    /// (the reaper work queue; uses the partial tombstone index).
    pub fn deletable_replicas(&self, rse: &str, now: EpochMs, limit: usize) -> Vec<Replica> {
        self.replicas_by_tombstone
            .range_limit(&(rse.to_string(), i64::MIN), &(rse.to_string(), now + 1), limit)
            .into_iter()
            .filter_map(|k| self.replicas.get(&k))
            .filter(|r| r.lock_count == 0)
            .collect()
    }

    /// Manually (un)tombstone — used by the volatile-RSE cache API.
    pub fn set_tombstone(&self, rse: &str, did: &DidKey, tombstone: Option<EpochMs>) -> Result<()> {
        self.get_replica(rse, did)?;
        self.replicas.update(&(rse.to_string(), did.clone()), self.now(), |r| {
            r.tombstone = tombstone;
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::Catalog;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_scope("data18", "root").unwrap();
        for name in ["A-DISK", "B-DISK", "C-DISK"] {
            c.add_rse(Rse::new(name, now)).unwrap();
        }
        c.add_file("data18", "f1", "root", 1000, "aabbccdd", None).unwrap();
        c
    }

    fn f1() -> DidKey {
        DidKey::new("data18", "f1")
    }

    #[test]
    fn add_and_list_replicas() {
        let c = catalog();
        let rep = c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        assert!(rep.pfn.starts_with("/data18/"));
        assert_eq!(c.list_replicas(&f1()).len(), 1);
        assert_eq!(c.available_replicas(&f1()).len(), 1);
        // file availability becomes Available
        assert_eq!(c.get_did(&f1()).unwrap().availability, Availability::Available);
    }

    #[test]
    fn duplicate_replica_rejected() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        assert!(c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).is_err());
    }

    #[test]
    fn replica_for_collection_rejected() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        assert!(c
            .add_replica("A-DISK", &DidKey::new("data18", "ds"), ReplicaState::Available, None)
            .is_err());
    }

    #[test]
    fn nondeterministic_requires_pfn() {
        let c = catalog();
        let now = c.now();
        let mut rse = Rse::new("TAPE-ND", now);
        rse.path_algorithm = crate::core::rse::PathAlgorithm::NonDeterministic;
        c.add_rse(rse).unwrap();
        assert!(c.add_replica("TAPE-ND", &f1(), ReplicaState::Available, None).is_err());
        let rep = c
            .add_replica("TAPE-ND", &f1(), ReplicaState::Available, Some("/tape/group7/f1"))
            .unwrap();
        assert_eq!(rep.pfn, "/tape/group7/f1");
    }

    #[test]
    fn unprotected_available_replica_is_tombstoned_at_birth() {
        let c = catalog();
        let rep = c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        // tombstoned at birth, but with the cache grace period
        assert!(rep.tombstone.unwrap() > c.now());
        assert!(c.deletable_replicas("A-DISK", c.now(), 10).is_empty());
        let eligible = c.deletable_replicas("A-DISK", c.now() + 25 * 3_600_000, 10);
        assert_eq!(eligible.len(), 1);
    }

    #[test]
    fn ranked_sources_by_distance() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.add_replica("B-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.set_distance("A-DISK", "C-DISK", 3).unwrap();
        c.set_distance("B-DISK", "C-DISK", 1).unwrap();
        let sources = c.ranked_sources(&f1(), "C-DISK");
        assert_eq!(sources.len(), 2);
        assert_eq!(sources[0].0.rse, "B-DISK");
        // zero distance = unconnected → excluded
        c.set_distance("A-DISK", "C-DISK", 0).unwrap();
        let sources = c.ranked_sources(&f1(), "C-DISK");
        assert_eq!(sources.len(), 1);
    }

    #[test]
    fn suspicious_escalates_to_bad() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.declare_suspicious("A-DISK", &f1(), "checksum mismatch").unwrap();
        c.declare_suspicious("A-DISK", &f1(), "checksum mismatch").unwrap();
        assert_eq!(c.get_replica("A-DISK", &f1()).unwrap().state, ReplicaState::Suspicious);
        c.declare_suspicious("A-DISK", &f1(), "checksum mismatch").unwrap();
        assert_eq!(c.get_replica("A-DISK", &f1()).unwrap().state, ReplicaState::Bad);
        assert_eq!(c.bad_replicas.len(), 1);
        // last available copy went bad + no rules → DELETED availability
        assert_eq!(c.get_did(&f1()).unwrap().availability, Availability::Deleted);
    }

    #[test]
    fn touch_updates_popularity_and_parents() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        c.attach(&ds, &f1()).unwrap();
        c.touch_replica("A-DISK", &f1());
        c.touch_replica("A-DISK", &f1());
        assert_eq!(c.popularity.get(&f1()).unwrap().accesses, 2);
        assert_eq!(c.popularity.get(&ds).unwrap().accesses, 2);
    }

    #[test]
    fn archive_constituent_resolves_archive_replicas() {
        let c = catalog();
        c.add_file("data18", "arch.zip", "root", 5000, "zz", None).unwrap();
        c.add_file("data18", "inner.root", "root", 100, "yy", None).unwrap();
        let arch = DidKey::new("data18", "arch.zip");
        let inner = DidKey::new("data18", "inner.root");
        c.register_constituent(&arch, &inner).unwrap();
        c.add_replica("A-DISK", &arch, ReplicaState::Available, None).unwrap();
        let reps = c.list_replicas(&inner);
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].did, arch);
    }

    #[test]
    fn remove_replica_refreshes_availability() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.add_replica("B-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.remove_replica("A-DISK", &f1()).unwrap();
        assert_eq!(c.get_did(&f1()).unwrap().availability, Availability::Available);
        c.remove_replica("B-DISK", &f1()).unwrap();
        assert_eq!(c.get_did(&f1()).unwrap().availability, Availability::Deleted);
        assert!(c.remove_replica("B-DISK", &f1()).is_err());
    }

    #[test]
    fn add_replicas_bulk_registers_batch() {
        let c = catalog();
        let mut specs = Vec::new();
        for i in 0..20 {
            c.add_file("data18", &format!("bulk{i}"), "root", 100, "aabbccdd", None).unwrap();
            specs.push(ReplicaSpec::new(
                DidKey::new("data18", &format!("bulk{i}")),
                ReplicaState::Available,
            ));
        }
        let added = c.add_replicas_bulk("A-DISK", &specs).unwrap();
        assert_eq!(added, 20);
        assert_eq!(c.replicas.len(), 20);
        for i in 0..20 {
            let key = DidKey::new("data18", &format!("bulk{i}"));
            assert_eq!(
                c.get_did(&key).unwrap().availability,
                Availability::Available,
                "availability derived per DID"
            );
            assert!(c.get_replica("A-DISK", &key).unwrap().tombstone.is_some());
        }
        assert_eq!(c.metrics.counter("replicas.added"), 20);
    }

    #[test]
    fn add_replicas_bulk_is_atomic_on_bad_spec() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let specs = vec![
            ReplicaSpec::new(f1(), ReplicaState::Available),
            // dataset DID: invalid for replicas → whole batch must fail
            ReplicaSpec::new(DidKey::new("data18", "ds"), ReplicaState::Available),
        ];
        assert!(c.add_replicas_bulk("A-DISK", &specs).is_err());
        assert_eq!(c.replicas.len(), 0, "no partial registration");
        // duplicate against an existing row also fails atomically
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.add_file("data18", "f2", "root", 10, "x", None).unwrap();
        let specs = vec![
            ReplicaSpec::new(DidKey::new("data18", "f2"), ReplicaState::Available),
            ReplicaSpec::new(f1(), ReplicaState::Available),
        ];
        assert!(c.add_replicas_bulk("A-DISK", &specs).is_err());
        assert_eq!(c.replicas.len(), 1);
    }

    #[test]
    fn remove_replicas_bulk_refreshes_availability_once_per_did() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        c.add_replica("B-DISK", &f1(), ReplicaState::Available, None).unwrap();
        let removed = c.remove_replicas_bulk(&[
            ("A-DISK".to_string(), f1()),
            ("B-DISK".to_string(), f1()),
            ("C-DISK".to_string(), f1()), // missing: skipped
        ]);
        assert_eq!(removed.len(), 2);
        assert_eq!(c.replicas.len(), 0);
        assert_eq!(c.get_did(&f1()).unwrap().availability, Availability::Deleted);
    }

    #[test]
    fn deletable_respects_future_tombstones() {
        let c = catalog();
        c.add_replica("A-DISK", &f1(), ReplicaState::Available, None).unwrap();
        let future = c.now() + 1_000_000;
        c.set_tombstone("A-DISK", &f1(), Some(future)).unwrap();
        assert!(c.deletable_replicas("A-DISK", c.now(), 10).is_empty());
        assert_eq!(c.deletable_replicas("A-DISK", future, 10).len(), 1);
    }
}
