//! Rucio Storage Elements (paper §2.4): the minimal unit of globally
//! addressable storage — a *description* of a storage endpoint, not
//! software at the site.
//!
//! Includes: attributes/kv-pairs, protocol sets with per-operation
//! priorities and fallbacks, deterministic + non-deterministic lfn2pfn
//! path algorithms (§4.2), distance ranking (§2.4), and volatile flags.

use std::collections::BTreeMap;

use crate::common::checksum;
use crate::common::clock::EpochMs;
use crate::db::Row;

/// Storage operation kinds with independent protocol priorities (§2.4:
/// "protocol priority for read, write, deletion, and third party copy").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operation {
    Read,
    Write,
    Delete,
    ThirdPartyCopy,
}

/// A protocol an RSE speaks (paper §1.3: gsiftp, SRM, ROOT, WebDAV, S3).
#[derive(Debug, Clone)]
pub struct Protocol {
    /// Scheme, e.g. `root`, `davs`, `gsiftp`, `srm`, `s3`.
    pub scheme: String,
    pub hostname: String,
    pub port: u16,
    /// Path prefix on the endpoint.
    pub prefix: String,
    /// Priority per operation; 0 = unsupported, 1 = first choice.
    pub read_priority: u8,
    pub write_priority: u8,
    pub delete_priority: u8,
    pub tpc_priority: u8,
}

impl Protocol {
    pub fn priority_for(&self, op: Operation) -> u8 {
        match op {
            Operation::Read => self.read_priority,
            Operation::Write => self.write_priority,
            Operation::Delete => self.delete_priority,
            Operation::ThirdPartyCopy => self.tpc_priority,
        }
    }

    /// Render a full URL for a pfn.
    pub fn url(&self, pfn: &str) -> String {
        format!(
            "{}://{}:{}{}{}",
            self.scheme, self.hostname, self.port, self.prefix, pfn
        )
    }
}

/// lfn→pfn path algorithm choice (paper §4.2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PathAlgorithm {
    /// The "hash" deterministic algorithm: md5-prefix directory fan-out.
    HashDeterministic,
    /// Flat deterministic layout: `/scope/name` (small instances).
    FlatDeterministic,
    /// Non-deterministic: the client/workflow supplies full paths; the
    /// catalog is authoritative (tape co-location etc.).
    NonDeterministic,
}

/// An RSE row.
#[derive(Debug, Clone)]
pub struct Rse {
    pub name: String,
    /// Disk or tape semantic (mirrors the attached simulator backend).
    pub is_tape: bool,
    /// Volatile RSEs may lose data outside Rucio's control (§2.4).
    pub volatile: bool,
    /// Deterministic RSEs compute paths from the DID alone (§2.4).
    pub path_algorithm: PathAlgorithm,
    /// Availability toggles (an RSE can be read-only, e.g. decommissioning).
    pub availability_read: bool,
    pub availability_write: bool,
    pub availability_delete: bool,
    /// Arbitrary key-value attributes ("all tape storage in Asia", §2.4).
    pub attributes: BTreeMap<String, String>,
    pub protocols: Vec<Protocol>,
    pub created_at: EpochMs,
    /// Soft deletion marker (decommissioned RSEs stay for history).
    pub deleted: bool,
}

impl Row for Rse {
    type Key = String;
    fn key(&self) -> String {
        self.name.clone()
    }
}

impl Rse {
    pub fn new(name: &str, now: EpochMs) -> Self {
        let mut attributes = BTreeMap::new();
        // Upstream convention: an RSE's own name is a true attribute.
        attributes.insert(name.to_string(), "true".to_string());
        Rse {
            name: name.to_string(),
            is_tape: false,
            volatile: false,
            path_algorithm: PathAlgorithm::HashDeterministic,
            availability_read: true,
            availability_write: true,
            availability_delete: true,
            attributes,
            protocols: vec![Protocol {
                scheme: "root".into(),
                hostname: format!("{}.example.org", name.to_lowercase()),
                port: 1094,
                prefix: "/rucio".into(),
                read_priority: 1,
                write_priority: 1,
                delete_priority: 1,
                tpc_priority: 1,
            }],
            created_at: now,
            deleted: false,
        }
    }

    pub fn with_attr(mut self, key: &str, value: &str) -> Self {
        self.attributes.insert(key.to_string(), value.to_string());
        self
    }

    pub fn with_tape(mut self) -> Self {
        self.is_tape = true;
        self.attributes.insert("tape".into(), "true".into());
        self.attributes.insert("type".into(), "tape".into());
        self
    }

    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attributes.get(key).map(|s| s.as_str())
    }

    /// Site attribute (network endpoint identity); defaults to own name.
    pub fn site(&self) -> &str {
        self.attr("site").unwrap_or(&self.name)
    }

    /// Best protocol for an operation (lowest non-zero priority), with
    /// fallbacks in priority order.
    pub fn protocols_for(&self, op: Operation) -> Vec<&Protocol> {
        let mut ps: Vec<&Protocol> =
            self.protocols.iter().filter(|p| p.priority_for(op) > 0).collect();
        ps.sort_by_key(|p| p.priority_for(op));
        ps
    }

    pub fn best_protocol(&self, op: Operation) -> Option<&Protocol> {
        self.protocols_for(op).into_iter().next()
    }

    /// lfn→pfn (paper §4.2). For non-deterministic RSEs the caller must
    /// supply the path via the replica record; this returns `None` then.
    pub fn lfn2pfn(&self, scope: &str, name: &str) -> Option<String> {
        match self.path_algorithm {
            PathAlgorithm::HashDeterministic => Some(hash_pfn(scope, name)),
            PathAlgorithm::FlatDeterministic => Some(format!("/{scope}/{name}")),
            PathAlgorithm::NonDeterministic => None,
        }
    }
}

/// The upstream "hash" algorithm: `/scope/XX/YY/name` where XX/YY are the
/// first two md5 bytes of `scope:name` — even directory fan-out (§4.2:
/// "the files are distributed evenly over the directories").
pub fn hash_pfn(scope: &str, name: &str) -> String {
    let digest = checksum::md5_hex(format!("{scope}:{name}").as_bytes());
    format!("/{}/{}/{}/{}", scope, &digest[0..2], &digest[2..4], name)
}

/// Distance entry between two RSEs (paper §2.4): "functional distance is
/// always a non zero value with increasing integer steps, and zero
/// distance indicates no connection".
#[derive(Debug, Clone)]
pub struct Distance {
    pub src: String,
    pub dst: String,
    /// 0 = no connection; 1 = closest.
    pub ranking: u32,
}

impl Row for Distance {
    type Key = (String, String);
    fn key(&self) -> (String, String) {
        (self.src.clone(), self.dst.clone())
    }
}

/// Convert an observed throughput (bytes/s) into a distance ranking:
/// higher throughput → closer (§2.4: "higher network throughput represents
/// closer distance ... updated periodically and automatically").
pub fn ranking_from_throughput(bps: f64) -> u32 {
    // log-decade binning: >=1 GB/s → 1, >=100 MB/s → 2, ... <100 KB/s → 6
    let mut rank = 1u32;
    let mut threshold = 1e9;
    while bps < threshold && rank < 6 {
        rank += 1;
        threshold /= 10.0;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::proptest::forall;

    #[test]
    fn hash_pfn_shape_and_determinism() {
        let p1 = hash_pfn("data18", "raw.0001");
        let p2 = hash_pfn("data18", "raw.0001");
        assert_eq!(p1, p2);
        assert!(p1.starts_with("/data18/"));
        assert!(p1.ends_with("/raw.0001"));
        let parts: Vec<&str> = p1.split('/').collect();
        assert_eq!(parts.len(), 5);
        assert_eq!(parts[2].len(), 2);
        assert_eq!(parts[3].len(), 2);
    }

    #[test]
    fn hash_pfn_fans_out_evenly() {
        use std::collections::BTreeMap;
        let mut dirs: BTreeMap<String, usize> = BTreeMap::new();
        for i in 0..4096 {
            let p = hash_pfn("mc20", &format!("evnt.{i:06}.root"));
            let dir = p.split('/').nth(2).unwrap().to_string();
            *dirs.entry(dir).or_insert(0) += 1;
        }
        // 256 possible first-level dirs; expect near-uniform 16 ± slack
        assert!(dirs.len() > 200, "only {} dirs used", dirs.len());
        let max = dirs.values().max().unwrap();
        assert!(*max < 40, "hot dir with {max} files");
    }

    #[test]
    fn path_algorithms() {
        let now = 0;
        let det = Rse::new("A", now);
        assert!(det.lfn2pfn("s", "n").unwrap().starts_with("/s/"));
        let mut flat = Rse::new("B", now);
        flat.path_algorithm = PathAlgorithm::FlatDeterministic;
        assert_eq!(flat.lfn2pfn("s", "n").unwrap(), "/s/n");
        let mut nondet = Rse::new("C", now);
        nondet.path_algorithm = PathAlgorithm::NonDeterministic;
        assert_eq!(nondet.lfn2pfn("s", "n"), None);
    }

    #[test]
    fn protocol_priorities_and_fallbacks() {
        let mut rse = Rse::new("X", 0);
        rse.protocols = vec![
            Protocol {
                scheme: "davs".into(),
                hostname: "h".into(),
                port: 443,
                prefix: "/p".into(),
                read_priority: 2,
                write_priority: 1,
                delete_priority: 1,
                tpc_priority: 2,
            },
            Protocol {
                scheme: "root".into(),
                hostname: "h".into(),
                port: 1094,
                prefix: "/p".into(),
                read_priority: 1,
                write_priority: 0, // unsupported for write
                delete_priority: 2,
                tpc_priority: 1,
            },
        ];
        assert_eq!(rse.best_protocol(Operation::Read).unwrap().scheme, "root");
        assert_eq!(rse.best_protocol(Operation::Write).unwrap().scheme, "davs");
        let reads = rse.protocols_for(Operation::Read);
        assert_eq!(reads.len(), 2);
        assert_eq!(reads[1].scheme, "davs"); // fallback order
        let writes = rse.protocols_for(Operation::Write);
        assert_eq!(writes.len(), 1);
    }

    #[test]
    fn protocol_url_render() {
        let rse = Rse::new("SITE-DISK", 0);
        let p = rse.best_protocol(Operation::Read).unwrap();
        let url = p.url("/scope/aa/bb/file");
        assert_eq!(url, "root://site-disk.example.org:1094/rucio/scope/aa/bb/file");
    }

    #[test]
    fn own_name_is_true_attribute() {
        let rse = Rse::new("CERN-PROD", 0).with_attr("tier", "0");
        assert_eq!(rse.attr("CERN-PROD"), Some("true"));
        assert_eq!(rse.attr("tier"), Some("0"));
        assert_eq!(rse.site(), "CERN-PROD");
        let sited = Rse::new("CERN-PROD", 0).with_attr("site", "CERN");
        assert_eq!(sited.site(), "CERN");
    }

    #[test]
    fn tape_builder_sets_attributes() {
        let rse = Rse::new("FZK-TAPE", 0).with_tape();
        assert!(rse.is_tape);
        assert_eq!(rse.attr("tape"), Some("true"));
    }

    #[test]
    fn throughput_ranking_bins() {
        assert_eq!(ranking_from_throughput(2e9), 1);
        assert_eq!(ranking_from_throughput(5e8), 2);
        assert_eq!(ranking_from_throughput(5e7), 3);
        assert_eq!(ranking_from_throughput(5e6), 4);
        assert_eq!(ranking_from_throughput(5e5), 5);
        assert_eq!(ranking_from_throughput(5e4), 6);
        assert_eq!(ranking_from_throughput(0.0), 6);
    }

    #[test]
    fn prop_ranking_monotonic_in_throughput() {
        forall(200, |g| {
            let a = g.f64() * 2e9;
            let b = g.f64() * 2e9;
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            assert!(
                ranking_from_throughput(hi) <= ranking_from_throughput(lo),
                "faster must not be farther"
            );
        });
    }
}
