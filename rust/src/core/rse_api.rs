//! RSE registry operations: registration, attributes, protocols,
//! distances, RSE-expression resolution (paper §2.4), and the per-VO
//! usage rollups multi-tenant accounting is built on.

use std::collections::{BTreeMap, BTreeSet};

use crate::common::error::{Result, RucioError};

use super::accounts_api::validate_name;
use super::rse::{ranking_from_throughput, Distance, Rse};
use super::rseexpr::{self, RseUniverse};
use super::types::DEFAULT_VO;
use super::Catalog;

impl Catalog {
    pub fn add_rse(&self, rse: Rse) -> Result<()> {
        validate_name(&rse.name, 60)?;
        self.rses.insert(rse, self.now())?;
        self.metrics.incr("rses.added", 1);
        Ok(())
    }

    pub fn get_rse(&self, name: &str) -> Result<Rse> {
        self.rses
            .get(&name.to_string())
            .filter(|r| !r.deleted)
            .ok_or_else(|| RucioError::RseNotFound(name.to_string()))
    }

    pub fn list_rses(&self) -> Vec<Rse> {
        self.rses.scan(|r| !r.deleted)
    }

    pub fn set_rse_attribute(&self, name: &str, key: &str, value: &str) -> Result<()> {
        self.get_rse(name)?;
        self.rses.update(&name.to_string(), self.now(), |r| {
            r.attributes.insert(key.to_string(), value.to_string());
        });
        Ok(())
    }

    /// Toggle availability (read/write/delete) — decommissioning leans on
    /// write=false, delete-disabled protects archival data (§4.3).
    pub fn set_rse_availability(
        &self,
        name: &str,
        read: bool,
        write: bool,
        delete: bool,
    ) -> Result<()> {
        self.get_rse(name)?;
        self.rses.update(&name.to_string(), self.now(), |r| {
            r.availability_read = read;
            r.availability_write = write;
            r.availability_delete = delete;
        });
        Ok(())
    }

    /// Drain an RSE: stop accepting new data while reads (and deletes)
    /// continue — the first step of decommissioning and the operator
    /// response to a degraded endpoint. Undraining restores writes. The
    /// `drained` attribute records the intent so that outage recovery
    /// (which restores availability wholesale) can leave a drain in place.
    pub fn set_rse_drain(&self, name: &str, drained: bool) -> Result<()> {
        self.get_rse(name)?;
        self.rses.update(&name.to_string(), self.now(), |r| {
            // Undraining never re-enables writes on an RSE that is in a
            // full outage (read off): outage recovery restores them.
            r.availability_write = !drained && r.availability_read;
            r.attributes
                .insert("drained".into(), if drained { "true" } else { "false" }.into());
        });
        Ok(())
    }

    /// Is the RSE administratively drained (independent of outages)?
    pub fn rse_is_drained(&self, name: &str) -> bool {
        self.get_rse(name)
            .map(|r| r.attr("drained") == Some("true"))
            .unwrap_or(false)
    }

    /// Soft-delete an RSE (after decommissioning).
    pub fn delete_rse(&self, name: &str) -> Result<()> {
        self.get_rse(name)?;
        self.rses.update(&name.to_string(), self.now(), |r| r.deleted = true);
        Ok(())
    }

    // ------------------------------------------------------------------
    // RSE expressions (§2.5)
    // ------------------------------------------------------------------

    /// Resolve an RSE expression to a set of live RSE names. Empty results
    /// are an error here ("RSE expression resolved to empty set") because
    /// every caller in the rule path requires candidates.
    pub fn resolve_rse_expression(&self, expression: &str) -> Result<Vec<String>> {
        let set = self.resolve_rse_expression_allow_empty(expression)?;
        if set.is_empty() {
            return Err(RucioError::RseExpressionEmpty(expression.to_string()));
        }
        Ok(set.into_iter().collect())
    }

    pub fn resolve_rse_expression_allow_empty(
        &self,
        expression: &str,
    ) -> Result<BTreeSet<String>> {
        let universe = CatalogUniverse { catalog: self };
        rseexpr::resolve(expression, &universe)
    }

    // ------------------------------------------------------------------
    // distances (§2.4)
    // ------------------------------------------------------------------

    /// Set the functional distance between two RSEs (0 = no connection).
    pub fn set_distance(&self, src: &str, dst: &str, ranking: u32) -> Result<()> {
        self.get_rse(src)?;
        self.get_rse(dst)?;
        self.distances.upsert(
            Distance { src: src.to_string(), dst: dst.to_string(), ranking },
            self.now(),
        );
        Ok(())
    }

    /// Distance ranking; `None` when unconnected (ranking 0 or unset pairs
    /// fall back to a configurable default so new links still work).
    pub fn distance(&self, src: &str, dst: &str) -> Option<u32> {
        match self.distances.get(&(src.to_string(), dst.to_string())) {
            Some(d) if d.ranking == 0 => None,
            Some(d) => Some(d.ranking),
            None => {
                let default = self.cfg.get_i64("rse", "default_distance", 4) as u32;
                Some(default)
            }
        }
    }

    /// Periodic distance re-evaluation from observed throughput (§2.4:
    /// "periodic re-evaluation of the collected average throughput ...
    /// helps to dynamically adjust and update the distances"). Takes
    /// (src_site, dst_site, bytes/s) samples; updates every RSE pair on
    /// those sites. Returns the number of updated pairs.
    pub fn update_distances_from_throughput(&self, samples: &[(String, String, f64)]) -> usize {
        let rses = self.list_rses();
        let mut updated = 0;
        for (src_site, dst_site, bps) in samples {
            let ranking = ranking_from_throughput(*bps);
            for src in rses.iter().filter(|r| r.site() == src_site) {
                for dst in rses.iter().filter(|r| r.site() == dst_site) {
                    if src.name == dst.name {
                        continue;
                    }
                    self.distances.upsert(
                        Distance { src: src.name.clone(), dst: dst.name.clone(), ranking },
                        self.now(),
                    );
                    updated += 1;
                }
            }
        }
        updated
    }

    // ------------------------------------------------------------------
    // per-VO rollups (multi-tenant accounting)
    // ------------------------------------------------------------------

    /// Per-VO usage rollup: account usage rows summed by the owning
    /// account's VO. Usage rows whose account vanished are attributed to
    /// the default VO so nothing silently drops out of the totals.
    pub fn vo_usage(&self) -> BTreeMap<String, (u64, u64)> {
        let mut out: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for ((vo, _), (bytes, files)) in self.vo_usage_by_rse() {
            let e = out.entry(vo).or_insert((0, 0));
            e.0 += bytes;
            e.1 += files;
        }
        out
    }

    /// Per-(VO, RSE) usage rollup — the tenant-level view that quota
    /// reports and the multi-VO invariants are built on.
    pub fn vo_usage_by_rse(&self) -> BTreeMap<(String, String), (u64, u64)> {
        let mut account_vo: BTreeMap<String, String> = BTreeMap::new();
        let mut out: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
        self.usages.for_each(|u| {
            let vo = account_vo
                .entry(u.account.clone())
                .or_insert_with(|| {
                    self.accounts
                        .get(&u.account)
                        .map(|a| a.vo)
                        .unwrap_or_else(|| DEFAULT_VO.to_string())
                })
                .clone();
            let e = out.entry((vo, u.rse.clone())).or_insert((0, 0));
            e.0 += u.bytes;
            e.1 += u.files;
        });
        out
    }
}

struct CatalogUniverse<'a> {
    catalog: &'a Catalog,
}

impl RseUniverse for CatalogUniverse<'_> {
    fn all_rses(&self) -> Vec<String> {
        self.catalog
            .rses
            .filter_map(|r| (!r.deleted).then(|| r.name.clone()))
    }

    fn attribute(&self, rse: &str, key: &str) -> Option<String> {
        self.catalog
            .rses
            .get(&rse.to_string())
            .filter(|r| !r.deleted)
            .and_then(|r| r.attributes.get(key).cloned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Catalog;

    fn catalog_with_grid() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        for (name, tier, country) in [
            ("CERN-PROD", "0", "CH"),
            ("IN2P3-DISK", "1", "FR"),
            ("GRIF", "2", "FR"),
            ("DESY", "2", "DE"),
        ] {
            c.add_rse(
                Rse::new(name, now)
                    .with_attr("tier", tier)
                    .with_attr("country", country)
                    .with_attr("site", name),
            )
            .unwrap();
        }
        c
    }

    #[test]
    fn expression_resolution_against_catalog() {
        let c = catalog_with_grid();
        let got = c.resolve_rse_expression("tier=2&(country=FR|country=DE)").unwrap();
        assert_eq!(got, vec!["DESY", "GRIF"]);
        assert!(matches!(
            c.resolve_rse_expression("country=JP"),
            Err(RucioError::RseExpressionEmpty(_))
        ));
    }

    #[test]
    fn deleted_rses_leave_the_universe() {
        let c = catalog_with_grid();
        c.delete_rse("GRIF").unwrap();
        let got = c.resolve_rse_expression("country=FR").unwrap();
        assert_eq!(got, vec!["IN2P3-DISK"]);
        assert!(c.get_rse("GRIF").is_err());
    }

    #[test]
    fn attributes_updateable() {
        let c = catalog_with_grid();
        c.set_rse_attribute("DESY", "freespace", "120").unwrap();
        assert_eq!(c.resolve_rse_expression("freespace>100").unwrap(), vec!["DESY"]);
    }

    #[test]
    fn distances_with_default() {
        let c = catalog_with_grid();
        c.set_distance("CERN-PROD", "IN2P3-DISK", 1).unwrap();
        c.set_distance("CERN-PROD", "DESY", 0).unwrap(); // no connection
        assert_eq!(c.distance("CERN-PROD", "IN2P3-DISK"), Some(1));
        assert_eq!(c.distance("CERN-PROD", "DESY"), None);
        // unset pair → default
        assert_eq!(c.distance("GRIF", "DESY"), Some(4));
    }

    #[test]
    fn throughput_updates_distances() {
        let c = catalog_with_grid();
        let n = c.update_distances_from_throughput(&[(
            "CERN-PROD".into(),
            "GRIF".into(),
            2e9, // 2 GB/s → ranking 1
        )]);
        assert_eq!(n, 1);
        assert_eq!(c.distance("CERN-PROD", "GRIF"), Some(1));
        c.update_distances_from_throughput(&[("CERN-PROD".into(), "GRIF".into(), 5e5)]);
        assert_eq!(c.distance("CERN-PROD", "GRIF"), Some(5));
    }

    #[test]
    fn availability_toggles() {
        let c = catalog_with_grid();
        c.set_rse_availability("DESY", true, false, false).unwrap();
        let r = c.get_rse("DESY").unwrap();
        assert!(r.availability_read && !r.availability_write && !r.availability_delete);
    }

    #[test]
    fn vo_usage_rolls_up_by_tenant() {
        use crate::core::rules_api::RuleSpec;
        use crate::core::types::{AccountType, DidKey, ReplicaState};
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_rse(Rse::new("DISK-1", now)).unwrap();
        c.add_account_vo("at1", AccountType::User, "", "atlas").unwrap();
        c.add_account_vo("cm1", AccountType::User, "", "cms").unwrap();
        c.add_scope("s-atlas", "at1").unwrap();
        c.add_scope("s-cms", "cm1").unwrap();
        for (scope, owner, n) in [("s-atlas", "at1", 2), ("s-cms", "cm1", 1)] {
            for i in 0..n {
                let key = DidKey::new(scope, &format!("f{i}"));
                c.add_file(scope, &format!("f{i}"), owner, 100, "aabbccdd", None).unwrap();
                c.add_replica("DISK-1", &key, ReplicaState::Available, None).unwrap();
                c.add_rule(RuleSpec::new(owner, key, "DISK-1", 1)).unwrap();
            }
        }
        let roll = c.vo_usage();
        assert_eq!(roll.get("atlas"), Some(&(200, 2)));
        assert_eq!(roll.get("cms"), Some(&(100, 1)));
        let by_rse = c.vo_usage_by_rse();
        assert_eq!(by_rse.get(&("atlas".into(), "DISK-1".into())), Some(&(200, 2)));
        // Σ per-VO == global
        let total: u64 = roll.values().map(|(b, _)| *b).sum();
        let mut global = 0;
        c.usages.for_each(|u| global += u.bytes);
        assert_eq!(total, global);
    }

    #[test]
    fn drain_round_trip_and_outage_interaction() {
        let c = catalog_with_grid();
        c.set_rse_drain("DESY", true).unwrap();
        let r = c.get_rse("DESY").unwrap();
        assert!(r.availability_read && !r.availability_write);
        assert!(c.rse_is_drained("DESY"));
        c.set_rse_drain("DESY", false).unwrap();
        assert!(c.get_rse("DESY").unwrap().availability_write);
        assert!(!c.rse_is_drained("DESY"));
        // undraining during a full outage must not re-enable writes
        c.set_rse_drain("GRIF", true).unwrap();
        c.set_rse_availability("GRIF", false, false, false).unwrap();
        c.set_rse_drain("GRIF", false).unwrap();
        let r = c.get_rse("GRIF").unwrap();
        assert!(!r.availability_write, "no writes while the RSE is down");
        assert!(!c.rse_is_drained("GRIF"));
    }
}
