//! The RSE expression language (paper §2.5, ref [19]): a set-complete
//! language over RSE attributes, defined by a formal grammar.
//!
//! Grammar (recursive descent):
//! ```text
//! expr    := term (('|' term) | ('\' term))*      union / difference
//! term    := factor ('&' factor)*                 intersection
//! factor  := '(' expr ')' | '*' | primitive
//! primitive := IDENT '=' VALUE                    attribute equality
//!            | IDENT '<' NUM | IDENT '>' NUM      numeric comparison
//!            | IDENT                              RSE name, or boolean attr
//! ```
//!
//! "An attribute match of the grammar always results in a set of RSEs,
//! which could also be empty" — evaluation returns an ordered set; the
//! *caller* (rule engine) decides whether empty is an error.

use std::collections::BTreeSet;

use crate::common::error::{Result, RucioError};

/// Attribute lookup the evaluator runs against. Implemented by the RSE
/// registry; a simple map-backed impl exists for tests.
pub trait RseUniverse {
    /// All RSE names.
    fn all_rses(&self) -> Vec<String>;
    /// Attribute value for an RSE (`None` when unset). Every RSE
    /// implicitly has its own name as a true attribute (upstream
    /// convention), which the evaluator handles itself.
    fn attribute(&self, rse: &str, key: &str) -> Option<String>;
}

/// Map-backed universe for tests and standalone evaluation.
pub struct MapUniverse {
    pub rses: Vec<(String, std::collections::BTreeMap<String, String>)>,
}

impl RseUniverse for MapUniverse {
    fn all_rses(&self) -> Vec<String> {
        self.rses.iter().map(|(n, _)| n.clone()).collect()
    }

    fn attribute(&self, rse: &str, key: &str) -> Option<String> {
        self.rses
            .iter()
            .find(|(n, _)| n == rse)
            .and_then(|(_, attrs)| attrs.get(key).cloned())
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Value(String),
    And,
    Or,
    Minus,
    Eq,
    Lt,
    Gt,
    LParen,
    RParen,
    Star,
}

fn lex(input: &str) -> Result<Vec<Tok>> {
    let mut toks = Vec::new();
    let bytes = input.as_bytes();
    let mut i = 0;
    let is_word =
        |c: u8| c.is_ascii_alphanumeric() || matches!(c, b'_' | b'-' | b'.' | b':' | b'*');
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b' ' | b'\t' => i += 1,
            b'&' => {
                toks.push(Tok::And);
                i += 1;
            }
            b'|' => {
                toks.push(Tok::Or);
                i += 1;
            }
            b'\\' => {
                toks.push(Tok::Minus);
                i += 1;
            }
            b'=' => {
                toks.push(Tok::Eq);
                i += 1;
            }
            b'<' => {
                toks.push(Tok::Lt);
                i += 1;
            }
            b'>' => {
                toks.push(Tok::Gt);
                i += 1;
            }
            b'(' => {
                toks.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                toks.push(Tok::RParen);
                i += 1;
            }
            c if is_word(c) => {
                let start = i;
                while i < bytes.len() && is_word(bytes[i]) {
                    i += 1;
                }
                let word = std::str::from_utf8(&bytes[start..i]).unwrap().to_string();
                if word == "*" {
                    toks.push(Tok::Star);
                } else if matches!(toks.last(), Some(Tok::Eq) | Some(Tok::Lt) | Some(Tok::Gt)) {
                    toks.push(Tok::Value(word));
                } else {
                    toks.push(Tok::Ident(word));
                }
            }
            other => {
                return Err(RucioError::InvalidRseExpression(format!(
                    "unexpected character '{}' at {i} in '{input}'",
                    other as char
                )))
            }
        }
    }
    Ok(toks)
}

/// Parsed expression AST.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    All,
    /// Bare identifier: RSE name if one matches, else boolean attribute.
    Name(String),
    AttrEq(String, String),
    AttrLt(String, f64),
    AttrGt(String, f64),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
    Minus(Box<Expr>, Box<Expr>),
}

impl std::fmt::Display for Expr {
    /// Canonical printer: fully parenthesized compounds, so printing is
    /// unambiguous and `parse(print(e))` evaluates identically to `e`
    /// (and `print(parse(s))` is a fixpoint — property-tested below).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Expr::All => write!(f, "*"),
            Expr::Name(n) => write!(f, "{n}"),
            Expr::AttrEq(k, v) => write!(f, "{k}={v}"),
            Expr::AttrLt(k, n) => write!(f, "{k}<{n}"),
            Expr::AttrGt(k, n) => write!(f, "{k}>{n}"),
            Expr::And(a, b) => write!(f, "({a}&{b})"),
            Expr::Or(a, b) => write!(f, "({a}|{b})"),
            Expr::Minus(a, b) => write!(f, "({a}\\{b})"),
        }
    }
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expr(&mut self) -> Result<Expr> {
        let mut left = self.term()?;
        loop {
            match self.peek() {
                Some(Tok::Or) => {
                    self.next();
                    let right = self.term()?;
                    left = Expr::Or(Box::new(left), Box::new(right));
                }
                Some(Tok::Minus) => {
                    self.next();
                    let right = self.term()?;
                    left = Expr::Minus(Box::new(left), Box::new(right));
                }
                _ => return Ok(left),
            }
        }
    }

    fn term(&mut self) -> Result<Expr> {
        let mut left = self.factor()?;
        while self.peek() == Some(&Tok::And) {
            self.next();
            let right = self.factor()?;
            left = Expr::And(Box::new(left), Box::new(right));
        }
        Ok(left)
    }

    fn factor(&mut self) -> Result<Expr> {
        match self.next() {
            Some(Tok::LParen) => {
                let e = self.expr()?;
                if self.next() != Some(Tok::RParen) {
                    return Err(RucioError::InvalidRseExpression("missing ')'".into()));
                }
                Ok(e)
            }
            Some(Tok::Star) => Ok(Expr::All),
            Some(Tok::Ident(name)) => match self.peek() {
                Some(Tok::Eq) => {
                    self.next();
                    match self.next() {
                        Some(Tok::Value(v)) | Some(Tok::Ident(v)) => Ok(Expr::AttrEq(name, v)),
                        _ => Err(RucioError::InvalidRseExpression(format!(
                            "expected value after '{name}='"
                        ))),
                    }
                }
                Some(Tok::Lt) => {
                    self.next();
                    let v = self.numeric_value(&name)?;
                    Ok(Expr::AttrLt(name, v))
                }
                Some(Tok::Gt) => {
                    self.next();
                    let v = self.numeric_value(&name)?;
                    Ok(Expr::AttrGt(name, v))
                }
                _ => Ok(Expr::Name(name)),
            },
            other => Err(RucioError::InvalidRseExpression(format!(
                "unexpected token {other:?}"
            ))),
        }
    }

    fn numeric_value(&mut self, attr: &str) -> Result<f64> {
        match self.next() {
            Some(Tok::Value(v)) | Some(Tok::Ident(v)) => v.parse().map_err(|_| {
                RucioError::InvalidRseExpression(format!("non-numeric comparison for {attr}: {v}"))
            }),
            _ => Err(RucioError::InvalidRseExpression(format!(
                "expected number after comparison on {attr}"
            ))),
        }
    }
}

/// Parse an expression string to an AST.
pub fn parse(input: &str) -> Result<Expr> {
    let trimmed = input.trim();
    if trimmed.is_empty() {
        return Err(RucioError::InvalidRseExpression("empty expression".into()));
    }
    let toks = lex(trimmed)?;
    let mut p = Parser { toks, pos: 0 };
    let e = p.expr()?;
    if p.pos != p.toks.len() {
        return Err(RucioError::InvalidRseExpression(format!(
            "trailing tokens in '{input}'"
        )));
    }
    Ok(e)
}

/// Evaluate an AST against a universe → ordered RSE set.
pub fn eval(expr: &Expr, universe: &dyn RseUniverse) -> BTreeSet<String> {
    match expr {
        Expr::All => universe.all_rses().into_iter().collect(),
        Expr::Name(name) => {
            let all = universe.all_rses();
            // Exact RSE-name match wins (upstream convention)...
            if all.iter().any(|r| r == name) {
                return std::iter::once(name.clone()).collect();
            }
            // ...else boolean attribute (attr present and truthy).
            all.into_iter()
                .filter(|r| {
                    universe
                        .attribute(r, name)
                        .map(|v| v != "false" && v != "0" && !v.is_empty())
                        .unwrap_or(false)
                })
                .collect()
        }
        Expr::AttrEq(key, value) => universe
            .all_rses()
            .into_iter()
            .filter(|r| universe.attribute(r, key).as_deref() == Some(value.as_str()))
            .collect(),
        Expr::AttrLt(key, num) => numeric_filter(universe, key, |v| v < *num),
        Expr::AttrGt(key, num) => numeric_filter(universe, key, |v| v > *num),
        Expr::And(a, b) => {
            let sa = eval(a, universe);
            let sb = eval(b, universe);
            sa.intersection(&sb).cloned().collect()
        }
        Expr::Or(a, b) => {
            let mut sa = eval(a, universe);
            sa.extend(eval(b, universe));
            sa
        }
        Expr::Minus(a, b) => {
            let sa = eval(a, universe);
            let sb = eval(b, universe);
            sa.difference(&sb).cloned().collect()
        }
    }
}

fn numeric_filter(
    universe: &dyn RseUniverse,
    key: &str,
    pred: impl Fn(f64) -> bool,
) -> BTreeSet<String> {
    universe
        .all_rses()
        .into_iter()
        .filter(|r| {
            universe
                .attribute(r, key)
                .and_then(|v| v.parse::<f64>().ok())
                .map(&pred)
                .unwrap_or(false)
        })
        .collect()
}

/// Parse + evaluate in one call.
pub fn resolve(input: &str, universe: &dyn RseUniverse) -> Result<BTreeSet<String>> {
    Ok(eval(&parse(input)?, universe))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn universe() -> MapUniverse {
        let mk = |name: &str, pairs: &[(&str, &str)]| {
            let mut m = BTreeMap::new();
            for (k, v) in pairs {
                m.insert(k.to_string(), v.to_string());
            }
            (name.to_string(), m)
        };
        MapUniverse {
            rses: vec![
                mk("CERN-PROD", &[("tier", "0"), ("country", "CH"), ("type", "disk")]),
                mk("CERN-TAPE", &[("tier", "0"), ("country", "CH"), ("type", "tape"), ("tape", "true")]),
                mk("IN2P3-DISK", &[("tier", "1"), ("country", "FR"), ("type", "disk")]),
                mk("GRIF", &[("tier", "2"), ("country", "FR"), ("type", "disk")]),
                mk("DESY", &[("tier", "2"), ("country", "DE"), ("type", "disk"), ("freespace", "120")]),
                mk("FZK-TAPE", &[("tier", "1"), ("country", "DE"), ("type", "tape"), ("tape", "true"), ("freespace", "40")]),
            ],
        }
    }

    fn names(set: BTreeSet<String>) -> Vec<String> {
        set.into_iter().collect()
    }

    #[test]
    fn paper_example_expression() {
        // "tier=2&(country=FR|country=DE)" — the §2.5 example.
        let u = universe();
        let got = names(resolve("tier=2&(country=FR|country=DE)", &u).unwrap());
        assert_eq!(got, vec!["DESY", "GRIF"]);
    }

    #[test]
    fn star_matches_all() {
        let u = universe();
        assert_eq!(resolve("*", &u).unwrap().len(), 6);
    }

    #[test]
    fn bare_rse_name() {
        let u = universe();
        assert_eq!(names(resolve("CERN-PROD", &u).unwrap()), vec!["CERN-PROD"]);
    }

    #[test]
    fn bare_boolean_attribute() {
        let u = universe();
        assert_eq!(
            names(resolve("tape", &u).unwrap()),
            vec!["CERN-TAPE", "FZK-TAPE"]
        );
    }

    #[test]
    fn difference_operator() {
        let u = universe();
        let got = names(resolve("country=DE\\tape", &u).unwrap());
        assert_eq!(got, vec!["DESY"]);
    }

    #[test]
    fn union_and_precedence() {
        // & binds tighter than |
        let u = universe();
        let got = names(resolve("tier=1&country=FR|tier=0&type=disk", &u).unwrap());
        assert_eq!(got, vec!["CERN-PROD", "IN2P3-DISK"]);
    }

    #[test]
    fn numeric_comparisons() {
        let u = universe();
        assert_eq!(names(resolve("freespace>100", &u).unwrap()), vec!["DESY"]);
        assert_eq!(names(resolve("freespace<100", &u).unwrap()), vec!["FZK-TAPE"]);
    }

    #[test]
    fn empty_result_is_ok_not_error() {
        let u = universe();
        assert!(resolve("country=JP", &u).unwrap().is_empty());
    }

    #[test]
    fn malformed_expressions_error() {
        let u = universe();
        for bad in ["", "tier=", "(tier=1", "tier=1)", "&tier=1", "tier=1 country=FR", "a=b=c"] {
            assert!(resolve(bad, &u).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn nested_parentheses() {
        let u = universe();
        let got = names(
            resolve("((country=FR|country=DE)&type=disk)\\(tier=2&country=DE)", &u).unwrap(),
        );
        assert_eq!(got, vec!["GRIF", "IN2P3-DISK"]);
    }

    /// Random expression tree, depth-bounded. Leaves draw fresh
    /// identifiers (usually matching nothing) so evaluation exercises
    /// empty sets as much as populated ones.
    fn gen_expr(g: &mut crate::common::proptest::Gen, depth: usize) -> Expr {
        if depth == 0 || g.chance(0.4) {
            match g.usize(0, 5) {
                0 => Expr::All,
                1 => Expr::Name(g.ident(1..8)),
                2 => Expr::AttrEq(g.ident(1..6), g.ident(1..6)),
                3 => Expr::AttrLt(g.ident(1..6), g.u64(0, 1000) as f64),
                _ => Expr::AttrGt(g.ident(1..6), g.u64(0, 1000) as f64),
            }
        } else {
            let a = Box::new(gen_expr(g, depth - 1));
            let b = Box::new(gen_expr(g, depth - 1));
            match g.usize(0, 3) {
                0 => Expr::And(a, b),
                1 => Expr::Or(a, b),
                _ => Expr::Minus(a, b),
            }
        }
    }

    #[test]
    fn prop_ast_print_parse_round_trip() {
        use crate::common::proptest::forall;
        let u = universe();
        forall(300, |g| {
            let ast = gen_expr(g, 3);
            let printed = ast.to_string();
            let reparsed = parse(&printed)
                .unwrap_or_else(|e| panic!("printed '{printed}' must reparse: {e}"));
            assert_eq!(reparsed, ast, "parse∘print is identity for '{printed}'");
            assert_eq!(reparsed.to_string(), printed, "printer fixpoint");
            assert_eq!(eval(&ast, &u), eval(&reparsed, &u));
        });
    }

    #[test]
    fn prop_de_morgan_and_complement_laws() {
        use crate::common::proptest::forall;
        let u = universe();
        let atoms = [
            "tier=0", "tier=1", "tier=2", "country=FR", "country=DE", "type=disk", "tape",
            "freespace>100", "*", "nomatch",
        ];
        forall(150, |g| {
            let a = *g.pick(&atoms);
            let b = *g.pick(&atoms);
            // De Morgan with complement via '*\X'
            assert_eq!(
                resolve(&format!("*\\({a}|{b})"), &u).unwrap(),
                resolve(&format!("(*\\{a})&(*\\{b})"), &u).unwrap(),
                "¬(A∪B) = ¬A∩¬B for {a}, {b}"
            );
            assert_eq!(
                resolve(&format!("*\\({a}&{b})"), &u).unwrap(),
                resolve(&format!("(*\\{a})|(*\\{b})"), &u).unwrap(),
                "¬(A∩B) = ¬A∪¬B for {a}, {b}"
            );
            // double complement
            assert_eq!(
                resolve(&format!("*\\(*\\{a})"), &u).unwrap(),
                resolve(a, &u).unwrap()
            );
            // absorption: A | (A & B) == A
            assert_eq!(
                resolve(&format!("{a}|({a}&{b})"), &u).unwrap(),
                resolve(a, &u).unwrap()
            );
        });
    }

    #[test]
    fn prop_malformed_inputs_error_not_panic() {
        use crate::common::proptest::forall;
        let u = universe();
        forall(500, |g| {
            // arbitrary printable garbage: resolving must return a Result,
            // never panic (forall turns panics into failures)
            let s = g.string(0..16);
            let _ = resolve(&s, &u);
        });
    }

    #[test]
    fn prop_set_algebra_laws() {
        use crate::common::proptest::forall;
        let u = universe();
        let atoms = [
            "tier=0", "tier=1", "tier=2", "country=FR", "country=DE", "country=CH", "type=disk",
            "tape", "*",
        ];
        forall(100, |g| {
            let a = *g.pick(&atoms);
            let b = *g.pick(&atoms);
            // commutativity
            assert_eq!(
                resolve(&format!("{a}&{b}"), &u).unwrap(),
                resolve(&format!("{b}&{a}"), &u).unwrap()
            );
            assert_eq!(
                resolve(&format!("{a}|{b}"), &u).unwrap(),
                resolve(&format!("{b}|{a}"), &u).unwrap()
            );
            // idempotence
            assert_eq!(
                resolve(&format!("{a}&{a}"), &u).unwrap(),
                resolve(a, &u).unwrap()
            );
            // A \ B ⊆ A and disjoint from B
            let diff = resolve(&format!("{a}\\{b}"), &u).unwrap();
            let sa = resolve(a, &u).unwrap();
            let sb = resolve(b, &u).unwrap();
            assert!(diff.is_subset(&sa));
            assert!(diff.intersection(&sb).next().is_none());
            // (A|B) == (A\B) | B
            let lhs = resolve(&format!("{a}|{b}"), &u).unwrap();
            let mut rhs = diff.clone();
            rhs.extend(sb);
            assert_eq!(lhs, rhs);
        });
    }
}
