//! The replication-rule engine (paper §2.5 + §4.2): rule creation with
//! RSE selection and quota checks, replica locks, transfer-request
//! creation, completion/failure handling, repair, content-change
//! re-evaluation, and lifetime expiry.
//!
//! Invariants maintained everywhere:
//! * `locks_ok + locks_replicating + locks_stuck == Σ locks(rule)`;
//! * `replica.lock_count == #locks on that (rse, did)`;
//! * a replica with `lock_count > 0` never carries a tombstone;
//! * account usage equals the Σ bytes of the account's locks per RSE
//!   ("the accounts are only charged for the files they actively set
//!   replication rules on", §2.5);
//! * rule evaluation is idempotent/additive — re-evaluating never removes
//!   other rules' replicas ("there is no possibility of having
//!   conflicting rules", §2.5).

use std::collections::BTreeSet;

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};
use crate::jsonx::Json;

use super::types::*;
use super::Catalog;

/// Parameters for rule creation (paper §2.5: "a replication rule requires
/// a minimum of four parameters": DID, RSE expression, copies, lifetime).
#[derive(Debug, Clone)]
pub struct RuleSpec {
    pub account: String,
    pub did: DidKey,
    pub rse_expression: String,
    pub copies: u32,
    /// Relative lifetime; `None` = forever.
    pub lifetime_ms: Option<i64>,
    /// Weight attribute name for placement skew (§2.5).
    pub weight: Option<String>,
    pub activity: String,
    pub purge_replicas: bool,
    pub subscription_id: Option<u64>,
}

impl RuleSpec {
    pub fn new(account: &str, did: DidKey, rse_expression: &str, copies: u32) -> Self {
        RuleSpec {
            account: account.to_string(),
            did,
            rse_expression: rse_expression.to_string(),
            copies,
            lifetime_ms: None,
            weight: None,
            activity: "User Subscriptions".to_string(),
            purge_replicas: false,
            subscription_id: None,
        }
    }

    pub fn with_lifetime(mut self, ms: i64) -> Self {
        self.lifetime_ms = Some(ms);
        self
    }

    pub fn with_activity(mut self, activity: &str) -> Self {
        self.activity = activity.to_string();
        self
    }

    pub fn with_weight(mut self, attr: &str) -> Self {
        self.weight = Some(attr.to_string());
        self
    }
}

/// One planned lock before application.
struct PlannedLock {
    did: DidKey,
    bytes: u64,
    adler32: String,
    rse: String,
    /// Replica already available there (lock will be Ok, no transfer).
    have_available: bool,
    /// Replica exists in Copying (another rule's transfer is inbound).
    have_copying: bool,
}

impl Catalog {
    // ------------------------------------------------------------------
    // rule creation (§2.5 / §4.2 step 1)
    // ------------------------------------------------------------------

    pub fn add_rule(&self, spec: RuleSpec) -> Result<u64> {
        let now = self.now();
        self.get_account(&spec.account)?;
        self.get_did(&spec.did)?;
        if spec.copies == 0 {
            return Err(RucioError::InvalidValue("copies must be >= 1".into()));
        }
        let candidates = self.resolve_rse_expression(&spec.rse_expression)?;
        let writable: Vec<String> = candidates
            .iter()
            .filter(|r| self.get_rse(r).map(|x| x.availability_write).unwrap_or(false))
            .cloned()
            .collect();
        if (candidates.len() as u32) < spec.copies {
            return Err(RucioError::InvalidValue(format!(
                "expression '{}' yields {} RSEs < {} copies",
                spec.rse_expression,
                candidates.len(),
                spec.copies
            )));
        }

        let files = self.resolve_files(&spec.did);
        // Plan phase: choose target RSEs per file without mutating.
        let mut plan: Vec<PlannedLock> = Vec::with_capacity(files.len() * spec.copies as usize);
        for f in &files {
            let chosen = self.select_rses_for_file(
                &f.key,
                &candidates,
                &writable,
                spec.copies,
                spec.weight.as_deref(),
                &BTreeSet::new(),
            )?;
            for (rse, have_available, have_copying) in chosen {
                plan.push(PlannedLock {
                    did: f.key.clone(),
                    bytes: f.bytes,
                    adler32: f.adler32.clone(),
                    rse,
                    have_available,
                    have_copying,
                });
            }
        }

        // Quota phase (§2.5: "when requesting the replication rule Rucio
        // validates the available quota").
        let mut needed: std::collections::BTreeMap<String, u64> = Default::default();
        for p in &plan {
            *needed.entry(p.rse.clone()).or_insert(0) += p.bytes;
        }
        for (rse, bytes) in &needed {
            self.check_quota(&spec.account, rse, *bytes)?;
        }

        // Apply phase: the whole plan lands as batched writes (one commit
        // per table) instead of row-at-a-time inserts.
        let rule_id = self.next_id();
        let expires_at = spec.lifetime_ms.map(|l| now + l);
        self.rules.insert(
            Rule {
                id: rule_id,
                account: spec.account.clone(),
                did: spec.did.clone(),
                rse_expression: spec.rse_expression.clone(),
                copies: spec.copies,
                state: RuleState::Replicating, // fixed up below
                locks_ok: 0,
                locks_replicating: 0,
                locks_stuck: 0,
                expires_at,
                weight: spec.weight.clone(),
                activity: spec.activity.clone(),
                created_at: now,
                updated_at: now,
                child_rule: None,
                subscription_id: spec.subscription_id,
                purge_replicas: spec.purge_replicas,
                stuck_at: None,
            },
            now,
        )?;
        self.apply_planned_locks(rule_id, &spec.account, &spec.activity, plan)?;
        self.refresh_rule_state(rule_id);
        self.metrics.incr("rules.added", 1);
        self.notify(
            "rule-created",
            Json::obj()
                .with("rule_id", rule_id)
                .with("account", spec.account.as_str())
                .with("scope", spec.did.scope.as_str())
                .with("name", spec.did.name.as_str())
                .with("rse_expression", spec.rse_expression.as_str())
                .with("copies", spec.copies as u64),
        );
        Ok(rule_id)
    }

    /// RSE selection for one file (§2.5: "Rucio primarily tries to
    /// minimize the amount of transfers created, thus it prioritizes RSEs
    /// where data is partially already available. Otherwise RSEs are
    /// selected randomly unless the weight parameter ... is used").
    /// Returns (rse, have_available, have_copying) triples.
    fn select_rses_for_file(
        &self,
        file: &DidKey,
        candidates: &[String],
        writable: &[String],
        copies: u32,
        weight: Option<&str>,
        exclude: &BTreeSet<String>,
    ) -> Result<Vec<(String, bool, bool)>> {
        let replicas = self.list_replicas(file);
        let mut chosen: Vec<(String, bool, bool)> = Vec::new();
        let candidate_set: BTreeSet<&String> = candidates.iter().collect();

        // 1. existing available replicas in the candidate set
        for r in replicas.iter().filter(|r| r.state == ReplicaState::Available) {
            if chosen.len() as u32 >= copies {
                break;
            }
            if candidate_set.contains(&r.rse) && !exclude.contains(&r.rse) {
                chosen.push((r.rse.clone(), true, false));
            }
        }
        // 2. inbound copies (share the pending transfer)
        for r in replicas.iter().filter(|r| r.state == ReplicaState::Copying) {
            if chosen.len() as u32 >= copies {
                break;
            }
            if candidate_set.contains(&r.rse)
                && !exclude.contains(&r.rse)
                && !chosen.iter().any(|(c, _, _)| c == &r.rse)
            {
                chosen.push((r.rse.clone(), false, true));
            }
        }
        // 3. fresh targets: weighted/random among writable candidates
        let mut pool: Vec<String> = writable
            .iter()
            .filter(|r| !exclude.contains(*r) && !chosen.iter().any(|(c, _, _)| c == *r))
            .cloned()
            .collect();
        while (chosen.len() as u32) < copies {
            if pool.is_empty() {
                return Err(RucioError::InvalidValue(format!(
                    "not enough writable RSEs for {file}: need {copies}, have {}",
                    chosen.len()
                )));
            }
            let idx = match weight {
                Some(attr) => {
                    let weights: Vec<f64> = pool
                        .iter()
                        .map(|r| {
                            self.get_rse(r)
                                .ok()
                                .and_then(|x| x.attr(attr).and_then(|v| v.parse().ok()))
                                .unwrap_or(1.0f64)
                                .max(0.0)
                        })
                        .collect();
                    if weights.iter().sum::<f64>() <= 0.0 {
                        self.rng.lock().unwrap().range_usize(0, pool.len())
                    } else {
                        self.rng.lock().unwrap().weighted(&weights)
                    }
                }
                None => self.rng.lock().unwrap().range_usize(0, pool.len()),
            };
            let rse = pool.swap_remove(idx);
            chosen.push((rse, false, false));
        }
        Ok(chosen)
    }

    /// Materialize one planned lock (repair / re-evaluation paths).
    fn apply_planned_lock(
        &self,
        rule_id: u64,
        account: &str,
        activity: &str,
        p: PlannedLock,
    ) -> Result<()> {
        self.apply_planned_locks(rule_id, account, activity, vec![p])
    }

    /// Materialize a batch of planned locks with one commit per table:
    /// replica protections (lock_count bump / Copying stubs), lock rows,
    /// deduplicated transfer requests, the rule's tallies, and per-RSE
    /// account-usage charges are each applied once per batch instead of
    /// once per row (paper §3.6 bulk operations).
    fn apply_planned_locks(
        &self,
        rule_id: u64,
        account: &str,
        activity: &str,
        plan: Vec<PlannedLock>,
    ) -> Result<()> {
        if plan.is_empty() {
            return Ok(());
        }
        let now = self.now();

        // Stage phase: resolve everything (and surface errors) before any
        // mutation, so stage-phase validation failures leave no partial
        // state. (There are no cross-table transactions; the commit phase
        // below orders its writes so the only realistically fallible one
        // happens first.)
        let mut protect: Vec<(String, DidKey)> = Vec::new();
        let mut stubs: Vec<Replica> = Vec::new();
        let mut lock_rows: Vec<ReplicaLock> = Vec::with_capacity(plan.len());
        let mut request_rows: Vec<TransferRequest> = Vec::new();
        let mut batch_dests: BTreeSet<(String, DidKey)> = BTreeSet::new();
        let mut tally_ok = 0u32;
        let mut tally_replicating = 0u32;
        let mut usage: std::collections::BTreeMap<String, (i64, i64)> = Default::default();

        for p in &plan {
            let replica_key = (p.rse.clone(), p.did.clone());
            if self.replicas.contains(&replica_key) {
                // Protect the replica: bump lock_count, clear tombstone
                // (§2.5: "replica locks ... lock a replica on a certain RSE").
                protect.push(replica_key);
            } else {
                // New stub in Copying; a transfer will fill it.
                let rse = self.get_rse(&p.rse)?;
                let pfn = rse
                    .lfn2pfn(&p.did.scope, &p.did.name)
                    .unwrap_or_else(|| format!("/nondet/{}/{}", p.did.scope, p.did.name));
                stubs.push(Replica {
                    rse: p.rse.clone(),
                    did: p.did.clone(),
                    bytes: p.bytes,
                    state: ReplicaState::Copying,
                    pfn,
                    lock_count: 1,
                    tombstone: None,
                    accessed_at: now,
                    created_at: now,
                    error_count: 0,
                });
            }
            let lock_state = if p.have_available { LockState::Ok } else { LockState::Replicating };
            match lock_state {
                LockState::Ok => tally_ok += 1,
                _ => tally_replicating += 1,
            }
            lock_rows.push(ReplicaLock {
                rule_id,
                rse: p.rse.clone(),
                did: p.did.clone(),
                state: lock_state,
                bytes: p.bytes,
            });
            let e = usage.entry(p.rse.clone()).or_insert((0, 0));
            e.0 += p.bytes as i64;
            e.1 += 1;

            // Transfer request, unless data is (or is becoming) available.
            // Dedup against live requests AND earlier entries of this batch.
            if !p.have_available && !p.have_copying {
                let dest = (p.rse.clone(), p.did.clone());
                if self.requests_by_dest.get(&dest).is_empty() && batch_dests.insert(dest) {
                    request_rows.push(TransferRequest {
                        id: self.next_id(),
                        did: p.did.clone(),
                        dst_rse: p.rse.clone(),
                        rule_id,
                        bytes: p.bytes,
                        adler32: p.adler32.clone(),
                        activity: activity.to_string(),
                        state: self.initial_request_state(),
                        attempts: 0,
                        priority: PRIORITY_NORMAL,
                        path: None,
                        hop: 0,
                        src_rse: None,
                        external_id: None,
                        fts_server: None,
                        created_at: now,
                        updated_at: now,
                        retry_after: None,
                        last_error: None,
                    });
                }
            }
        }

        // Commit phase: one batched write per table. The stub insert is
        // the only realistically fallible commit (a racing add_replica can
        // make a staged stub a duplicate), so it runs FIRST — if it fails,
        // no other table has been touched yet and the plan aborts cleanly.
        self.replicas.insert_bulk(stubs, now)?;
        self.locks.insert_bulk(lock_rows, now)?;
        let n_requests = request_rows.len();
        if n_requests > 0 {
            self.requests.insert_bulk(request_rows, now)?;
            self.metrics.incr("requests.created", n_requests as u64);
        }
        self.replicas.update_bulk(&protect, now, |r| {
            r.lock_count += 1;
            r.tombstone = None;
        });
        self.rules.update(&rule_id, now, |r| {
            r.locks_ok += tally_ok;
            r.locks_replicating += tally_replicating;
        });
        for (rse, (bytes, files)) in usage {
            self.charge_usage(account, &rse, bytes, files);
        }
        Ok(())
    }

    /// Recompute a rule's state from its lock tallies; notify on OK.
    pub(crate) fn refresh_rule_state(&self, rule_id: u64) {
        let now = self.now();
        let Some(rule) = self.rules.get(&rule_id) else { return };
        let new_state = if rule.locks_stuck > 0 {
            RuleState::Stuck
        } else if rule.locks_replicating > 0 {
            RuleState::Replicating
        } else {
            RuleState::Ok
        };
        if new_state != rule.state {
            self.rules.update(&rule_id, now, |r| {
                r.state = new_state;
                r.updated_at = now;
                if new_state == RuleState::Stuck {
                    r.stuck_at = Some(now);
                }
            });
            // §2.5: "notifications are always provided for state changes of
            // rules" — workflow systems key off rule-ok.
            let event = match new_state {
                RuleState::Ok => "rule-ok",
                RuleState::Stuck => "rule-stuck",
                _ => "rule-progress",
            };
            self.notify(
                event,
                Json::obj()
                    .with("rule_id", rule_id)
                    .with("scope", rule.did.scope.as_str())
                    .with("name", rule.did.name.as_str())
                    .with("state", new_state.as_str()),
            );
        }
    }

    /// Create many rules as one atomic call: each rule's locks and
    /// transfer requests land through the usual batched commits; a
    /// mid-batch failure rolls back the rules already created (the
    /// `delete_rule` unwind releases locks, refunds usage, re-tombstones),
    /// so callers observe all rules or none. Shared by `POST /rules/bulk`
    /// and the transmogrifier's per-subscription sweeps.
    pub fn add_rules_bulk(&self, specs: Vec<RuleSpec>) -> Result<Vec<u64>> {
        let mut ids: Vec<u64> = Vec::with_capacity(specs.len());
        for spec in specs {
            match self.add_rule(spec) {
                Ok(id) => ids.push(id),
                Err(e) => {
                    for id in ids {
                        let _ = self.delete_rule(id);
                    }
                    return Err(e);
                }
            }
        }
        Ok(ids)
    }

    pub fn get_rule(&self, rule_id: u64) -> Result<Rule> {
        self.rules
            .get(&rule_id)
            .ok_or_else(|| RucioError::RuleNotFound(rule_id.to_string()))
    }

    pub fn list_rules_for_did(&self, did: &DidKey) -> Vec<Rule> {
        self.rules_by_did
            .get(did)
            .into_iter()
            .filter_map(|id| self.rules.get(&id))
            .collect()
    }

    // ------------------------------------------------------------------
    // transfer outcome handling (§4.2 step 4: transfer-finisher)
    // ------------------------------------------------------------------

    /// New requests enter through the throttler's admission state when
    /// `[throttler] enabled` is set (paper Fig 6: activity shares gate
    /// submission); otherwise they queue directly, exactly as before.
    pub(crate) fn initial_request_state(&self) -> RequestState {
        if self.cfg.get_bool("throttler", "enabled", false) {
            RequestState::Waiting
        } else {
            RequestState::Queued
        }
    }

    /// A transfer finished successfully: replica becomes available, all
    /// replicating locks on it flip to OK, covering rules update. For a
    /// multi-hop chain this is the *final* hop (intermediate hops go
    /// through [`Catalog::advance_hop`]); the staging replicas are
    /// tombstoned here so the reaper collects them.
    pub fn on_transfer_done(&self, request_id: u64) -> Result<()> {
        let now = self.now();
        let req = self
            .requests
            .get(&request_id)
            .ok_or_else(|| RucioError::Internal(format!("request {request_id} unknown")))?;
        // Validate on the snapshot for a clean error, then re-check under
        // the row lock: a concurrent cancel must not be overwritten
        // (terminal states accept nothing).
        request_transition(req.state, RequestEvent::Done)?;
        let mut applied = false;
        self.requests.update(&request_id, now, |r| {
            if let Ok(next) = request_transition(r.state, RequestEvent::Done) {
                r.state = next;
                r.updated_at = now;
                // terminal rows carry no active chain (consistent with
                // the failure and cancel paths)
                r.path = None;
                r.hop = 0;
                applied = true;
            }
        });
        if !applied {
            return Err(RucioError::InvalidValue(format!(
                "request {request_id} raced to a terminal state"
            )));
        }
        // Chain bookkeeping: staging replicas served their purpose —
        // tombstone them now (reaper-collectable) unless another rule
        // locked them in the meantime.
        for rse in req.intermediate_rses() {
            let key = (rse.clone(), req.did.clone());
            let mut tombstoned = false;
            self.replicas.update(&key, now, |r| {
                if r.lock_count == 0 {
                    r.tombstone = Some(now);
                    tombstoned = true;
                }
            });
            if tombstoned {
                self.metrics.incr("conveyor.multihop.intermediates_tombstoned", 1);
            }
        }
        self.replica_available(&req.dst_rse, &req.did)?;
        let replica_key = (req.dst_rse.clone(), req.did.clone());
        // Orphaned arrival (rule deleted mid-flight): leave it cache-like.
        if self.replicas.get(&replica_key).map(|r| r.lock_count).unwrap_or(0) == 0 {
            self.replicas.update(&replica_key, now, |r| r.tombstone = Some(now));
        }
        for lock_key in self.locks_by_replica.get(&replica_key) {
            let Some(lock) = self.locks.get(&lock_key) else { continue };
            if lock.state != LockState::Replicating {
                continue;
            }
            self.locks.update(&lock_key, now, |l| l.state = LockState::Ok);
            self.rules.update(&lock.rule_id, now, |r| {
                r.locks_replicating = r.locks_replicating.saturating_sub(1);
                r.locks_ok += 1;
                r.updated_at = now;
            });
            self.refresh_rule_state(lock.rule_id);
        }
        self.metrics.incr("transfers.done", 1);
        Ok(())
    }

    /// A transfer failed: retry with backoff, then mark locks STUCK
    /// (§4.2: "for failed transfer requests the transfer-finisher will
    /// update the associated replication rule as STUCK").
    pub fn on_transfer_failed(&self, request_id: u64, reason: &str) -> Result<()> {
        let now = self.now();
        let req = self
            .requests
            .get(&request_id)
            .ok_or_else(|| RucioError::Internal(format!("request {request_id} unknown")))?;
        // A checksum mismatch at the source means the source copy itself
        // is damaged (§2.4: "the replica will be flagged as suspicious"):
        // every strike counts, and after the threshold the necromancer
        // takes over recovery from another copy. Matched on the shared
        // constant so destination-side checksum wording never blames a
        // healthy source.
        if reason.contains(crate::ftssim::REASON_SOURCE_CHECKSUM) {
            if let Some(src) = &req.src_rse {
                let _ = self.declare_suspicious(src, &req.did, reason);
            }
        }
        let max_attempts = self.cfg.get_i64("conveyor", "max_attempts", 3) as u32;
        let retry_delay = self.cfg.get_duration_ms("conveyor", "retry_delay", 600_000);
        let attempts = req.attempts + 1;
        // A failed chain is abandoned: un-landed staging stubs are
        // dropped, landed intermediates tombstoned, and the retry (if
        // any) re-plans from scratch — the topology may have changed.
        self.cleanup_chain_intermediates(&req, now);
        if attempts < max_attempts {
            request_transition(req.state, RequestEvent::FailRetry)?;
            let mut applied = false;
            self.requests.update(&request_id, now, |r| {
                if let Ok(next) = request_transition(r.state, RequestEvent::FailRetry) {
                    r.attempts = attempts;
                    r.state = next;
                    r.retry_after = Some(now + retry_delay);
                    r.last_error = Some(reason.to_string());
                    r.updated_at = now;
                    r.external_id = None;
                    r.path = None;
                    r.hop = 0;
                    applied = true;
                }
            });
            if applied {
                self.metrics.incr("transfers.retried", 1);
            }
            return Ok(());
        }
        request_transition(req.state, RequestEvent::FailFinal)?;
        let mut applied = false;
        self.requests.update(&request_id, now, |r| {
            if let Ok(next) = request_transition(r.state, RequestEvent::FailFinal) {
                r.attempts = attempts;
                r.state = next;
                r.last_error = Some(reason.to_string());
                r.updated_at = now;
                r.path = None;
                r.hop = 0;
                applied = true;
            }
        });
        if !applied {
            return Ok(()); // raced to a terminal state; nothing to stick
        }
        let replica_key = (req.dst_rse.clone(), req.did.clone());
        for lock_key in self.locks_by_replica.get(&replica_key) {
            let Some(lock) = self.locks.get(&lock_key) else { continue };
            if lock.state != LockState::Replicating {
                continue;
            }
            self.locks.update(&lock_key, now, |l| l.state = LockState::Stuck);
            self.rules.update(&lock.rule_id, now, |r| {
                r.locks_replicating = r.locks_replicating.saturating_sub(1);
                r.locks_stuck += 1;
                r.updated_at = now;
            });
            self.refresh_rule_state(lock.rule_id);
        }
        self.metrics.incr("transfers.failed", 1);
        Ok(())
    }

    /// An intermediate hop of a multi-hop chain landed: the staging
    /// replica becomes available (it is the next hop's source) and the
    /// request re-queues for the next hop's submission. Re-queued hops
    /// bypass the throttler — the chain was admitted once.
    pub fn advance_hop(&self, request_id: u64) -> Result<()> {
        let now = self.now();
        let req = self
            .requests
            .get(&request_id)
            .ok_or_else(|| RucioError::Internal(format!("request {request_id} unknown")))?;
        request_transition(req.state, RequestEvent::HopDone)?;
        let (_, landed) = req
            .current_hop()
            .ok_or_else(|| RucioError::Internal(format!("request {request_id} has no chain")))?;
        // Gate under the row lock (a racing cancel must win), then flip
        // the landed staging replica available — if that fails, the
        // re-queued hop finds no source and the retry path re-plans.
        let mut applied = false;
        self.requests.update(&request_id, now, |r| {
            if let Ok(next) = request_transition(r.state, RequestEvent::HopDone) {
                r.state = next;
                r.hop += 1;
                r.external_id = None;
                r.fts_server = None;
                r.updated_at = now;
                applied = true;
            }
        });
        if !applied {
            return Err(RucioError::InvalidValue(format!(
                "request {request_id} raced out of SUBMITTED"
            )));
        }
        self.replica_available(landed, &req.did)?;
        self.metrics.incr("conveyor.multihop.hops_done", 1);
        Ok(())
    }

    /// Drop a chain's staging replicas: never-landed Copying stubs are
    /// removed outright, landed copies are tombstoned for the reaper.
    /// Replicas another rule locked in the meantime are left alone.
    pub(crate) fn cleanup_chain_intermediates(&self, req: &TransferRequest, now: EpochMs) {
        for rse in req.intermediate_rses() {
            let key = (rse.clone(), req.did.clone());
            let Some(rep) = self.replicas.get(&key) else { continue };
            if rep.lock_count > 0 {
                continue;
            }
            if rep.state == ReplicaState::Copying {
                let _ = self.replicas.remove(&key, now);
                self.refresh_availability(&req.did);
            } else {
                self.replicas.update(&key, now, |r| r.tombstone = Some(now));
            }
        }
    }

    // ------------------------------------------------------------------
    // repair (§4.2: rule-repairer "will either decide to submit a new
    // transfer request for an alternative destination RSE or re-submit,
    // after some delay, a transfer request for the same RSE")
    // ------------------------------------------------------------------

    pub fn repair_rule(&self, rule_id: u64) -> Result<()> {
        let now = self.now();
        let rule = self.get_rule(rule_id)?;
        if rule.state != RuleState::Stuck {
            return Ok(());
        }
        let candidates = self.resolve_rse_expression(&rule.rse_expression)?;
        let writable: Vec<String> = candidates
            .iter()
            .filter(|r| self.get_rse(r).map(|x| x.availability_write).unwrap_or(false))
            .cloned()
            .collect();

        for lock_key in self.locks_by_rule.get(&rule_id) {
            let Some(lock) = self.locks.get(&lock_key) else { continue };
            if lock.state != LockState::Stuck {
                continue;
            }
            // RSEs this rule already uses for the file (any state).
            let used: BTreeSet<String> = self
                .locks_by_rule
                .get(&rule_id)
                .into_iter()
                .filter_map(|k| self.locks.get(&k))
                .filter(|l| l.did == lock.did)
                .map(|l| l.rse)
                .collect();
            let alternative = self
                .select_rses_for_file(&lock.did, &candidates, &writable, 1, rule.weight.as_deref(), &used)
                .ok()
                .and_then(|v| v.into_iter().next());

            match alternative {
                Some((new_rse, have_available, have_copying)) => {
                    // Move the lock to the alternative RSE.
                    self.release_lock(&lock, &rule.account, now, rule.purge_replicas);
                    self.rules.update(&rule_id, now, |r| {
                        r.locks_stuck = r.locks_stuck.saturating_sub(1);
                    });
                    self.apply_planned_lock(
                        rule_id,
                        &rule.account,
                        &rule.activity,
                        PlannedLock {
                            did: lock.did.clone(),
                            bytes: lock.bytes,
                            adler32: self
                                .get_did(&lock.did)
                                .map(|d| d.adler32)
                                .unwrap_or_default(),
                            rse: new_rse,
                            have_available,
                            have_copying,
                        },
                    )?;
                }
                None => {
                    // Same-RSE delayed retry: fresh request, lock back to
                    // Replicating. The replica row may be gone (the
                    // necromancer removes bad copies while locks are
                    // stuck): recreate the Copying stub the lock protects
                    // so the retried transfer has a destination record.
                    let replica_key = (lock.rse.clone(), lock.did.clone());
                    if !self.replicas.contains(&replica_key) {
                        let pfn = self
                            .get_rse(&lock.rse)
                            .ok()
                            .and_then(|r| r.lfn2pfn(&lock.did.scope, &lock.did.name))
                            .unwrap_or_else(|| {
                                format!("/nondet/{}/{}", lock.did.scope, lock.did.name)
                            });
                        let lock_count =
                            self.locks_by_replica.get(&replica_key).len() as u32;
                        let _ = self.replicas.insert(
                            Replica {
                                rse: lock.rse.clone(),
                                did: lock.did.clone(),
                                bytes: lock.bytes,
                                state: ReplicaState::Copying,
                                pfn,
                                lock_count,
                                tombstone: None,
                                accessed_at: now,
                                created_at: now,
                                error_count: 0,
                            },
                            now,
                        );
                    }
                    self.locks.update(&lock_key, now, |l| l.state = LockState::Replicating);
                    self.rules.update(&rule_id, now, |r| {
                        r.locks_stuck = r.locks_stuck.saturating_sub(1);
                        r.locks_replicating += 1;
                    });
                    let existing = self
                        .requests_by_dest
                        .get(&(lock.rse.clone(), lock.did.clone()));
                    if existing.is_empty() {
                        let req_id = self.next_id();
                        let adler32 =
                            self.get_did(&lock.did).map(|d| d.adler32).unwrap_or_default();
                        self.requests.insert(
                            TransferRequest {
                                id: req_id,
                                did: lock.did.clone(),
                                dst_rse: lock.rse.clone(),
                                rule_id,
                                bytes: lock.bytes,
                                adler32,
                                activity: rule.activity.clone(),
                                state: self.initial_request_state(),
                                attempts: 0,
                                priority: PRIORITY_NORMAL,
                                path: None,
                                hop: 0,
                                src_rse: None,
                                external_id: None,
                                fts_server: None,
                                created_at: now,
                                updated_at: now,
                                retry_after: None,
                                last_error: None,
                            },
                            now,
                        )?;
                    }
                }
            }
        }
        self.refresh_rule_state(rule_id);
        self.metrics.incr("rules.repaired", 1);
        Ok(())
    }

    // ------------------------------------------------------------------
    // rule removal + expiry (§4.3)
    // ------------------------------------------------------------------

    /// Remove a rule: locks released in one batched commit, usage
    /// refunded per RSE, replicas tombstoned when unprotected ("at the
    /// end of the rule lifetime replicas become eligible for deletion").
    pub fn delete_rule(&self, rule_id: u64) -> Result<()> {
        let now = self.now();
        let rule = self.get_rule(rule_id)?;
        let lock_keys = self.locks_by_rule.get(&rule_id);
        // Rule row goes first: the release bookkeeping below re-homes or
        // cancels transfer requests that reference rules which no longer
        // exist, so the rule must already be gone when it runs.
        self.rules.remove(&rule_id, now);
        let released = self.locks.remove_bulk(&lock_keys, now);
        self.release_removed_locks(&released, &rule.account, now, rule.purge_replicas);
        self.metrics.incr("rules.deleted", 1);
        self.notify(
            "rule-deleted",
            Json::obj()
                .with("rule_id", rule_id)
                .with("scope", rule.did.scope.as_str())
                .with("name", rule.did.name.as_str()),
        );
        Ok(())
    }

    /// Release one lock: remove the row, then the shared post-release
    /// bookkeeping.
    fn release_lock(&self, lock: &ReplicaLock, account: &str, now: EpochMs, purge: bool) {
        self.locks
            .remove(&(lock.rule_id, lock.rse.clone(), lock.did.clone()), now);
        self.release_removed_locks(std::slice::from_ref(lock), account, now, purge);
    }

    /// Post-removal bookkeeping for a batch of released locks (the lock
    /// rows themselves are already gone): replica lock_counts and
    /// tombstones flip in one commit, never-completed Copying stubs are
    /// dropped, and usage is refunded once per RSE instead of per row.
    fn release_removed_locks(
        &self,
        locks: &[ReplicaLock],
        account: &str,
        now: EpochMs,
        purge: bool,
    ) {
        if locks.is_empty() {
            return;
        }
        // §4.3: "all rule removals are configured with a 24h delay to undo
        // any potential changes" — the grace period before eligibility.
        let grace = if purge {
            0
        } else {
            self.cfg.get_duration_ms("reaper", "tombstone_grace", 24 * 3_600_000)
        };
        let replica_keys: Vec<(String, DidKey)> =
            locks.iter().map(|l| (l.rse.clone(), l.did.clone())).collect();
        let updated = self.replicas.update_bulk(&replica_keys, now, |r| {
            r.lock_count = r.lock_count.saturating_sub(1);
            if r.lock_count == 0 {
                r.tombstone = Some(now + grace);
            }
        });
        // A never-completed Copying stub with no locks left: drop it
        // immediately (nothing physical exists yet).
        let dead: Vec<(String, DidKey)> = updated
            .iter()
            .filter(|r| r.lock_count == 0 && r.state == ReplicaState::Copying)
            .map(|r| (r.rse.clone(), r.did.clone()))
            .collect();
        if !dead.is_empty() {
            let removed = self.replicas.remove_bulk(&dead, now);
            let mut seen: BTreeSet<DidKey> = BTreeSet::new();
            for rep in &removed {
                if seen.insert(rep.did.clone()) {
                    self.refresh_availability(&rep.did);
                }
            }
        }
        let mut usage: std::collections::BTreeMap<String, (i64, i64)> = Default::default();
        for l in locks {
            let e = usage.entry(l.rse.clone()).or_insert((0, 0));
            e.0 -= l.bytes as i64;
            e.1 -= 1;
        }
        for (rse, (bytes, files)) in usage {
            self.charge_usage(account, &rse, bytes, files);
        }
        // Transfer requests owned by a released lock's rule must not be
        // left orphaned (system invariant: every live request references a
        // live rule): re-home the request to a surviving replicating lock
        // on the same replica, or cancel it.
        for l in locks {
            let dest = (l.rse.clone(), l.did.clone());
            for req_id in self.requests_by_dest.get(&dest) {
                let Some(req) = self.requests.get(&req_id) else { continue };
                if req.rule_id != l.rule_id || self.rules.contains(&req.rule_id) {
                    continue;
                }
                let heir = self
                    .locks_by_replica
                    .get(&dest)
                    .into_iter()
                    .filter_map(|k| self.locks.get(&k))
                    .find(|x| x.state == LockState::Replicating);
                match heir {
                    Some(h) => {
                        self.requests.update(&req_id, now, |r| {
                            r.rule_id = h.rule_id;
                            r.updated_at = now;
                        });
                    }
                    None => {
                        // Cancel: an in-flight multi-hop chain is wound
                        // down too (stubs dropped, landed intermediates
                        // tombstoned for the reaper). The transition gate
                        // keeps a request that just completed terminal —
                        // a DONE row is never flipped to FAILED.
                        self.cleanup_chain_intermediates(&req, now);
                        self.requests.update(&req_id, now, |r| {
                            if let Ok(next) =
                                request_transition(r.state, RequestEvent::Cancel)
                            {
                                r.state = next;
                                r.last_error = Some("rule removed".into());
                                r.updated_at = now;
                                r.path = None;
                                r.hop = 0;
                            }
                        });
                    }
                }
            }
        }
    }

    /// A replica can no longer back its locks (declared bad, §4.4): flip
    /// every non-stuck lock on it STUCK and fix the owning rules' tallies
    /// in one place, so the lock/tally arithmetic has a single home.
    pub(crate) fn stick_locks_on_replica(&self, rse: &str, did: &DidKey, now: EpochMs) {
        let replica_key = (rse.to_string(), did.clone());
        for lock_key in self.locks_by_replica.get(&replica_key) {
            let Some(lock) = self.locks.get(&lock_key) else { continue };
            if lock.state == LockState::Stuck {
                continue;
            }
            self.locks.update(&lock_key, now, |l| l.state = LockState::Stuck);
            self.rules.update(&lock.rule_id, now, |r| {
                match lock.state {
                    LockState::Ok => r.locks_ok = r.locks_ok.saturating_sub(1),
                    LockState::Replicating => {
                        r.locks_replicating = r.locks_replicating.saturating_sub(1)
                    }
                    LockState::Stuck => {}
                }
                r.locks_stuck += 1;
                r.stuck_at = Some(now);
                r.updated_at = now;
            });
            self.refresh_rule_state(lock.rule_id);
        }
    }

    /// A file is permanently lost (§4.4 last-copy handling): every rule
    /// still covering it — in particular dataset/container rules reaching
    /// it through the hierarchy — drops its locks on the file, exactly as
    /// if the file had been detached. Without this, ancestor rules would
    /// cycle STUCK forever on data that no longer exists anywhere.
    pub(crate) fn release_locks_on_lost_file(&self, did: &DidKey) {
        let now = self.now();
        let stranded: Vec<ReplicaLock> = self
            .locks_by_did
            .get(did)
            .into_iter()
            .filter_map(|k| self.locks.get(&k))
            .collect();
        for lock in stranded {
            let Some(rule) = self.rules.get(&lock.rule_id) else { continue };
            self.rules.update(&lock.rule_id, now, |r| match lock.state {
                LockState::Ok => r.locks_ok = r.locks_ok.saturating_sub(1),
                LockState::Replicating => {
                    r.locks_replicating = r.locks_replicating.saturating_sub(1)
                }
                LockState::Stuck => r.locks_stuck = r.locks_stuck.saturating_sub(1),
            });
            self.release_lock(&lock, &rule.account, now, rule.purge_replicas);
            self.refresh_rule_state(lock.rule_id);
        }
    }

    /// Campaign-scale expiry: point many rules' lifetimes at `expires_at`
    /// in one pass (mass-deletion sweeps, §4.3 deletion-rate tables). The
    /// `rules_by_expiry` index follows each update, so the judge-cleaner's
    /// next `process_expired_rules` sweep picks the whole batch up.
    /// Unknown rule ids are skipped; returns the number of rules updated.
    pub fn set_rule_expiration_bulk(
        &self,
        rule_ids: &[u64],
        expires_at: Option<EpochMs>,
    ) -> usize {
        let now = self.now();
        let updated = self
            .rules
            .update_bulk(rule_ids, now, |r| r.expires_at = expires_at)
            .len();
        self.metrics.incr("rules.expiry_bulk_updates", updated as u64);
        updated
    }

    /// Expired rules (judge-cleaner work queue): delete up to `limit`
    /// rules whose expiry passed.
    pub fn process_expired_rules(&self, limit: usize) -> usize {
        let now = self.now();
        let expired = self.rules_by_expiry.range_limit(&i64::MIN, &now, limit);
        let n = expired.len();
        for rule_id in expired {
            let _ = self.delete_rule(rule_id);
        }
        n
    }

    // ------------------------------------------------------------------
    // content-change re-evaluation (§2.5: "when files are added or removed
    // from a dataset, the replication rule also reflects these changes")
    // ------------------------------------------------------------------

    /// Called by `attach`: extend rules covering `parent` (or any of its
    /// ancestors) over the newly reachable files.
    pub(crate) fn on_content_added(&self, parent: &DidKey, files: &[Did]) -> Result<()> {
        if files.is_empty() {
            return Ok(());
        }
        let mut covering: Vec<u64> = self.rules_by_did.get(parent);
        for anc in self.ancestors(parent) {
            covering.extend(self.rules_by_did.get(&anc));
        }
        covering.sort();
        covering.dedup();
        for rule_id in covering {
            let Some(rule) = self.rules.get(&rule_id) else { continue };
            let Ok(candidates) = self.resolve_rse_expression(&rule.rse_expression) else {
                continue;
            };
            let writable: Vec<String> = candidates
                .iter()
                .filter(|r| self.get_rse(r).map(|x| x.availability_write).unwrap_or(false))
                .cloned()
                .collect();
            // Plan across all newly reachable files, then extend the rule
            // with one batched commit.
            let mut plan: Vec<PlannedLock> = Vec::new();
            for f in files {
                // Skip files the rule already covers.
                let has_lock = self
                    .locks_by_rule
                    .get(&rule_id)
                    .into_iter()
                    .filter_map(|k| self.locks.get(&k))
                    .any(|l| l.did == f.key);
                if has_lock {
                    continue;
                }
                let copies = rule.copies.min(candidates.len() as u32);
                if let Ok(chosen) = self.select_rses_for_file(
                    &f.key,
                    &candidates,
                    &writable,
                    copies,
                    rule.weight.as_deref(),
                    &BTreeSet::new(),
                ) {
                    for (rse, have_available, have_copying) in chosen {
                        plan.push(PlannedLock {
                            did: f.key.clone(),
                            bytes: f.bytes,
                            adler32: f.adler32.clone(),
                            rse,
                            have_available,
                            have_copying,
                        });
                    }
                }
            }
            self.apply_planned_locks(rule_id, &rule.account, &rule.activity, plan)?;
            self.refresh_rule_state(rule_id);
        }
        Ok(())
    }

    /// Called by `detach`: drop locks of rules that no longer reach the
    /// removed files.
    pub(crate) fn on_content_removed(&self, parent: &DidKey, files: &[Did]) -> Result<()> {
        if files.is_empty() {
            return Ok(());
        }
        let now = self.now();
        let mut covering: Vec<u64> = self.rules_by_did.get(parent);
        for anc in self.ancestors(parent) {
            covering.extend(self.rules_by_did.get(&anc));
        }
        covering.sort();
        covering.dedup();
        for rule_id in covering {
            let Some(rule) = self.rules.get(&rule_id) else { continue };
            let still_reachable: BTreeSet<DidKey> =
                self.resolve_files(&rule.did).into_iter().map(|d| d.key).collect();
            for f in files {
                if still_reachable.contains(&f.key) {
                    continue;
                }
                for lock_key in self.locks_by_rule.get(&rule_id) {
                    let Some(lock) = self.locks.get(&lock_key) else { continue };
                    if lock.did != f.key {
                        continue;
                    }
                    self.rules.update(&rule_id, now, |r| match lock.state {
                        LockState::Ok => r.locks_ok = r.locks_ok.saturating_sub(1),
                        LockState::Replicating => {
                            r.locks_replicating = r.locks_replicating.saturating_sub(1)
                        }
                        LockState::Stuck => r.locks_stuck = r.locks_stuck.saturating_sub(1),
                    });
                    self.release_lock(&lock, &rule.account, now, rule.purge_replicas);
                }
            }
            self.refresh_rule_state(rule_id);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // quota (§2.5)
    // ------------------------------------------------------------------

    pub fn set_account_limit(&self, account: &str, rse: &str, bytes: u64) -> Result<()> {
        self.get_account(account)?;
        self.get_rse(rse)?;
        self.limits.upsert(
            AccountLimit { account: account.to_string(), rse: rse.to_string(), bytes },
            self.now(),
        );
        Ok(())
    }

    pub fn get_account_limit(&self, account: &str, rse: &str) -> Option<u64> {
        self.limits
            .get(&(account.to_string(), rse.to_string()))
            .map(|l| l.bytes)
    }

    pub fn get_account_usage(&self, account: &str, rse: &str) -> AccountUsage {
        self.usages
            .get(&(account.to_string(), rse.to_string()))
            .unwrap_or(AccountUsage {
                account: account.to_string(),
                rse: rse.to_string(),
                bytes: 0,
                files: 0,
            })
    }

    fn check_quota(&self, account: &str, rse: &str, additional: u64) -> Result<()> {
        // Admin accounts bypass quota (root protects detector data with
        // unlimited rules, §4.3).
        if self.accounts.get(&account.to_string()).map(|a| a.admin).unwrap_or(false) {
            return Ok(());
        }
        if let Some(limit) = self.get_account_limit(account, rse) {
            let usage = self.get_account_usage(account, rse);
            if usage.bytes + additional > limit {
                return Err(RucioError::QuotaExceeded(format!(
                    "{account} on {rse}: {} + {additional} > {limit}",
                    usage.bytes
                )));
            }
        }
        Ok(())
    }

    fn charge_usage(&self, account: &str, rse: &str, bytes_delta: i64, files_delta: i64) {
        let key = (account.to_string(), rse.to_string());
        let now = self.now();
        if self.usages.contains(&key) {
            self.usages.update(&key, now, |u| {
                u.bytes = (u.bytes as i64 + bytes_delta).max(0) as u64;
                u.files = (u.files as i64 + files_delta).max(0) as u64;
            });
        } else {
            let _ = self.usages.insert(
                AccountUsage {
                    account: account.to_string(),
                    rse: rse.to_string(),
                    bytes: bytes_delta.max(0) as u64,
                    files: files_delta.max(0) as u64,
                },
                now,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::Catalog;

    /// Catalog with alice + 4 disk RSEs (2 FR, 2 DE) + one tape.
    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_account("alice", AccountType::User, "a@x").unwrap();
        c.add_scope("data18", "root").unwrap();
        for (name, country) in
            [("FR-A", "FR"), ("FR-B", "FR"), ("DE-A", "DE"), ("DE-B", "DE")]
        {
            c.add_rse(
                Rse::new(name, now)
                    .with_attr("country", country)
                    .with_attr("type", "disk"),
            )
            .unwrap();
        }
        c.add_rse(Rse::new("DE-TAPE", now).with_attr("country", "DE").with_tape())
            .unwrap();
        c
    }

    fn file(c: &Catalog, name: &str, bytes: u64) -> DidKey {
        c.add_file("data18", name, "root", bytes, "aabbccdd", None).unwrap();
        DidKey::new("data18", name)
    }

    fn assert_lock_invariant(c: &Catalog, rule_id: u64) {
        let rule = c.get_rule(rule_id).unwrap();
        let locks: Vec<ReplicaLock> = c
            .locks_by_rule
            .get(&rule_id)
            .into_iter()
            .filter_map(|k| c.locks.get(&k))
            .collect();
        let ok = locks.iter().filter(|l| l.state == LockState::Ok).count() as u32;
        let repl = locks.iter().filter(|l| l.state == LockState::Replicating).count() as u32;
        let stuck = locks.iter().filter(|l| l.state == LockState::Stuck).count() as u32;
        assert_eq!((rule.locks_ok, rule.locks_replicating, rule.locks_stuck), (ok, repl, stuck));
        // replica lock_count matches locks across all rules
        for l in &locks {
            let rep = c.get_replica(&l.rse, &l.did).unwrap();
            let total = c.locks_by_replica.get(&(l.rse.clone(), l.did.clone())).len() as u32;
            assert_eq!(rep.lock_count, total);
            assert!(rep.tombstone.is_none(), "locked replica must not be tombstoned");
        }
    }

    #[test]
    fn rule_without_replicas_creates_transfer() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c
            .add_rule(RuleSpec::new("root", f.clone(), "country=FR", 1))
            .unwrap();
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Replicating);
        assert_eq!(rule.locks_replicating, 1);
        assert_eq!(c.requests.len(), 1);
        let reqs = c.requests.scan(|_| true);
        assert_eq!(reqs[0].state, RequestState::Queued);
        assert!(reqs[0].dst_rse.starts_with("FR-"));
        // replica stub in Copying
        let rep = c.get_replica(&reqs[0].dst_rse, &f).unwrap();
        assert_eq!(rep.state, ReplicaState::Copying);
        assert_eq!(rep.lock_count, 1);
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn rule_on_existing_replica_is_instant_ok() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "country=FR", 1)).unwrap();
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Ok);
        assert_eq!(c.requests.len(), 0, "minimize transfers: reuse FR-A");
        // the replica is now protected
        let rep = c.get_replica("FR-A", &f).unwrap();
        assert_eq!(rep.lock_count, 1);
        assert!(rep.tombstone.is_none());
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn transfer_done_completes_rule_and_notifies() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        c.on_transfer_done(req.id).unwrap();
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Ok);
        assert_eq!(c.get_replica("DE-A", &f).unwrap().state, ReplicaState::Available);
        assert_eq!(c.get_did(&f).unwrap().availability, Availability::Available);
        // rule-ok notification queued
        let events: Vec<String> =
            c.outbox.scan(|_| true).into_iter().map(|m| m.event_type).collect();
        assert!(events.contains(&"rule-ok".to_string()), "{events:?}");
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn transfer_failure_retries_then_sticks() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        // two failures → Retry
        c.on_transfer_failed(req.id, "SOURCE gone").unwrap();
        assert_eq!(c.requests.get(&req.id).unwrap().state, RequestState::Retry);
        assert_eq!(c.get_rule(rid).unwrap().state, RuleState::Replicating);
        c.on_transfer_failed(req.id, "SOURCE gone").unwrap();
        assert_eq!(c.requests.get(&req.id).unwrap().attempts, 2);
        // third failure exhausts attempts → STUCK
        c.on_transfer_failed(req.id, "SOURCE gone").unwrap();
        assert_eq!(c.requests.get(&req.id).unwrap().state, RequestState::Failed);
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Stuck);
        assert_eq!(rule.locks_stuck, 1);
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn repair_moves_to_alternative_rse() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "country=DE&type=disk", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        let original_rse = req.dst_rse.clone();
        for _ in 0..3 {
            c.on_transfer_failed(req.id, "DESTINATION broken").unwrap();
        }
        assert_eq!(c.get_rule(rid).unwrap().state, RuleState::Stuck);
        c.repair_rule(rid).unwrap();
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Replicating);
        // lock moved to the other DE disk RSE
        let locks: Vec<ReplicaLock> = c
            .locks_by_rule
            .get(&rid)
            .into_iter()
            .filter_map(|k| c.locks.get(&k))
            .collect();
        assert_eq!(locks.len(), 1);
        assert_ne!(locks[0].rse, original_rse);
        // a fresh request exists for the new destination
        let queued = c.requests.scan(|r| r.state == RequestState::Queued);
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].dst_rse, locks[0].rse);
        // old Copying stub dropped
        assert!(c.get_replica(&original_rse, &f).is_err());
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn repair_requeues_same_rse_when_no_alternative() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        for _ in 0..3 {
            c.on_transfer_failed(req.id, "x").unwrap();
        }
        c.repair_rule(rid).unwrap();
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Replicating);
        let queued = c.requests.scan(|r| r.state == RequestState::Queued);
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].dst_rse, "DE-A");
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn two_rules_one_physical_copy_both_charged() {
        // §2.5: "the files are shared with only one physical copy, but ...
        // both accounts are charged for this file".
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        let r1 = c.add_rule(RuleSpec::new("root", f.clone(), "FR-A", 1)).unwrap();
        let r2 = c.add_rule(RuleSpec::new("alice", f.clone(), "FR-A", 1)).unwrap();
        assert_eq!(c.get_replica("FR-A", &f).unwrap().lock_count, 2);
        assert_eq!(c.get_account_usage("root", "FR-A").bytes, 1000);
        assert_eq!(c.get_account_usage("alice", "FR-A").bytes, 1000);
        // deleting one rule keeps the replica protected (no conflict)
        c.delete_rule(r1).unwrap();
        let rep = c.get_replica("FR-A", &f).unwrap();
        assert_eq!(rep.lock_count, 1);
        assert!(rep.tombstone.is_none());
        assert_eq!(c.get_account_usage("root", "FR-A").bytes, 0);
        // deleting the second frees it (tombstone with grace)
        c.delete_rule(r2).unwrap();
        let rep = c.get_replica("FR-A", &f).unwrap();
        assert_eq!(rep.lock_count, 0);
        assert!(rep.tombstone.unwrap() > c.now(), "24h grace applies");
    }

    #[test]
    fn quota_enforced_for_regular_accounts() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.set_account_limit("alice", "FR-A", 500).unwrap();
        c.set_account_limit("alice", "FR-B", 500).unwrap();
        let err = c.add_rule(RuleSpec::new("alice", f.clone(), "country=FR", 1));
        assert!(matches!(err, Err(RucioError::QuotaExceeded(_))), "{err:?}");
        // nothing leaked
        assert_eq!(c.rules.len(), 0);
        assert_eq!(c.locks.len(), 0);
        // admin bypasses quota
        c.set_account_limit("alice", "FR-A", 0).unwrap();
        assert!(c.add_rule(RuleSpec::new("root", f, "FR-A", 1)).is_ok());
    }

    #[test]
    fn copies_2_spreads_over_distinct_rses() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "type=disk", 2)).unwrap();
        let locks: Vec<ReplicaLock> = c
            .locks_by_rule
            .get(&rid)
            .into_iter()
            .filter_map(|k| c.locks.get(&k))
            .collect();
        assert_eq!(locks.len(), 2);
        assert_ne!(locks[0].rse, locks[1].rse);
        assert_eq!(c.requests.len(), 2);
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn copies_exceeding_candidates_rejected() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        assert!(c.add_rule(RuleSpec::new("root", f, "country=FR", 3)).is_err());
    }

    #[test]
    fn shared_request_dedup() {
        // Two rules needing the same (file, rse) share one transfer.
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let r1 = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let r2 = c.add_rule(RuleSpec::new("alice", f.clone(), "DE-A", 1)).unwrap();
        assert_eq!(c.requests.len(), 1, "deduplicated transfer");
        let req = c.requests.scan(|_| true)[0].clone();
        c.on_transfer_done(req.id).unwrap();
        assert_eq!(c.get_rule(r1).unwrap().state, RuleState::Ok);
        assert_eq!(c.get_rule(r2).unwrap().state, RuleState::Ok);
        assert_eq!(c.get_replica("DE-A", &f).unwrap().lock_count, 2);
    }

    #[test]
    fn dataset_rule_covers_all_files_and_extends_on_attach() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let f1 = file(&c, "f1", 100);
        c.attach(&ds, &f1).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", ds.clone(), "FR-A", 1)).unwrap();
        assert_eq!(c.locks_by_rule.get(&rid).len(), 1);
        // attach another file later → rule extends (§2.5)
        let f2 = file(&c, "f2", 200);
        c.attach(&ds, &f2).unwrap();
        assert_eq!(c.locks_by_rule.get(&rid).len(), 2);
        assert_eq!(c.requests.len(), 2);
        assert_lock_invariant(&c, rid);
        // container-level rules extend too
        c.add_container("data18", "cont", "root").unwrap();
        let cont = DidKey::new("data18", "cont");
        c.attach(&cont, &ds).unwrap();
        let rid2 = c.add_rule(RuleSpec::new("root", cont.clone(), "DE-A", 1)).unwrap();
        assert_eq!(c.locks_by_rule.get(&rid2).len(), 2);
        let f3 = file(&c, "f3", 300);
        c.attach(&ds, &f3).unwrap();
        assert_eq!(c.locks_by_rule.get(&rid).len(), 3, "dataset rule");
        assert_eq!(c.locks_by_rule.get(&rid2).len(), 3, "container rule via ancestor");
        assert_lock_invariant(&c, rid2);
    }

    #[test]
    fn detach_removes_locks() {
        let c = catalog();
        c.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        let f1 = file(&c, "f1", 100);
        let f2 = file(&c, "f2", 200);
        c.attach(&ds, &f1).unwrap();
        c.attach(&ds, &f2).unwrap();
        c.add_replica("FR-A", &f1, ReplicaState::Available, None).unwrap();
        c.add_replica("FR-A", &f2, ReplicaState::Available, None).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", ds.clone(), "FR-A", 1)).unwrap();
        assert_eq!(c.get_account_usage("root", "FR-A").bytes, 300);
        c.detach(&ds, &f2).unwrap();
        assert_eq!(c.locks_by_rule.get(&rid).len(), 1);
        assert_eq!(c.get_account_usage("root", "FR-A").bytes, 100);
        // detached file's replica becomes unprotected
        assert!(c.get_replica("FR-A", &f2).unwrap().tombstone.is_some());
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn expired_rules_cleaned() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        let _rid = c
            .add_rule(RuleSpec::new("root", f.clone(), "FR-A", 1).with_lifetime(10_000))
            .unwrap();
        assert_eq!(c.process_expired_rules(10), 0, "not expired yet");
        if let crate::common::clock::Clock::Sim(s) = &c.clock {
            s.advance(20_000);
        }
        assert_eq!(c.process_expired_rules(10), 1);
        assert_eq!(c.rules.len(), 0);
        assert!(c.get_replica("FR-A", &f).unwrap().tombstone.is_some());
    }

    #[test]
    fn purge_replicas_tombstones_immediately() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        let mut spec = RuleSpec::new("root", f.clone(), "FR-A", 1);
        spec.purge_replicas = true;
        let rid = c.add_rule(spec).unwrap();
        c.delete_rule(rid).unwrap();
        let rep = c.get_replica("FR-A", &f).unwrap();
        assert!(rep.tombstone.unwrap() <= c.now(), "purge = no grace");
    }

    #[test]
    fn weighted_selection_prefers_heavy_rse() {
        let c = catalog();
        c.set_rse_attribute("FR-A", "w", "99").unwrap();
        c.set_rse_attribute("FR-B", "w", "1").unwrap();
        let mut fr_a = 0;
        for i in 0..60 {
            let f = file(&c, &format!("wf{i}"), 10);
            let rid = c
                .add_rule(RuleSpec::new("root", f, "country=FR", 1).with_weight("w"))
                .unwrap();
            let lock_key = &c.locks_by_rule.get(&rid)[0];
            if c.locks.get(lock_key).unwrap().rse == "FR-A" {
                fr_a += 1;
            }
        }
        assert!(fr_a > 50, "weight 99:1 should dominate, got {fr_a}/60");
    }

    #[test]
    fn orphan_transfer_arrival_is_cached_not_protected() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        // rule removed while transfer in flight
        c.delete_rule(rid).unwrap();
        // replica stub is gone (never completed); re-arrival registers
        // nothing since the stub was dropped — done handler tolerates it.
        assert!(c.on_transfer_done(req.id).is_err() || c.get_replica("DE-A", &f).is_err());
    }

    #[test]
    fn declare_bad_sticks_covering_locks() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        let rid = c.add_rule(RuleSpec::new("root", f.clone(), "FR-A", 1)).unwrap();
        assert_eq!(c.get_rule(rid).unwrap().state, RuleState::Ok);
        c.declare_bad("FR-A", &f, "bit rot", "ops").unwrap();
        // no rule may sit in OK on a bad replica (system invariant)
        let rule = c.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Stuck);
        assert_eq!(rule.locks_stuck, 1);
        assert_eq!(rule.locks_ok, 0);
        assert_lock_invariant(&c, rid);
    }

    #[test]
    fn deleted_rule_requests_rehomed_or_canceled() {
        // Two rules share one deduplicated transfer; deleting the request's
        // owner re-homes it to the survivor, deleting the last cancels it.
        let c = catalog();
        let f = file(&c, "f1", 1000);
        let r1 = c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let r2 = c.add_rule(RuleSpec::new("alice", f.clone(), "DE-A", 1)).unwrap();
        assert_eq!(c.requests.len(), 1, "deduplicated transfer");
        let req_id = c.requests.scan(|_| true)[0].id;
        assert_eq!(c.requests.get(&req_id).unwrap().rule_id, r1);
        c.delete_rule(r1).unwrap();
        let req = c.requests.get(&req_id).unwrap();
        assert_eq!(req.rule_id, r2, "request re-homed to the surviving rule");
        assert_eq!(req.state, RequestState::Queued);
        c.delete_rule(r2).unwrap();
        let req = c.requests.get(&req_id).unwrap();
        assert_eq!(req.state, RequestState::Failed, "no rule left: canceled");
    }

    #[test]
    fn checksum_failure_marks_source_suspicious() {
        let c = catalog();
        let f = file(&c, "f1", 1000);
        c.add_replica("FR-A", &f, ReplicaState::Available, None).unwrap();
        c.add_rule(RuleSpec::new("root", f.clone(), "DE-A", 1)).unwrap();
        let req = c.requests.scan(|_| true)[0].clone();
        c.requests
            .update(&req.id, c.now(), |r| r.src_rse = Some("FR-A".into()));
        c.on_transfer_failed(req.id, "CHECKSUM mismatch at source").unwrap();
        assert_eq!(
            c.get_replica("FR-A", &f).unwrap().state,
            ReplicaState::Suspicious,
            "corrupt source flagged on first strike"
        );
        // a network error does not blame the source
        c.on_transfer_failed(req.id, "TRANSFER network error").unwrap();
        assert_eq!(c.get_replica("FR-A", &f).unwrap().error_count, 1);
    }

    #[test]
    fn prop_rule_lifecycle_invariants() {
        use crate::common::proptest::forall;
        forall(25, |g| {
            let c = catalog();
            let n_files = g.usize(1, 5);
            c.add_dataset("data18", "ds", "root").unwrap();
            let ds = DidKey::new("data18", "ds");
            let mut files = Vec::new();
            for i in 0..n_files {
                let f = file(&c, &format!("pf{i}"), g.u64(1, 10_000));
                // some files pre-placed
                if g.bool() {
                    let rse = *g.pick(&["FR-A", "FR-B", "DE-A", "DE-B"]);
                    c.add_replica(rse, &f, ReplicaState::Available, None).unwrap();
                }
                c.attach(&ds, &f).unwrap();
                files.push(f);
            }
            let copies = g.usize(1, 3) as u32;
            let expr = *g.pick(&["type=disk", "country=FR|country=DE", "*"]);
            let rid = match c.add_rule(RuleSpec::new("root", ds.clone(), expr, copies)) {
                Ok(r) => r,
                Err(_) => return, // e.g. copies > candidates on '*'? fine
            };
            assert_lock_invariant(&c, rid);
            let rule = c.get_rule(rid).unwrap();
            assert_eq!(
                (rule.locks_ok + rule.locks_replicating + rule.locks_stuck) as usize,
                n_files * copies as usize,
                "locks == copies × files"
            );
            // drive all requests to done or failed
            for req in c.requests.scan(|r| r.state == RequestState::Queued) {
                if g.chance(0.8) {
                    c.on_transfer_done(req.id).unwrap();
                } else {
                    for _ in 0..3 {
                        c.on_transfer_failed(req.id, "x").unwrap();
                    }
                }
            }
            assert_lock_invariant(&c, rid);
            if c.get_rule(rid).unwrap().state == RuleState::Stuck {
                c.repair_rule(rid).unwrap();
                assert_lock_invariant(&c, rid);
            }
            // delete and verify full cleanup
            c.delete_rule(rid).unwrap();
            assert_eq!(c.locks_by_rule.get(&rid).len(), 0);
            assert_eq!(c.get_account_usage("root", "FR-A").bytes, 0);
            assert_eq!(c.get_account_usage("root", "DE-B").bytes, 0);
        });
    }
}
