//! Subscriptions (paper §2.5): standing dataflow policies — "data
//! placement requests for future incoming DIDs". Each subscription
//! carries a `meta-expr` filter ([`crate::core::metaexpr`]) matched
//! against new DIDs; positive matches create the subscribed replication
//! rules on behalf of the owning account, through the bulk rule path.

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};
use crate::db::Row;

use super::metaexpr::MetaExpr;
use super::rules_api::RuleSpec;
use super::types::*;
use super::Catalog;

/// The filter of a subscription (e.g. "all RAW data coming from the
/// detector"): scope + DID-type selection plus a typed `meta-expr` over
/// name and metadata — the same language, planner and evaluator that
/// serve `list_dids`.
#[derive(Debug, Clone, Default)]
pub struct SubscriptionFilter {
    /// Match DIDs in any of these scopes (empty = all scopes).
    pub scopes: Vec<String>,
    /// Restrict to DID types (empty = datasets only, the usual unit).
    pub did_types: Vec<DidType>,
    /// `meta-expr` filter over name glob + typed metadata
    /// (`None` = match everything the scope/type gates admit).
    pub expr: Option<MetaExpr>,
}

impl SubscriptionFilter {
    /// Build a filter from a `meta-expr` string (parse errors surface at
    /// definition time, not match time).
    pub fn with_expr(mut self, expr: &str) -> Result<Self> {
        self.expr = Some(super::metaexpr::parse(expr)?);
        Ok(self)
    }

    pub fn matches(&self, did: &Did) -> bool {
        if !self.scopes.is_empty() && !self.scopes.iter().any(|s| *s == did.key.scope) {
            return false;
        }
        let type_ok = if self.did_types.is_empty() {
            did.did_type == DidType::Dataset
        } else {
            self.did_types.contains(&did.did_type)
        };
        if !type_ok {
            return false;
        }
        self.expr.as_ref().map(|e| e.matches(did)).unwrap_or(true)
    }
}

/// A rule template the subscription instantiates per matching DID.
#[derive(Debug, Clone)]
pub struct SubscriptionRule {
    pub rse_expression: String,
    pub copies: u32,
    pub lifetime_ms: Option<i64>,
    pub activity: String,
}

/// A standing subscription.
#[derive(Debug, Clone)]
pub struct Subscription {
    pub id: u64,
    pub name: String,
    pub account: String,
    pub filter: SubscriptionFilter,
    pub rules: Vec<SubscriptionRule>,
    pub enabled: bool,
    pub created_at: EpochMs,
    /// How many DIDs this subscription has matched (monitoring).
    pub matched: u64,
}

impl Row for Subscription {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl Catalog {
    pub fn add_subscription(
        &self,
        name: &str,
        account: &str,
        filter: SubscriptionFilter,
        rules: Vec<SubscriptionRule>,
    ) -> Result<u64> {
        self.get_account(account)?;
        if rules.is_empty() {
            return Err(RucioError::InvalidValue("subscription needs >= 1 rule".into()));
        }
        // Validate expressions up front (empty is allowed at definition
        // time — RSEs may appear later).
        for r in &rules {
            self.resolve_rse_expression_allow_empty(&r.rse_expression)?;
        }
        let now = self.now();
        let id = self.next_id();
        self.subscriptions.insert(
            Subscription {
                id,
                name: name.to_string(),
                account: account.to_string(),
                filter,
                rules,
                enabled: true,
                created_at: now,
                matched: 0,
            },
            now,
        )?;
        self.metrics.incr("subscriptions.added", 1);
        Ok(id)
    }

    pub fn set_subscription_enabled(&self, id: u64, enabled: bool) -> Result<()> {
        self.subscriptions
            .update(&id, self.now(), |s| s.enabled = enabled)
            .ok_or_else(|| RucioError::SubscriptionNotFound(id.to_string()))?;
        Ok(())
    }

    /// Match a batch of (new) DIDs against all enabled subscriptions and
    /// create the subscribed rules — the transmogrifier work unit ("after
    /// the creation of a DID its metadata is matched with the filter of
    /// all subscriptions", §2.5). Subscriptions are snapshotted once per
    /// batch; each subscription's rules land through the bulk rule path,
    /// falling back to per-rule creation when one member poisons the
    /// batch (e.g. an expression currently resolving empty). Idempotent
    /// per (subscription, did). Returns created rule ids.
    pub fn transmogrify_batch(&self, keys: &[DidKey]) -> Vec<u64> {
        let mut created = Vec::new();
        if keys.is_empty() {
            return created;
        }
        let subs = self.subscriptions.scan(|s| s.enabled);
        if subs.is_empty() {
            return created;
        }
        // Fetch each DID once for the whole subscription sweep; dedup so
        // a key repeated inside one event batch cannot double-match.
        let mut seen = std::collections::BTreeSet::new();
        let dids: Vec<Did> = keys
            .iter()
            .filter(|k| seen.insert((*k).clone()))
            .filter_map(|k| self.dids.get(k))
            .collect();
        // Idempotency data, gathered once per DID instead of once per
        // (subscription × DID): which subscriptions already rule each DID.
        let ruled_by: Vec<std::collections::BTreeSet<u64>> = dids
            .iter()
            .map(|d| {
                self.list_rules_for_did(&d.key)
                    .iter()
                    .filter_map(|r| r.subscription_id)
                    .collect()
            })
            .collect();
        for sub in subs {
            let matched: Vec<&Did> = dids
                .iter()
                .zip(&ruled_by)
                .filter(|(d, ruled)| sub.filter.matches(d) && !ruled.contains(&sub.id))
                .map(|(d, _)| d)
                .collect();
            if matched.is_empty() {
                continue;
            }
            self.subscriptions
                .update(&sub.id, self.now(), |s| s.matched += matched.len() as u64);
            for tpl in &sub.rules {
                let build_spec = |d: &Did| {
                    let mut spec =
                        RuleSpec::new(&sub.account, d.key.clone(), &tpl.rse_expression, tpl.copies)
                            .with_activity(&tpl.activity);
                    if let Some(l) = tpl.lifetime_ms {
                        spec = spec.with_lifetime(l);
                    }
                    spec.subscription_id = Some(sub.id);
                    spec
                };
                let specs: Vec<RuleSpec> = matched.iter().copied().map(build_spec).collect();
                match self.add_rules_bulk(specs) {
                    Ok(ids) => created.extend(ids),
                    Err(_) => {
                        // One bad member rolled the batch back — salvage
                        // the healthy ones individually (specs rebuilt:
                        // the common success path pays no extra clone).
                        for &d in &matched {
                            match self.add_rule(build_spec(d)) {
                                Ok(id) => created.push(id),
                                Err(e) => crate::log_warn!(
                                    "subscription {} rule failed on {}: {e}",
                                    sub.name,
                                    d.key
                                ),
                            }
                        }
                    }
                }
            }
        }
        self.metrics.incr("subscriptions.rules_created", created.len() as u64);
        created
    }

    /// Match one DID against all enabled subscriptions (synchronous
    /// interactive path; the async batch path is the transmogrifier).
    pub fn match_subscriptions(&self, did_key: &DidKey) -> Result<Vec<u64>> {
        self.get_did(did_key)?;
        Ok(self.transmogrify_batch(std::slice::from_ref(did_key)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::metaexpr::parse;
    use crate::core::rse::Rse;
    use crate::core::Catalog;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_scope("data18", "root").unwrap();
        for (name, country) in [("CERN-DISK", "CH"), ("BNL-TAPE", "US"), ("FZK-TAPE", "DE")] {
            let mut rse = Rse::new(name, now).with_attr("country", country);
            if name.ends_with("TAPE") {
                rse = rse.with_tape();
            }
            c.add_rse(rse).unwrap();
        }
        c
    }

    fn raw_filter() -> SubscriptionFilter {
        SubscriptionFilter {
            scopes: vec!["data18".into()],
            did_types: vec![],
            expr: Some(parse("name=raw.* AND datatype=RAW").unwrap()),
        }
    }

    fn tape_rule() -> SubscriptionRule {
        SubscriptionRule {
            rse_expression: "tape".into(),
            copies: 1,
            lifetime_ms: None,
            activity: "T0 Export".into(),
        }
    }

    #[test]
    fn filter_matching_semantics() {
        let c = catalog();
        c.add_dataset("data18", "raw.001", "root").unwrap();
        let key = DidKey::new("data18", "raw.001");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let did = c.get_did(&key).unwrap();
        assert!(raw_filter().matches(&did));

        // wrong scope
        let mut f = raw_filter();
        f.scopes = vec!["mc20".into()];
        assert!(!f.matches(&did));
        // wrong meta
        let f = SubscriptionFilter { scopes: vec!["data18".into()], ..Default::default() }
            .with_expr("datatype=AOD")
            .unwrap();
        assert!(!f.matches(&did));
        // wrong name glob
        let f = SubscriptionFilter { scopes: vec!["data18".into()], ..Default::default() }
            .with_expr("name=aod.*")
            .unwrap();
        assert!(!f.matches(&did));
        // typed predicates reach the engine: run-number window
        c.set_metadata(&key, "run", "358031").unwrap();
        let did = c.get_did(&key).unwrap();
        let f = SubscriptionFilter::default().with_expr("run>=358000 AND run<359000").unwrap();
        assert!(f.matches(&did));
        // files don't match by default (datasets only)
        c.add_file("data18", "raw.file", "root", 1, "x", None).unwrap();
        let fkey = DidKey::new("data18", "raw.file");
        c.set_metadata(&fkey, "datatype", "RAW").unwrap();
        let fdid = c.get_did(&fkey).unwrap();
        assert!(!raw_filter().matches(&fdid));
        // ...unless the filter opts into files
        let mut f = raw_filter();
        f.did_types = vec![DidType::File];
        assert!(f.matches(&fdid));
        // malformed expressions surface at definition time
        assert!(SubscriptionFilter::default().with_expr("datatype=").is_err());
    }

    #[test]
    fn matching_creates_rules_idempotently() {
        let c = catalog();
        c.add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()]).unwrap();
        c.add_dataset("data18", "raw.002", "root").unwrap();
        let key = DidKey::new("data18", "raw.002");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let created = c.match_subscriptions(&key).unwrap();
        assert_eq!(created.len(), 1);
        let rule = c.get_rule(created[0]).unwrap();
        assert_eq!(rule.account, "root");
        assert_eq!(rule.activity, "T0 Export");
        assert!(rule.subscription_id.is_some());
        // Re-matching does not duplicate.
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
    }

    #[test]
    fn batch_matching_sweeps_many_dids_at_once() {
        let c = catalog();
        c.add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()]).unwrap();
        let mut keys = Vec::new();
        for i in 0..6 {
            let name = format!("raw.{i:03}");
            c.add_dataset("data18", &name, "root").unwrap();
            let key = DidKey::new("data18", &name);
            if i % 2 == 0 {
                c.set_metadata(&key, "datatype", "RAW").unwrap();
            }
            keys.push(key);
        }
        // duplicate keys in the batch must not double-match
        keys.push(keys[0].clone());
        let created = c.transmogrify_batch(&keys);
        assert_eq!(created.len(), 3, "only the RAW-tagged half matches");
        let sub = c.subscriptions.scan(|_| true).remove(0);
        assert_eq!(sub.matched, 3);
        // second sweep: idempotent
        assert!(c.transmogrify_batch(&keys).is_empty());
    }

    #[test]
    fn non_matching_did_creates_nothing() {
        let c = catalog();
        c.add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()]).unwrap();
        c.add_dataset("data18", "aod.001", "root").unwrap();
        let key = DidKey::new("data18", "aod.001");
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
    }

    #[test]
    fn disabled_subscription_skipped() {
        let c = catalog();
        let id = c
            .add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()])
            .unwrap();
        c.set_subscription_enabled(id, false).unwrap();
        c.add_dataset("data18", "raw.003", "root").unwrap();
        let key = DidKey::new("data18", "raw.003");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
    }

    #[test]
    fn multiple_rule_templates() {
        let c = catalog();
        let two_rules = vec![
            tape_rule(),
            SubscriptionRule {
                rse_expression: "CERN-DISK".into(),
                copies: 1,
                lifetime_ms: Some(1000),
                activity: "Data Consolidation".into(),
            },
        ];
        c.add_subscription("raw-two", "root", raw_filter(), two_rules).unwrap();
        c.add_dataset("data18", "raw.004", "root").unwrap();
        let key = DidKey::new("data18", "raw.004");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let created = c.match_subscriptions(&key).unwrap();
        assert_eq!(created.len(), 2);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let c = catalog();
        assert!(c.add_subscription("x", "root", raw_filter(), vec![]).is_err());
        let bad_rule = SubscriptionRule {
            rse_expression: "((broken".into(),
            copies: 1,
            lifetime_ms: None,
            activity: "A".into(),
        };
        assert!(c.add_subscription("x", "root", raw_filter(), vec![bad_rule]).is_err());
        assert!(c
            .add_subscription("x", "ghost-account", raw_filter(), vec![tape_rule()])
            .is_err());
    }
}
