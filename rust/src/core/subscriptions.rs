//! Subscriptions (paper §2.5): standing dataflow policies — "data
//! placement requests for future incoming DIDs". A metadata filter is
//! matched against every new DID; positive matches create the subscribed
//! replication rules on behalf of the owning account.

use std::collections::BTreeMap;

use crate::common::clock::EpochMs;
use crate::common::error::{Result, RucioError};
use crate::db::Row;

use super::rules_api::RuleSpec;
use super::types::*;
use super::Catalog;

/// The metadata filter of a subscription (e.g. "all RAW data coming from
/// the detector").
#[derive(Debug, Clone, Default)]
pub struct SubscriptionFilter {
    /// Match DIDs in any of these scopes (empty = all scopes).
    pub scopes: Vec<String>,
    /// Name pattern (regex, matched on the DID name).
    pub name_pattern: Option<String>,
    /// Restrict to DID types (empty = datasets only, the usual unit).
    pub did_types: Vec<DidType>,
    /// Required metadata key → value equalities.
    pub meta: BTreeMap<String, String>,
}

impl SubscriptionFilter {
    pub fn matches(&self, did: &Did) -> bool {
        if !self.scopes.is_empty() && !self.scopes.iter().any(|s| *s == did.key.scope) {
            return false;
        }
        let type_ok = if self.did_types.is_empty() {
            did.did_type == DidType::Dataset
        } else {
            self.did_types.contains(&did.did_type)
        };
        if !type_ok {
            return false;
        }
        if let Some(p) = &self.name_pattern {
            match regex::Regex::new(p) {
                Ok(re) if re.is_match(&did.key.name) => {}
                _ => return false,
            }
        }
        for (k, v) in &self.meta {
            if did.meta.get(k) != Some(v) {
                return false;
            }
        }
        true
    }
}

/// A rule template the subscription instantiates per matching DID.
#[derive(Debug, Clone)]
pub struct SubscriptionRule {
    pub rse_expression: String,
    pub copies: u32,
    pub lifetime_ms: Option<i64>,
    pub activity: String,
}

/// A standing subscription.
#[derive(Debug, Clone)]
pub struct Subscription {
    pub id: u64,
    pub name: String,
    pub account: String,
    pub filter: SubscriptionFilter,
    pub rules: Vec<SubscriptionRule>,
    pub enabled: bool,
    pub created_at: EpochMs,
    /// How many DIDs this subscription has matched (monitoring).
    pub matched: u64,
}

impl Row for Subscription {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl Catalog {
    pub fn add_subscription(
        &self,
        name: &str,
        account: &str,
        filter: SubscriptionFilter,
        rules: Vec<SubscriptionRule>,
    ) -> Result<u64> {
        self.get_account(account)?;
        if rules.is_empty() {
            return Err(RucioError::InvalidValue("subscription needs >= 1 rule".into()));
        }
        // Validate expressions up front (empty is allowed at definition
        // time — RSEs may appear later).
        for r in &rules {
            self.resolve_rse_expression_allow_empty(&r.rse_expression)?;
        }
        let now = self.now();
        let id = self.next_id();
        self.subscriptions.insert(
            Subscription {
                id,
                name: name.to_string(),
                account: account.to_string(),
                filter,
                rules,
                enabled: true,
                created_at: now,
                matched: 0,
            },
            now,
        )?;
        self.metrics.incr("subscriptions.added", 1);
        Ok(id)
    }

    pub fn set_subscription_enabled(&self, id: u64, enabled: bool) -> Result<()> {
        self.subscriptions
            .update(&id, self.now(), |s| s.enabled = enabled)
            .ok_or_else(|| RucioError::SubscriptionNotFound(id.to_string()))?;
        Ok(())
    }

    /// Match a (new) DID against all enabled subscriptions, creating the
    /// subscribed rules ("after the creation of a DID its metadata is
    /// matched with the filter of all subscriptions", §2.5). Returns
    /// created rule ids. Idempotent per (subscription, did): existing
    /// subscription rules on the DID are not duplicated.
    pub fn match_subscriptions(&self, did_key: &DidKey) -> Result<Vec<u64>> {
        let did = self.get_did(did_key)?;
        let mut created = Vec::new();
        for sub in self.subscriptions.scan(|s| s.enabled) {
            if !sub.filter.matches(&did) {
                continue;
            }
            let already = self
                .list_rules_for_did(did_key)
                .iter()
                .any(|r| r.subscription_id == Some(sub.id));
            if already {
                continue;
            }
            self.subscriptions.update(&sub.id, self.now(), |s| s.matched += 1);
            for tpl in &sub.rules {
                let mut spec = RuleSpec::new(&sub.account, did_key.clone(), &tpl.rse_expression, tpl.copies)
                    .with_activity(&tpl.activity);
                if let Some(l) = tpl.lifetime_ms {
                    spec = spec.with_lifetime(l);
                }
                spec.subscription_id = Some(sub.id);
                match self.add_rule(spec) {
                    Ok(rule_id) => created.push(rule_id),
                    Err(e) => {
                        // Don't fail the whole matching sweep on one bad
                        // template (e.g. expression currently empty).
                        crate::log_warn!("subscription {} rule failed on {did_key}: {e}", sub.name);
                    }
                }
            }
        }
        Ok(created)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::Catalog;

    fn catalog() -> Catalog {
        let c = Catalog::new_for_tests();
        let now = c.now();
        c.add_scope("data18", "root").unwrap();
        for (name, country) in [("CERN-DISK", "CH"), ("BNL-TAPE", "US"), ("FZK-TAPE", "DE")] {
            let mut rse = Rse::new(name, now).with_attr("country", country);
            if name.ends_with("TAPE") {
                rse = rse.with_tape();
            }
            c.add_rse(rse).unwrap();
        }
        c
    }

    fn raw_filter() -> SubscriptionFilter {
        SubscriptionFilter {
            scopes: vec!["data18".into()],
            name_pattern: Some("^raw\\.".into()),
            did_types: vec![],
            meta: BTreeMap::from([("datatype".to_string(), "RAW".to_string())]),
        }
    }

    fn tape_rule() -> SubscriptionRule {
        SubscriptionRule {
            rse_expression: "tape".into(),
            copies: 1,
            lifetime_ms: None,
            activity: "T0 Export".into(),
        }
    }

    #[test]
    fn filter_matching_semantics() {
        let c = catalog();
        c.add_dataset("data18", "raw.001", "root").unwrap();
        let key = DidKey::new("data18", "raw.001");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let did = c.get_did(&key).unwrap();
        assert!(raw_filter().matches(&did));

        // wrong scope
        let mut f = raw_filter();
        f.scopes = vec!["mc20".into()];
        assert!(!f.matches(&did));
        // wrong meta
        let mut f = raw_filter();
        f.meta.insert("datatype".into(), "AOD".into());
        assert!(!f.matches(&did));
        // wrong name
        let mut f = raw_filter();
        f.name_pattern = Some("^aod\\.".into());
        assert!(!f.matches(&did));
        // files don't match by default (datasets only)
        c.add_file("data18", "raw.file", "root", 1, "x", None).unwrap();
        let mut fdid = c.get_did(&DidKey::new("data18", "raw.file")).unwrap();
        fdid.meta.insert("datatype".into(), "RAW".into());
        assert!(!raw_filter().matches(&fdid));
    }

    #[test]
    fn matching_creates_rules_idempotently() {
        let c = catalog();
        c.add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()]).unwrap();
        c.add_dataset("data18", "raw.002", "root").unwrap();
        let key = DidKey::new("data18", "raw.002");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let created = c.match_subscriptions(&key).unwrap();
        assert_eq!(created.len(), 1);
        let rule = c.get_rule(created[0]).unwrap();
        assert_eq!(rule.account, "root");
        assert_eq!(rule.activity, "T0 Export");
        assert!(rule.subscription_id.is_some());
        // Re-matching does not duplicate.
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
        assert_eq!(c.subscriptions.get(&created[0].min(u64::MAX)).is_none(), true);
    }

    #[test]
    fn non_matching_did_creates_nothing() {
        let c = catalog();
        c.add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()]).unwrap();
        c.add_dataset("data18", "aod.001", "root").unwrap();
        let key = DidKey::new("data18", "aod.001");
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
    }

    #[test]
    fn disabled_subscription_skipped() {
        let c = catalog();
        let id = c
            .add_subscription("raw-to-tape", "root", raw_filter(), vec![tape_rule()])
            .unwrap();
        c.set_subscription_enabled(id, false).unwrap();
        c.add_dataset("data18", "raw.003", "root").unwrap();
        let key = DidKey::new("data18", "raw.003");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        assert!(c.match_subscriptions(&key).unwrap().is_empty());
    }

    #[test]
    fn multiple_rule_templates() {
        let c = catalog();
        let two_rules = vec![
            tape_rule(),
            SubscriptionRule {
                rse_expression: "CERN-DISK".into(),
                copies: 1,
                lifetime_ms: Some(1000),
                activity: "Data Consolidation".into(),
            },
        ];
        c.add_subscription("raw-two", "root", raw_filter(), two_rules).unwrap();
        c.add_dataset("data18", "raw.004", "root").unwrap();
        let key = DidKey::new("data18", "raw.004");
        c.set_metadata(&key, "datatype", "RAW").unwrap();
        let created = c.match_subscriptions(&key).unwrap();
        assert_eq!(created.len(), 2);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let c = catalog();
        assert!(c.add_subscription("x", "root", raw_filter(), vec![]).is_err());
        let bad_rule = SubscriptionRule {
            rse_expression: "((broken".into(),
            copies: 1,
            lifetime_ms: None,
            activity: "A".into(),
        };
        assert!(c.add_subscription("x", "root", raw_filter(), vec![bad_rule]).is_err());
        assert!(c
            .add_subscription("x", "ghost-account", raw_filter(), vec![tape_rule()])
            .is_err());
    }
}
