//! Core row types: the Rucio schema (paper §2, §3.6) as typed tables.

use std::collections::BTreeMap;

use crate::common::clock::EpochMs;
use crate::db::Row;

use super::metaexpr::MetaValue;

/// A Data IDentifier key: the `(scope, name)` tuple of paper §2.2
/// ("The combination of scope and name must be unique").
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DidKey {
    pub scope: String,
    pub name: String,
}

impl DidKey {
    pub fn new(scope: &str, name: &str) -> Self {
        DidKey { scope: scope.to_string(), name: name.to_string() }
    }
}

impl std::fmt::Display for DidKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.scope, self.name)
    }
}

/// Granularity of a DID (paper §2.2, Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DidType {
    File,
    Dataset,
    Container,
}

impl DidType {
    pub fn as_str(&self) -> &'static str {
        match self {
            DidType::File => "FILE",
            DidType::Dataset => "DATASET",
            DidType::Container => "CONTAINER",
        }
    }

    pub fn is_collection(&self) -> bool {
        !matches!(self, DidType::File)
    }
}

/// File availability (paper §2.2): derived from the replica catalog but
/// materialized for cheap listing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Availability {
    Available,
    Lost,
    Deleted,
}

impl Availability {
    pub fn as_str(&self) -> &'static str {
        match self {
            Availability::Available => "AVAILABLE",
            Availability::Lost => "LOST",
            Availability::Deleted => "DELETED",
        }
    }
}

/// A DID row: file, dataset, or container.
#[derive(Debug, Clone, PartialEq)]
pub struct Did {
    pub key: DidKey,
    pub did_type: DidType,
    /// Owning account.
    pub account: String,
    /// File size (files only; collections aggregate lazily).
    pub bytes: u64,
    /// Adler-32 checksum (files; enforced on access/transfer, §2.2).
    pub adler32: String,
    /// MD5, optionally recorded alongside (§2.2 supports both).
    pub md5: Option<String>,
    /// GUID-style experiment identifier (unique when present).
    pub guid: Option<String>,
    /// Collections: open for content addition (§2.2). Files: always false.
    pub open: bool,
    /// Monotonic collections never shrink (§2.2).
    pub monotonic: bool,
    /// Suppressed DIDs are hidden from default listings (§2.2).
    pub suppressed: bool,
    pub availability: Availability,
    /// Typed metadata (paper §2.2 "experiment-internal metadata"):
    /// string / int / float / bool values, mirrored into the catalog's
    /// per-key inverted index for `meta-expr` discovery queries.
    pub meta: BTreeMap<String, MetaValue>,
    pub created_at: EpochMs,
    /// Lifetime expiry for the DID itself (undertaker input).
    pub expired_at: Option<EpochMs>,
    /// Archive constituents support (§2.2): Some(archive DID) when this
    /// file lives inside a registered archive.
    pub constituent_of: Option<DidKey>,
}

impl Row for Did {
    type Key = DidKey;
    fn key(&self) -> DidKey {
        self.key.clone()
    }
}

/// Parent→child edge in the collection hierarchy (Fig 1).
#[derive(Debug, Clone)]
pub struct Attachment {
    pub parent: DidKey,
    pub child: DidKey,
    pub created_at: EpochMs,
}

impl Row for Attachment {
    type Key = (DidKey, DidKey);
    fn key(&self) -> (DidKey, DidKey) {
        (self.parent.clone(), self.child.clone())
    }
}

/// Tombstoned names: "DIDs are identified forever" (§2.2) — once used, a
/// name may never be reused, even after deletion.
#[derive(Debug, Clone)]
pub struct NameTombstone {
    pub key: DidKey,
    pub deleted_at: EpochMs,
}

impl Row for NameTombstone {
    type Key = DidKey;
    fn key(&self) -> DidKey {
        self.key.clone()
    }
}

/// Replica state on an RSE.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ReplicaState {
    Available,
    /// Being created by a queued/active transfer.
    Copying,
    /// Declared bad (checksum mismatch / repeated failures, §4.4).
    Bad,
    /// Flagged suspicious after download errors; necromancer triages.
    Suspicious,
}

impl ReplicaState {
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplicaState::Available => "AVAILABLE",
            ReplicaState::Copying => "COPYING",
            ReplicaState::Bad => "BAD",
            ReplicaState::Suspicious => "SUSPICIOUS",
        }
    }
}

/// A physical replica (paper §2.4: "file locations are commonly called
/// replicas").
#[derive(Debug, Clone)]
pub struct Replica {
    pub rse: String,
    pub did: DidKey,
    pub bytes: u64,
    pub state: ReplicaState,
    /// Physical file name on storage (lfn2pfn output).
    pub pfn: String,
    /// Number of replica locks protecting this replica. >0 ⇒ undeletable
    /// (§2.5 "replication rules ... protect this data from deletion").
    pub lock_count: u32,
    /// Deletion eligibility marker: set when the last lock is removed
    /// (reaper input; §4.3 "timed markers on such expired entries").
    pub tombstone: Option<EpochMs>,
    /// Last access (traces drive LRU deletion, §4.3).
    pub accessed_at: EpochMs,
    pub created_at: EpochMs,
    /// Error counter feeding suspicious→bad escalation.
    pub error_count: u32,
}

impl Row for Replica {
    type Key = (String, DidKey);
    fn key(&self) -> (String, DidKey) {
        (self.rse.clone(), self.did.clone())
    }
}

/// Replication rule state (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleState {
    Ok,
    Replicating,
    Stuck,
    Suspended,
}

impl RuleState {
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleState::Ok => "OK",
            RuleState::Replicating => "REPLICATING",
            RuleState::Stuck => "STUCK",
            RuleState::Suspended => "SUSPENDED",
        }
    }
}

/// A replication rule (paper §2.5): the central policy object.
#[derive(Debug, Clone)]
pub struct Rule {
    pub id: u64,
    pub account: String,
    pub did: DidKey,
    /// RSE expression (paper §2.5, ref [19]).
    pub rse_expression: String,
    pub copies: u32,
    pub state: RuleState,
    /// Lock tallies (invariant: ok+replicating+stuck == copies × files).
    pub locks_ok: u32,
    pub locks_replicating: u32,
    pub locks_stuck: u32,
    /// Absolute expiry (creation + lifetime), None = forever.
    pub expires_at: Option<EpochMs>,
    /// Optional placement weight attribute name (§2.5).
    pub weight: Option<String>,
    /// Transfer activity tag (Fig 6 accounting + FTS shares).
    pub activity: String,
    pub created_at: EpochMs,
    pub updated_at: EpochMs,
    /// Rebalancing linkage (§6.2: "links the original replication rule
    /// with the newly created one").
    pub child_rule: Option<u64>,
    /// Subscription that spawned this rule, if any.
    pub subscription_id: Option<u64>,
    /// Delete replicas immediately when the rule goes (vs tombstone grace).
    pub purge_replicas: bool,
    /// Repair bookkeeping.
    pub stuck_at: Option<EpochMs>,
}

impl Row for Rule {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

/// Replica lock state mirrors the transfer progress per (rule, file, rse).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LockState {
    Ok,
    Replicating,
    Stuck,
}

/// A replica lock (paper §2.5: "the system internal bookkeeping of these
/// selection decisions are called replica locks").
#[derive(Debug, Clone)]
pub struct ReplicaLock {
    pub rule_id: u64,
    pub rse: String,
    pub did: DidKey,
    pub state: LockState,
    pub bytes: u64,
}

impl Row for ReplicaLock {
    type Key = (u64, String, DidKey);
    fn key(&self) -> (u64, String, DidKey) {
        (self.rule_id, self.rse.clone(), self.did.clone())
    }
}

/// Transfer request lifecycle (paper §4.2 workflow steps 1–4, Fig 6's
/// admission-controlled pipeline).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RequestState {
    /// Admission control: created but not yet released by the throttler
    /// (paper Fig 6 — FTS activity shares arbitrate competing activities
    /// before submission).
    Waiting,
    Queued,
    Submitted,
    Done,
    Failed,
    /// Waiting for a retry slot after a failure (repairer delay).
    Retry,
}

impl RequestState {
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestState::Waiting => "WAITING",
            RequestState::Queued => "QUEUED",
            RequestState::Submitted => "SUBMITTED",
            RequestState::Done => "DONE",
            RequestState::Failed => "FAILED",
            RequestState::Retry => "RETRY",
        }
    }

    pub fn parse(s: &str) -> Option<RequestState> {
        match s {
            "WAITING" => Some(RequestState::Waiting),
            "QUEUED" => Some(RequestState::Queued),
            "SUBMITTED" => Some(RequestState::Submitted),
            "DONE" => Some(RequestState::Done),
            "FAILED" => Some(RequestState::Failed),
            "RETRY" => Some(RequestState::Retry),
            _ => None,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, RequestState::Done | RequestState::Failed)
    }

    /// All states (transition-table exhaustiveness helper).
    pub const ALL: [RequestState; 6] = [
        RequestState::Waiting,
        RequestState::Queued,
        RequestState::Submitted,
        RequestState::Done,
        RequestState::Failed,
        RequestState::Retry,
    ];
}

/// Events driving the request state machine. Every mutation of a
/// request's state goes through [`request_transition`], so the legal
/// lifecycle is written down in exactly one place.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestEvent {
    /// Throttler admission: a Waiting request is released for submission.
    Release,
    /// Conveyor submitter hands the request to FTS.
    Submit,
    /// Terminal success (the destination replica is in place).
    Done,
    /// A recoverable failure: back off and retry.
    FailRetry,
    /// A final failure: attempts exhausted (locks go STUCK).
    FailFinal,
    /// A Retry request's backoff elapsed; back into the queue.
    RetryDue,
    /// An intermediate hop of a multi-hop chain landed; re-queue for the
    /// next hop's submission (no re-admission — the chain was admitted
    /// once).
    HopDone,
    /// Administrative cancel (rule deleted, chain re-planned).
    Cancel,
}

impl RequestEvent {
    /// All events (transition-table exhaustiveness helper).
    pub const ALL: [RequestEvent; 8] = [
        RequestEvent::Release,
        RequestEvent::Submit,
        RequestEvent::Done,
        RequestEvent::FailRetry,
        RequestEvent::FailFinal,
        RequestEvent::RetryDue,
        RequestEvent::HopDone,
        RequestEvent::Cancel,
    ];
}

/// The request state-transition table. Every `(state, event)` pair either
/// yields the successor state or an error — there are no silent no-ops,
/// so a misrouted event (double completion, submit of an unadmitted
/// request, anything on a terminal request) surfaces instead of
/// corrupting tallies.
///
/// `Done`/`FailRetry`/`FailFinal` are accepted from every non-terminal
/// state: completions may arrive for requests the submitter never saw
/// (a replica landed through another channel) and failures are recorded
/// against queued requests too (no source available).
pub fn request_transition(
    state: RequestState,
    event: RequestEvent,
) -> crate::common::error::Result<RequestState> {
    use RequestEvent as E;
    use RequestState as S;
    let next = match (state, event) {
        // admission
        (S::Waiting, E::Release) => Some(S::Queued),
        // submission
        (S::Queued, E::Submit) => Some(S::Submitted),
        // multi-hop: an intermediate hop landed, queue the next one
        (S::Submitted, E::HopDone) => Some(S::Queued),
        // outcomes, legal from any non-terminal state
        (S::Waiting | S::Queued | S::Submitted | S::Retry, E::Done) => Some(S::Done),
        (S::Waiting | S::Queued | S::Submitted | S::Retry, E::FailRetry) => Some(S::Retry),
        (S::Waiting | S::Queued | S::Submitted | S::Retry, E::FailFinal) => Some(S::Failed),
        // retry backoff elapsed
        (S::Retry, E::RetryDue) => Some(S::Queued),
        // administrative cancel of anything still live
        (S::Waiting | S::Queued | S::Submitted | S::Retry, E::Cancel) => Some(S::Failed),
        _ => None,
    };
    next.ok_or_else(|| {
        crate::common::error::RucioError::InvalidValue(format!(
            "illegal request transition: {} + {event:?}",
            state.as_str()
        ))
    })
}

/// Default request priority (1 = lowest urgency, 5 = highest; the FTS
/// simulator starts higher-priority jobs first within a link).
pub const PRIORITY_NORMAL: u8 = 3;
/// Priority applied by `POST /requests/{id}/boost`.
pub const PRIORITY_BOOSTED: u8 = 5;

/// A transfer request created by the rule engine (paper §4.2 step 1).
#[derive(Debug, Clone)]
pub struct TransferRequest {
    pub id: u64,
    pub did: DidKey,
    pub dst_rse: String,
    pub rule_id: u64,
    pub bytes: u64,
    pub adler32: String,
    pub activity: String,
    pub state: RequestState,
    pub attempts: u32,
    /// Scheduling priority (1–5; see [`PRIORITY_NORMAL`]). The FTS
    /// simulator starts higher-priority jobs first on a contended link.
    pub priority: u8,
    /// Multi-hop chain: the full planned route `[src, staging.., dst]`
    /// when no direct source→destination link is usable. `None` for
    /// ordinary direct transfers.
    pub path: Option<Vec<String>>,
    /// Index of the hop currently executing: `path[hop] → path[hop+1]`.
    pub hop: u32,
    /// Chosen source RSE (submitter fills this).
    pub src_rse: Option<String>,
    /// FTS transfer id once submitted.
    pub external_id: Option<u64>,
    /// Which FTS server got it.
    pub fts_server: Option<usize>,
    pub created_at: EpochMs,
    pub updated_at: EpochMs,
    /// Earliest time a Retry request may be re-queued.
    pub retry_after: Option<EpochMs>,
    pub last_error: Option<String>,
}

impl Row for TransferRequest {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

impl TransferRequest {
    /// The `(source, destination)` of the hop currently executing: the
    /// chain hop for multi-hop requests, `None` for direct transfers
    /// (whose source is chosen per submission attempt).
    pub fn current_hop(&self) -> Option<(&str, &str)> {
        let path = self.path.as_ref()?;
        let i = self.hop as usize;
        match (path.get(i), path.get(i + 1)) {
            (Some(a), Some(b)) => Some((a.as_str(), b.as_str())),
            _ => None,
        }
    }

    /// Is the currently executing hop the final leg into `dst_rse`?
    /// Direct transfers are trivially on their final hop.
    pub fn on_final_hop(&self) -> bool {
        match &self.path {
            Some(path) => (self.hop as usize) + 2 >= path.len(),
            None => true,
        }
    }

    /// The staging RSEs of a planned chain (everything strictly between
    /// source and destination).
    pub fn intermediate_rses(&self) -> &[String] {
        match &self.path {
            Some(path) if path.len() > 2 => &path[1..path.len() - 1],
            _ => &[],
        }
    }
}

/// Account type (paper §2.3: individual users, groups, organized
/// activities).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccountType {
    User,
    Group,
    Service,
}

/// The default virtual organisation: single-VO deployments run every
/// account under it (Rucio's convention for the pre-multi-VO world).
pub const DEFAULT_VO: &str = "def";

#[derive(Debug, Clone)]
pub struct Account {
    pub name: String,
    pub account_type: AccountType,
    pub email: String,
    pub created_at: EpochMs,
    /// Suspended accounts cannot authenticate.
    pub suspended: bool,
    /// Admin accounts bypass the default permission policy ("privileged
    /// accounts can circumvent this restriction", §2.3). Admin is scoped
    /// to the account's VO unless the VO is [`DEFAULT_VO`].
    pub admin: bool,
    /// Virtual organisation the account belongs to (multi-VO tenancy,
    /// ESCAPE data-lake deployment model).
    pub vo: String,
}

impl Row for Account {
    type Key = String;
    fn key(&self) -> String {
        self.name.clone()
    }
}

/// Authentication mechanism (paper §4.1).
/// `Hash` because it is part of the `Identity` table key (shard routing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AuthType {
    UserPass,
    X509,
    Gss,
    Ssh,
}

impl AuthType {
    pub fn as_str(&self) -> &'static str {
        match self {
            AuthType::UserPass => "userpass",
            AuthType::X509 => "x509",
            AuthType::Gss => "gss",
            AuthType::Ssh => "ssh",
        }
    }

    pub fn parse(s: &str) -> Option<AuthType> {
        match s {
            "userpass" => Some(AuthType::UserPass),
            "x509" => Some(AuthType::X509),
            "gss" => Some(AuthType::Gss),
            "ssh" => Some(AuthType::Ssh),
            _ => None,
        }
    }
}

/// An identity→account mapping (paper Fig 2: many-to-many).
#[derive(Debug, Clone)]
pub struct Identity {
    /// e.g. DN string, username, SSH key fingerprint, Kerberos principal.
    pub identity: String,
    pub auth_type: AuthType,
    pub account: String,
    /// Secret material for userpass (salted hash) / ssh (public key).
    pub secret: Option<String>,
}

impl Row for Identity {
    type Key = (String, AuthType, String);
    fn key(&self) -> (String, AuthType, String) {
        (self.identity.clone(), self.auth_type, self.account.clone())
    }
}

/// A short-lived auth token (paper §4.1).
#[derive(Debug, Clone)]
pub struct Token {
    pub token: String,
    pub account: String,
    pub expires_at: EpochMs,
    pub issued_at: EpochMs,
    /// VO of the issuing account, pinned at issue time so every later
    /// validation can enforce tenant isolation without a second lookup.
    pub vo: String,
}

impl Row for Token {
    type Key = String;
    fn key(&self) -> String {
        self.token.clone()
    }
}

/// Account quota limit on an RSE expression resolution (paper §2.5:
/// "quotas, which are policy limits which Rucio enforces on accounts").
#[derive(Debug, Clone)]
pub struct AccountLimit {
    pub account: String,
    pub rse: String,
    pub bytes: u64,
}

impl Row for AccountLimit {
    type Key = (String, String);
    fn key(&self) -> (String, String) {
        (self.account.clone(), self.rse.clone())
    }
}

/// Rule-derived account usage per RSE (paper §2.5: "accounts are only
/// charged for the files they actively set replication rules on").
#[derive(Debug, Clone, Default)]
pub struct AccountUsage {
    pub account: String,
    pub rse: String,
    pub bytes: u64,
    pub files: u64,
}

impl Row for AccountUsage {
    type Key = (String, String);
    fn key(&self) -> (String, String) {
        (self.account.clone(), self.rse.clone())
    }
}

/// Outbound hermes message (paper §4.5).
#[derive(Debug, Clone)]
pub struct OutboxMessage {
    pub id: u64,
    pub event_type: String,
    pub payload: crate::jsonx::Json,
    pub created_at: EpochMs,
}

impl Row for OutboxMessage {
    type Key = u64;
    fn key(&self) -> u64 {
        self.id
    }
}

/// Bad-replica triage entry (paper §4.4).
#[derive(Debug, Clone)]
pub struct BadReplica {
    pub rse: String,
    pub did: DidKey,
    pub reason: String,
    pub declared_by: String,
    pub declared_at: EpochMs,
    /// Handled by the necromancer yet?
    pub resolved: bool,
}

impl Row for BadReplica {
    type Key = (String, DidKey);
    fn key(&self) -> (String, DidKey) {
        (self.rse.clone(), self.did.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn did_key_display() {
        let k = DidKey::new("data18", "raw.0001");
        assert_eq!(format!("{k}"), "data18:raw.0001");
    }

    #[test]
    fn did_type_properties() {
        assert!(DidType::Dataset.is_collection());
        assert!(DidType::Container.is_collection());
        assert!(!DidType::File.is_collection());
        assert_eq!(DidType::File.as_str(), "FILE");
    }

    #[test]
    fn auth_type_round_trip() {
        for t in [AuthType::UserPass, AuthType::X509, AuthType::Gss, AuthType::Ssh] {
            assert_eq!(AuthType::parse(t.as_str()), Some(t));
        }
        assert_eq!(AuthType::parse("oidc"), None);
    }

    #[test]
    fn state_strings() {
        assert_eq!(RuleState::Stuck.as_str(), "STUCK");
        assert_eq!(RequestState::Queued.as_str(), "QUEUED");
        assert_eq!(ReplicaState::Suspicious.as_str(), "SUSPICIOUS");
        assert_eq!(Availability::Lost.as_str(), "LOST");
    }

    #[test]
    fn request_state_round_trip() {
        for s in RequestState::ALL {
            assert_eq!(RequestState::parse(s.as_str()), Some(s));
        }
        assert_eq!(RequestState::parse("NOPE"), None);
        assert!(RequestState::Done.is_terminal());
        assert!(RequestState::Failed.is_terminal());
        assert!(!RequestState::Waiting.is_terminal());
    }

    /// Exhaustive check of the full `(state, event)` table: every pair is
    /// either a legal transition to the documented successor or an error.
    /// No silent no-ops: a legal transition never yields its own state
    /// except the documented Retry+FailRetry (a repeated failure while
    /// already backing off re-arms the backoff — a real action, not a
    /// no-op).
    #[test]
    fn request_transition_table_is_exhaustive() {
        use RequestEvent as E;
        use RequestState as S;
        let expect = |s: S, e: E| -> Option<S> {
            match (s, e) {
                (S::Waiting, E::Release) => Some(S::Queued),
                (S::Queued, E::Submit) => Some(S::Submitted),
                (S::Submitted, E::HopDone) => Some(S::Queued),
                (S::Retry, E::RetryDue) => Some(S::Queued),
                (S::Waiting | S::Queued | S::Submitted | S::Retry, E::Done) => Some(S::Done),
                (S::Waiting | S::Queued | S::Submitted | S::Retry, E::FailRetry) => {
                    Some(S::Retry)
                }
                (S::Waiting | S::Queued | S::Submitted | S::Retry, E::FailFinal) => {
                    Some(S::Failed)
                }
                (S::Waiting | S::Queued | S::Submitted | S::Retry, E::Cancel) => {
                    Some(S::Failed)
                }
                _ => None,
            }
        };
        let mut legal = 0;
        let mut illegal = 0;
        for s in RequestState::ALL {
            for e in RequestEvent::ALL {
                match (request_transition(s, e), expect(s, e)) {
                    (Ok(next), Some(want)) => {
                        assert_eq!(next, want, "{s:?} + {e:?}");
                        legal += 1;
                    }
                    (Err(_), None) => illegal += 1,
                    (got, want) => {
                        panic!("{s:?} + {e:?}: got {got:?}, expected {want:?}")
                    }
                }
            }
        }
        assert_eq!(legal + illegal, RequestState::ALL.len() * RequestEvent::ALL.len());
        // terminal states accept nothing
        for s in [S::Done, S::Failed] {
            for e in RequestEvent::ALL {
                assert!(request_transition(s, e).is_err(), "{s:?} must be terminal");
            }
        }
        // the only legal self-transition is Retry + FailRetry
        for s in RequestState::ALL {
            for e in RequestEvent::ALL {
                if let Ok(next) = request_transition(s, e) {
                    if next == s {
                        assert_eq!((s, e), (S::Retry, E::FailRetry), "unexpected no-op");
                    }
                }
            }
        }
    }

    /// Every live state reaches a terminal state, and the happy path
    /// Waiting→Queued→Submitted→Done is exactly three transitions.
    #[test]
    fn request_lifecycle_paths() {
        use RequestEvent as E;
        use RequestState as S;
        let mut s = S::Waiting;
        for e in [E::Release, E::Submit, E::Done] {
            s = request_transition(s, e).unwrap();
        }
        assert_eq!(s, S::Done);
        // retry loop terminates in Failed
        let mut s = S::Queued;
        s = request_transition(s, E::Submit).unwrap();
        s = request_transition(s, E::FailRetry).unwrap();
        s = request_transition(s, E::RetryDue).unwrap();
        s = request_transition(s, E::Submit).unwrap();
        s = request_transition(s, E::FailFinal).unwrap();
        assert_eq!(s, S::Failed);
        // multi-hop: Submitted --HopDone--> Queued --Submit--> Submitted
        let s = request_transition(S::Submitted, E::HopDone).unwrap();
        assert_eq!(request_transition(s, E::Submit).unwrap(), S::Submitted);
    }

    #[test]
    fn transfer_request_hop_helpers() {
        let mut req = TransferRequest {
            id: 1,
            did: DidKey::new("s", "f"),
            dst_rse: "C".into(),
            rule_id: 1,
            bytes: 10,
            adler32: "x".into(),
            activity: "Production".into(),
            state: RequestState::Queued,
            attempts: 0,
            priority: PRIORITY_NORMAL,
            path: Some(vec!["A".into(), "B".into(), "C".into()]),
            hop: 0,
            src_rse: None,
            external_id: None,
            fts_server: None,
            created_at: 0,
            updated_at: 0,
            retry_after: None,
            last_error: None,
        };
        assert_eq!(req.current_hop(), Some(("A", "B")));
        assert!(!req.on_final_hop());
        assert_eq!(req.intermediate_rses(), &["B".to_string()]);
        req.hop = 1;
        assert_eq!(req.current_hop(), Some(("B", "C")));
        assert!(req.on_final_hop());
        req.path = None;
        assert!(req.on_final_hop());
        assert_eq!(req.current_hop(), None);
        assert!(req.intermediate_rses().is_empty());
    }
}

/// A namespace scope (paper §2.2: "the scope thus partitions the global
/// namespace"; §2.3: each account has an associated scope).
#[derive(Debug, Clone)]
pub struct Scope {
    pub name: String,
    pub account: String,
    pub created_at: EpochMs,
    /// VO owning the scope; scope names are globally unique but every
    /// scope belongs to exactly one VO (tenant isolation boundary).
    pub vo: String,
}

impl Row for Scope {
    type Key = String;
    fn key(&self) -> String {
        self.name.clone()
    }
}

/// Access popularity per DID (traces feed this; placement + LRU deletion
/// read it — paper §4.3, §6.1).
#[derive(Debug, Clone)]
pub struct Popularity {
    pub did: DidKey,
    pub accesses: u64,
    pub last_access: EpochMs,
    /// Accesses in the current sliding window (placement signal).
    pub window_accesses: u64,
    pub window_start: EpochMs,
}

impl Row for Popularity {
    type Key = DidKey;
    fn key(&self) -> DidKey {
        self.did.clone()
    }
}

/// Decayed per-DID access heat (paper §6.1: C3PO's demand signal). Fed
/// by the same read-trace path as [`Popularity`], but the score halves
/// every `[heat] half_life` so it tracks *current* demand, while
/// `Popularity.accesses` keeps the lifetime tally. The two are updated
/// together, so `Heat.accesses == Popularity.accesses` is an invariant.
#[derive(Debug, Clone)]
pub struct Heat {
    pub did: DidKey,
    /// Decayed score as of `updated_at`: one unit per read access,
    /// exponentially halved per half-life since then.
    pub score: f64,
    pub updated_at: EpochMs,
    /// Lifetime read accesses folded into this score.
    pub accesses: u64,
}

impl Heat {
    /// The score decayed forward to `now` (pure; does not mutate).
    pub fn score_at(&self, now: EpochMs, half_life_ms: i64) -> f64 {
        decay_score(self.score, self.updated_at, now, half_life_ms)
    }
}

/// Exponential half-life decay of an access score from `then` to `now`.
pub fn decay_score(score: f64, then: EpochMs, now: EpochMs, half_life_ms: i64) -> f64 {
    let dt = (now - then).max(0) as f64;
    let hl = (half_life_ms.max(1)) as f64;
    score * (-dt / hl).exp2()
}

impl Row for Heat {
    type Key = DidKey;
    fn key(&self) -> DidKey {
        self.did.clone()
    }
}
