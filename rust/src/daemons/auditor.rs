//! The auditor — consistency daemon (paper §4.4, Fig 4): compares a
//! storage dump taken at time T against the Rucio catalog at an earlier
//! time T−D and a later time T+D.
//!
//! Classification (Fig 4):
//! * in both catalog lists and the dump → **consistent**;
//! * in both catalog lists, missing from the dump → **lost** (flagged for
//!   the necromancer);
//! * in the dump, in neither catalog list → **dark** (deleted from
//!   storage; "it is important to remove dark files since the accounting
//!   and quota system depend on the correct state of the storage");
//! * anything else → **transient** (in-flight create/delete), ignored.
//!
//! Implementation: each tick snapshots the catalog (the T+D list), audits
//! against the *previous* snapshot (T−D) and a storage dump taken between
//! the two — i.e. T is strictly historical, exactly as the paper requires.

use std::collections::{BTreeMap, BTreeSet};

use crate::common::clock::EpochMs;
use crate::core::types::ReplicaState;

use super::{Ctx, Daemon};

/// Outcome of one RSE audit.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct AuditReport {
    pub consistent: usize,
    pub lost: usize,
    pub dark: usize,
    pub transient: usize,
}

pub struct Auditor {
    pub ctx: Ctx,
    pub instance: String,
    /// (rse → pfn set) snapshot from the previous cycle: the T−D list.
    prev_catalog: BTreeMap<String, BTreeSet<String>>,
    /// Storage dumps taken at the previous cycle: the time-T lists.
    prev_dumps: BTreeMap<String, BTreeSet<String>>,
    pub last_reports: BTreeMap<String, AuditReport>,
}

impl Auditor {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        Auditor {
            ctx,
            instance: instance.to_string(),
            prev_catalog: BTreeMap::new(),
            prev_dumps: BTreeMap::new(),
            last_reports: BTreeMap::new(),
        }
    }

    /// One pass over the replica table → pfn sets for every RSE
    /// (previously one full scan *per RSE*: O(R·N) → O(N); EXPERIMENTS.md
    /// §Perf).
    fn catalog_pfns_all(&self) -> BTreeMap<String, BTreeSet<String>> {
        let mut sets: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        self.ctx.catalog.replicas.for_each(|r| {
            if r.state != ReplicaState::Copying {
                sets.entry(r.rse.clone()).or_default().insert(r.pfn.clone());
            }
        });
        sets
    }
}

impl Daemon for Auditor {
    fn name(&self) -> &'static str {
        "auditor"
    }

    fn interval_ms(&self) -> i64 {
        // Daily in production; the sim driver compresses this.
        3_600_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = self.ctx.catalog.clone();
        let _ = self.ctx.heartbeats.beat("auditor", &self.instance, now);
        let mut processed = 0;

        let mut all_current = self.catalog_pfns_all();
        for rse in cat.list_rses() {
            let name = rse.name.clone();
            let current = all_current.remove(&name).unwrap_or_default(); // T+D list
            let (Some(prev), Some(dump)) =
                (self.prev_catalog.get(&name), self.prev_dumps.get(&name))
            else {
                // First cycle for this RSE: just record the snapshots.
                self.record_snapshots(&name, current);
                continue;
            };

            let mut report = AuditReport::default();
            // Files on storage at T:
            for pfn in dump {
                match (prev.contains(pfn), current.contains(pfn)) {
                    (true, true) => report.consistent += 1,
                    (false, false) => {
                        // DARK: on storage, never in the catalog around T.
                        report.dark += 1;
                        if let Some(sys) = self.ctx.fleet.get(&name) {
                            let _ = sys.delete(pfn);
                        }
                        cat.metrics.incr("auditor.dark_deleted", 1);
                    }
                    _ => report.transient += 1,
                }
            }
            // Catalog files missing from storage at T:
            for pfn in prev.intersection(&current) {
                if !dump.contains(pfn) {
                    report.lost += 1;
                    // Flag for recovery (§4.4: "the lost files are flagged
                    // with a special state for potential recovery").
                    let mut found = None;
                    cat.replicas.for_each(|r| {
                        if r.rse == name && &r.pfn == pfn {
                            found = Some(r.did.clone());
                        }
                    });
                    if let Some(did) = found {
                        let _ = cat.declare_bad(&name, &did, "lost: missing from storage dump", "auditor");
                    }
                    cat.metrics.incr("auditor.lost_flagged", 1);
                }
            }
            processed += report.consistent + report.lost + report.dark + report.transient;
            self.last_reports.insert(name.clone(), report);
            self.record_snapshots(&name, current);
        }
        processed
    }
}

impl Auditor {
    fn record_snapshots(&mut self, rse: &str, current: BTreeSet<String>) {
        // The dump is taken NOW — it becomes "time T" for the next cycle,
        // strictly between this catalog snapshot (T−D) and the next (T+D).
        if let Some(sys) = self.ctx.fleet.get(rse) {
            self.prev_dumps.insert(
                rse.to_string(),
                sys.dump().into_iter().map(|(pfn, _)| pfn).collect(),
            );
        }
        self.prev_catalog.insert(rse.to_string(), current);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::DidKey;
    use crate::daemons::conveyor::tests::{rig, seed_file};

    #[test]
    fn consistent_files_stay_untouched() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        cat.add_rule(crate::core::rules_api::RuleSpec::new("root", f.clone(), "SRC-DISK", 1))
            .unwrap();
        let mut auditor = Auditor::new(ctx.clone(), "a1");
        auditor.tick(cat.now()); // snapshot cycle
        auditor.tick(cat.now());
        let report = &auditor.last_reports["SRC-DISK"];
        assert_eq!(report.consistent, 1);
        assert_eq!(report.lost + report.dark, 0);
    }

    #[test]
    fn dark_files_detected_and_deleted() {
        let (ctx, cat) = rig();
        seed_file(&ctx, "f1", 100);
        let sys = ctx.fleet.get("SRC-DISK").unwrap();
        let mut auditor = Auditor::new(ctx.clone(), "a1");
        auditor.tick(cat.now()); // first snapshot (dump is clean)
        // plant a dark file — it will be in the NEXT dump, not in either
        // catalog snapshot
        sys.plant_dark("/dark/unknown.bin", 500, cat.now());
        auditor.tick(cat.now()); // snapshot including the dark file
        auditor.tick(cat.now()); // audit
        let report = &auditor.last_reports["SRC-DISK"];
        assert_eq!(report.dark, 1);
        assert!(sys.stat("/dark/unknown.bin").is_err(), "dark file removed");
    }

    #[test]
    fn lost_files_flagged_bad() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        cat.add_rule(crate::core::rules_api::RuleSpec::new("root", f.clone(), "SRC-DISK", 1))
            .unwrap();
        let pfn = cat.get_replica("SRC-DISK", &f).unwrap().pfn;
        let mut auditor = Auditor::new(ctx.clone(), "a1");
        auditor.tick(cat.now());
        // file vanishes from storage outside Rucio's control
        ctx.fleet.get("SRC-DISK").unwrap().vanish(&pfn);
        auditor.tick(cat.now()); // dump w/o the file
        auditor.tick(cat.now()); // audit
        let report = &auditor.last_reports["SRC-DISK"];
        assert_eq!(report.lost, 1);
        assert_eq!(
            cat.get_replica("SRC-DISK", &f).unwrap().state,
            ReplicaState::Bad
        );
        assert_eq!(cat.bad_replicas.len(), 1);
        let _ = DidKey::new("x", "y");
    }

    #[test]
    fn transient_files_ignored() {
        let (ctx, cat) = rig();
        let mut auditor = Auditor::new(ctx.clone(), "a1");
        seed_file(&ctx, "old", 100);
        auditor.tick(cat.now());
        // new file created AFTER the first catalog snapshot: appears in
        // dump + current catalog but not prev → transient, untouched.
        let f = seed_file(&ctx, "fresh", 100);
        auditor.tick(cat.now());
        auditor.tick(cat.now());
        let report = &auditor.last_reports["SRC-DISK"];
        assert!(report.dark == 0, "fresh file is not dark: {report:?}");
        assert!(cat.get_replica("SRC-DISK", &f).is_ok());
    }
}
