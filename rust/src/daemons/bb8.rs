//! Fleet-resident rebalancing daemon (paper §6.2): wraps
//! [`crate::rebalance::Bb8`] so background equalization and move
//! finalization run on the driver cadence, and adds the decommission
//! lifecycle: an operator (or the REST `POST /rses/{rse}/decommission`
//! route) flags an RSE with the `decommission` attribute, and this
//! daemon drives it `pending` → `draining` → `done` — first shot drains
//! every movable rule and disables writes, later ticks catch rules that
//! became movable afterwards, and the flag flips to `done` once no lock
//! pins the RSE any more.

use crate::common::clock::EpochMs;
use crate::rebalance::Bb8;

use super::{Ctx, Daemon};

/// `decommission` RSE-attribute states the daemon recognises.
pub const DECOM_PENDING: &str = "pending";
pub const DECOM_DRAINING: &str = "draining";
pub const DECOM_DONE: &str = "done";

pub struct Bb8Daemon {
    inner: Bb8,
    /// Master switch (`[bb8] enabled`).
    pub enabled: bool,
}

impl Bb8Daemon {
    pub fn new(ctx: Ctx) -> Self {
        let enabled = ctx.catalog.cfg.get_bool("bb8", "enabled", true);
        Bb8Daemon { inner: Bb8::new(ctx), enabled }
    }

    /// Advance every flagged RSE one step through the decommission
    /// lifecycle. Returns the number of moves scheduled.
    fn drain_decommissions(&mut self, now: EpochMs) -> usize {
        let cat = self.inner.ctx.catalog.clone();
        let mut scheduled = 0;
        for rse in cat.list_rses() {
            match rse.attr("decommission") {
                Some(DECOM_PENDING) => match self.inner.decommission(&rse.name, now) {
                    Ok(moved) => {
                        let _ = cat.set_rse_attribute(&rse.name, "decommission", DECOM_DRAINING);
                        scheduled += moved;
                    }
                    Err(e) => {
                        crate::log_warn!("bb8: decommission of {} failed: {e}", rse.name)
                    }
                },
                Some(DECOM_DRAINING) => {
                    // stragglers: rules that became movable since the
                    // first pass (replication finished, moves abandoned)
                    scheduled += self.inner.drain_pass(&rse.name, now);
                    let mut locks_left = 0usize;
                    cat.locks.for_each(|l| {
                        if l.rse == rse.name {
                            locks_left += 1;
                        }
                    });
                    if locks_left == 0 {
                        let _ = cat.set_rse_attribute(&rse.name, "decommission", DECOM_DONE);
                        cat.metrics.incr("bb8.decommissions_completed", 1);
                    }
                }
                _ => {}
            }
        }
        scheduled
    }
}

impl Daemon for Bb8Daemon {
    fn name(&self) -> &'static str {
        "bb8"
    }

    fn interval_ms(&self) -> i64 {
        300_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        if !self.enabled {
            return 0;
        }
        // inner tick: day-budget rollover, finalize in-flight moves,
        // budget-gated background equalization over `bb8=true` RSEs
        let inner = self.inner.tick(now);
        inner + self.drain_decommissions(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::RequestState;
    use crate::daemons::conveyor::tests::{rig, seed_file};

    /// Three rules wholly resident on SRC-DISK, each with alternative
    /// destinations (no `bb8=true` attrs: background mode stays off).
    fn resident() -> Ctx {
        let (ctx, cat) = rig();
        for i in 0..3 {
            let f = seed_file(&ctx, &format!("d{i}"), 1000);
            cat.add_rule(RuleSpec::new("root", f, "SRC-DISK|DST-A|DST-B", 1)).unwrap();
        }
        ctx
    }

    fn drive_transfers(ctx: &Ctx) {
        let cat = &ctx.catalog;
        loop {
            let queued = cat.requests_by_state.get(&RequestState::Queued);
            if queued.is_empty() {
                break;
            }
            for id in queued {
                cat.on_transfer_done(id).unwrap();
            }
        }
    }

    #[test]
    fn decommission_lifecycle_pending_draining_done() {
        let ctx = resident();
        let cat = ctx.catalog.clone();
        cat.set_rse_attribute("SRC-DISK", "decommission", DECOM_PENDING).unwrap();
        let mut d = Bb8Daemon::new(ctx.clone());
        let scheduled = d.tick(cat.now());
        assert_eq!(scheduled, 3, "all resident rules scheduled away");
        let rse = cat.get_rse("SRC-DISK").unwrap();
        assert_eq!(rse.attr("decommission"), Some(DECOM_DRAINING));
        assert!(!rse.availability_write, "draining RSE refuses writes");
        // transfers complete → next tick finalizes and flips to done
        drive_transfers(&ctx);
        d.tick(cat.now());
        assert_eq!(
            cat.get_rse("SRC-DISK").unwrap().attr("decommission"),
            Some(DECOM_DONE)
        );
        assert_eq!(cat.metrics.counter("bb8.decommissions_completed"), 1);
        let mut locks_on_src = 0;
        cat.locks.for_each(|l| {
            if l.rse == "SRC-DISK" {
                locks_on_src += 1;
            }
        });
        assert_eq!(locks_on_src, 0);
    }

    #[test]
    fn unflagged_fleet_tick_is_a_no_op() {
        let ctx = resident();
        let cat = ctx.catalog.clone();
        let mut d = Bb8Daemon::new(ctx);
        assert_eq!(d.tick(cat.now()), 0, "no bb8 attrs, no decommission flags");
        assert!(cat.rules.scan(|r| r.activity == "Data Rebalancing").is_empty());
    }

    #[test]
    fn disabled_daemon_ignores_flags() {
        let ctx = resident();
        let cat = ctx.catalog.clone();
        cat.set_rse_attribute("SRC-DISK", "decommission", DECOM_PENDING).unwrap();
        let mut d = Bb8Daemon::new(ctx);
        d.enabled = false;
        assert_eq!(d.tick(cat.now()), 0);
        assert_eq!(
            cat.get_rse("SRC-DISK").unwrap().attr("decommission"),
            Some(DECOM_PENDING)
        );
    }
}
