//! Heat-driven dynamic placement daemon (paper §6.1): the fleet-resident
//! face of [`crate::placement::C3po`]. Where the library module selects
//! by raw popularity-window counts, this daemon consumes the *decayed*
//! per-DID heat table — fed by the tracer's read traces, halving every
//! `[heat] half_life` — so placement follows current demand and lets go
//! of yesterday's crowd. Every replica it creates is a cache: the rule
//! carries a lifetime (reaper reclaims it once the heat passes) and the
//! total bytes pinned by live cache rules are capped by
//! `[c3po] cache_budget_bytes`.

use std::collections::BTreeSet;

use crate::common::clock::EpochMs;
use crate::common::units::TB;
use crate::core::types::DidType;
use crate::core::Catalog;
use crate::placement::{C3po, RefScorer, Scorer, CACHE_ACTIVITY};

use super::{Ctx, Daemon};

/// The standing heat-driven placement daemon.
pub struct HeatC3po {
    inner: C3po,
    /// Decayed heat score at which a dataset becomes placement-eligible
    /// (`[c3po] heat_threshold`).
    pub heat_threshold: f64,
    /// Max total bytes live cache rules may pin
    /// (`[c3po] cache_budget_bytes`).
    pub budget_bytes: u64,
    /// Master switch (`[c3po] enabled`).
    pub enabled: bool,
}

impl HeatC3po {
    pub fn new(ctx: Ctx) -> Self {
        Self::with_scorer(ctx, Box::new(RefScorer))
    }

    pub fn with_scorer(ctx: Ctx, scorer: Box<dyn Scorer>) -> Self {
        let cfg = &ctx.catalog.cfg;
        let heat_threshold = cfg.get_f64("c3po", "heat_threshold", 4.0);
        let budget_bytes = cfg.get_bytes("c3po", "cache_budget_bytes", 20 * TB);
        let enabled = cfg.get_bool("c3po", "enabled", true);
        HeatC3po { inner: C3po::new(ctx, scorer), heat_threshold, budget_bytes, enabled }
    }

    /// Bytes currently pinned by live cache rules (sum of their locks).
    pub fn cache_bytes(cat: &Catalog) -> u64 {
        let mut cache_rules: BTreeSet<u64> = BTreeSet::new();
        cat.rules.for_each(|r| {
            if r.activity == CACHE_ACTIVITY {
                cache_rules.insert(r.id);
            }
        });
        let mut total = 0u64;
        cat.locks.for_each(|l| {
            if cache_rules.contains(&l.rule_id) {
                total += l.bytes;
            }
        });
        total
    }
}

impl Daemon for HeatC3po {
    fn name(&self) -> &'static str {
        "c3po"
    }

    fn interval_ms(&self) -> i64 {
        60_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        if !self.enabled {
            return 0;
        }
        let cat = self.inner.ctx.catalog.clone();
        let mut pinned = Self::cache_bytes(&cat);
        if pinned >= self.budget_bytes {
            cat.metrics.incr("c3po.budget_deferrals", 1);
            return 0;
        }
        // Over-scan relative to per_tick: some of the hottest DIDs are
        // files (heat tracks every read), cooling down, or over budget.
        let scan = self.inner.per_tick.saturating_mul(4).max(8);
        let mut placed = 0;
        for (did, _score) in cat.hottest_dids(now, scan, self.heat_threshold) {
            if placed >= self.inner.per_tick {
                break;
            }
            if self.inner.in_cooldown(&did, now) {
                continue;
            }
            let Ok(d) = cat.get_did(&did) else { continue };
            if d.did_type != DidType::Dataset {
                continue;
            }
            let ds_bytes = cat.did_bytes(&did);
            if pinned.saturating_add(ds_bytes) > self.budget_bytes {
                cat.metrics.incr("c3po.budget_deferrals", 1);
                continue;
            }
            match self.inner.place(&did, now) {
                Ok(Some(_)) => {
                    pinned += ds_bytes;
                    placed += 1;
                }
                Ok(None) => {
                    // replica cap reached or no candidate RSE: cool the
                    // dataset down so it is not rescanned every tick
                    self.inner.mark_cooldown(&did, now);
                }
                Err(e) => crate::log_warn!("c3po: placement failed for {did}: {e}"),
            }
        }
        placed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::types::DidKey;
    use crate::daemons::conveyor::tests::{rig, seed_file};
    use crate::storagesim::{StorageKind, StorageSystem};

    /// A dataset read often enough that its decayed heat clears the
    /// default threshold, plus a spacious candidate RSE.
    fn hot_rig() -> (Ctx, DidKey) {
        let (ctx, cat) = rig();
        let now = cat.now();
        cat.add_rse(Rse::new("BIG-DISK", now).with_attr("site", "BIG-DISK")).unwrap();
        ctx.fleet.add(StorageSystem::new("BIG-DISK", StorageKind::Disk, 1_000_000_000));
        cat.add_dataset("data18", "hot.ds", "root").unwrap();
        let ds = DidKey::new("data18", "hot.ds");
        let f = seed_file(&ctx, "hot.f1", 500);
        cat.attach(&ds, &f).unwrap();
        for _ in 0..6 {
            cat.touch_replica("SRC-DISK", &f);
        }
        (ctx, ds)
    }

    #[test]
    fn hot_dataset_gets_an_expiring_cache_rule() {
        let (ctx, ds) = hot_rig();
        let cat = ctx.catalog.clone();
        assert!(cat.heat_score(&ds, cat.now()) >= 4.0, "rig is hot");
        let mut d = HeatC3po::new(ctx);
        assert_eq!(d.tick(cat.now()), 1);
        let cache: Vec<_> = cat.rules.scan(|r| r.activity == CACHE_ACTIVITY);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache[0].did, ds);
        assert!(cache[0].expires_at.is_some(), "caches always expire");
        assert_eq!(cat.metrics.counter("c3po.placements"), 1);
        // cooldown: the same dataset is not re-placed next tick
        assert_eq!(d.tick(cat.now()), 0);
    }

    #[test]
    fn cold_dataset_is_ignored() {
        let (ctx, cat) = rig();
        cat.add_dataset("data18", "cold.ds", "root").unwrap();
        let ds = DidKey::new("data18", "cold.ds");
        let f = seed_file(&ctx, "cold.f1", 100);
        cat.attach(&ds, &f).unwrap();
        cat.touch_replica("SRC-DISK", &f); // heat 1 < threshold 4
        let mut d = HeatC3po::new(ctx);
        assert_eq!(d.tick(cat.now()), 0);
        assert!(cat.rules.scan(|r| r.activity == CACHE_ACTIVITY).is_empty());
    }

    #[test]
    fn exhausted_budget_defers_placement() {
        let (ctx, _ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let mut d = HeatC3po::new(ctx);
        d.budget_bytes = 0;
        assert_eq!(d.tick(cat.now()), 0);
        assert!(cat.rules.scan(|r| r.activity == CACHE_ACTIVITY).is_empty());
        assert!(cat.metrics.counter("c3po.budget_deferrals") >= 1);
    }

    #[test]
    fn disabled_daemon_is_inert() {
        let (ctx, _ds) = hot_rig();
        let cat = ctx.catalog.clone();
        let mut d = HeatC3po::new(ctx);
        d.enabled = false;
        assert_eq!(d.tick(cat.now()), 0);
    }
}
