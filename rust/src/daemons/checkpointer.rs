//! The checkpointer daemon: periodically snapshots every catalog table
//! through the registry's persistence handles (paper §3.6 — the
//! persistence layer's maintenance job, analogous to a database
//! checkpoint). Each run fences every table's WAL with a barrier
//! record, writes a consistent per-shard snapshot atomically, truncates
//! the log, and refreshes the `MANIFEST` id high-water mark — bounding
//! both recovery time and log growth.
//!
//! Config (`[db]`): `checkpoint_interval` (default 15m) sets the tick
//! cadence; the daemon is a no-op on catalogs without `wal_dir`.

use crate::common::clock::{EpochMs, MINUTE_MS};
use crate::daemons::{Ctx, Daemon};

pub struct Checkpointer {
    ctx: Ctx,
    interval_ms: i64,
}

impl Checkpointer {
    pub fn new(ctx: Ctx) -> Self {
        let interval_ms = ctx
            .catalog
            .cfg
            .get_duration_ms("db", "checkpoint_interval", 15 * MINUTE_MS);
        Checkpointer { ctx, interval_ms }
    }
}

impl Daemon for Checkpointer {
    fn name(&self) -> &'static str {
        "checkpointer"
    }

    /// One checkpoint sweep; returns the number of tables snapshotted.
    fn tick(&mut self, _now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        if !cat.durable() {
            return 0;
        }
        match cat.checkpoint_all() {
            Ok(stats) => {
                let rows: usize = stats.values().map(|s| s.rows).sum();
                cat.metrics.incr("checkpointer.runs", 1);
                cat.metrics.gauge_set("checkpointer.last_rows", rows as u64);
                stats.len()
            }
            Err(e) => {
                crate::log_warn!("checkpointer: {e}");
                cat.metrics.incr("checkpointer.errors", 1);
                0
            }
        }
    }

    fn interval_ms(&self) -> i64 {
        self.interval_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::clock::Clock;
    use crate::common::config::Config;
    use crate::core::Catalog;
    use crate::ftssim::FtsServer;
    use crate::mq::Broker;
    use crate::netsim::Network;
    use crate::storagesim::Fleet;
    use std::sync::Arc;

    fn ctx_with(cfg: Config) -> Ctx {
        let catalog = Arc::new(Catalog::new(Clock::sim_at(1_600_000_000_000), cfg));
        let fleet = Arc::new(Fleet::new());
        let net = Arc::new(Network::new());
        let broker = Broker::new();
        let fts = vec![Arc::new(FtsServer::new(
            "fts1",
            net.clone(),
            fleet.clone(),
            Some(broker.clone()),
        ))];
        Ctx::new(catalog, fleet, net, fts, broker)
    }

    #[test]
    fn noop_without_durability() {
        let mut d = Checkpointer::new(ctx_with(Config::new()));
        assert_eq!(d.tick(0), 0);
    }

    #[test]
    fn checkpoints_every_table_when_durable() {
        let dir = std::env::temp_dir()
            .join(format!("rucio-ckptd-{}", std::process::id()));
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        cfg.set("db", "checkpoint_interval", "5m");
        let ctx = ctx_with(cfg);
        ctx.catalog.add_scope("s", "root").unwrap();
        ctx.catalog.add_file("s", "f", "root", 1, "x", None).unwrap();
        let mut d = Checkpointer::new(ctx.clone());
        assert_eq!(d.interval_ms(), 5 * MINUTE_MS);
        let n = d.tick(0);
        assert!(n >= 19, "all catalog tables checkpointed: {n}");
        assert_eq!(ctx.catalog.metrics.counter("checkpointer.runs"), 1);
        // after a checkpoint, no table has uncheckpointed records
        for (name, s) in ctx.catalog.registry.wal_stats() {
            assert_eq!(s.records_since_checkpoint, 0, "table {name}");
        }
        assert!(dir.join("MANIFEST").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
