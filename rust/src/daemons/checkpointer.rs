//! The checkpointer daemon: the persistence layer's maintenance job
//! (paper §3.6 — analogous to a database checkpoint). Each checkpoint
//! sweep fences every *dirty* table's WAL with a barrier record,
//! rewrites only the dirty shard snapshot files (clean tables are
//! skipped entirely), truncates the logs, and refreshes the `MANIFEST`
//! id high-water mark — bounding both recovery time and log growth.
//! Between checkpoints the daemon compacts WALs that have outgrown
//! `[db] wal_compact_bytes` (folding each log to the last op per key)
//! and, when `[db] memory_budget` puts tables in paged mode, evicts
//! least-recently-used shards to disk every tick so hot-row counts stay
//! under budget.
//!
//! Config (`[db]`): `checkpoint_interval` (default 15m) sets the sweep
//! cadence, `compact_interval` (default 5m) the compaction cadence,
//! `wal_compact_bytes` (default 4MB) the per-table log size that makes
//! compaction worthwhile; the daemon is a no-op on catalogs without
//! `wal_dir`.
//!
//! Metrics: `checkpointer.runs`, `checkpointer.errors` (counted per
//! failed *table*, not per sweep), `checkpointer.skipped_clean`,
//! `checkpointer.compactions`, `checkpointer.evicted_shards`, and the
//! `checkpointer.last_rows` gauge.

use crate::common::clock::{EpochMs, MINUTE_MS};
use crate::daemons::{Ctx, Daemon};

pub struct Checkpointer {
    ctx: Ctx,
    ckpt_interval_ms: i64,
    compact_interval_ms: i64,
    compact_min_bytes: u64,
    last_ckpt: Option<EpochMs>,
}

impl Checkpointer {
    pub fn new(ctx: Ctx) -> Self {
        let cfg = &ctx.catalog.cfg;
        let ckpt_interval_ms = cfg.get_duration_ms("db", "checkpoint_interval", 15 * MINUTE_MS);
        let compact_interval_ms = cfg.get_duration_ms("db", "compact_interval", 5 * MINUTE_MS);
        let compact_min_bytes = cfg.get_bytes("db", "wal_compact_bytes", 4 * 1024 * 1024);
        Checkpointer {
            ctx,
            ckpt_interval_ms,
            compact_interval_ms,
            compact_min_bytes,
            last_ckpt: None,
        }
    }
}

impl Daemon for Checkpointer {
    fn name(&self) -> &'static str {
        "checkpointer"
    }

    /// One maintenance pass. On a checkpoint-due tick (the first tick,
    /// then every `checkpoint_interval`): full sweep — returns the
    /// number of tables snapshotted. Other ticks: WAL compaction —
    /// returns the number of logs compacted. Every tick also enforces
    /// the paged-mode memory budgets.
    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        if !cat.durable() {
            return 0;
        }
        let ckpt_due = self.last_ckpt.is_none_or(|t| now - t >= self.ckpt_interval_ms);
        let mut acted = 0usize;
        if ckpt_due {
            self.last_ckpt = Some(now);
            match cat.checkpoint_sweep() {
                Ok(sweep) => {
                    let rows: usize = sweep.tables.values().map(|s| s.rows).sum();
                    cat.metrics.incr("checkpointer.runs", 1);
                    cat.metrics.gauge_set("checkpointer.last_rows", rows as u64);
                    // One failed table must not hide the others: errors
                    // count per table, and the sweep already visited
                    // every remaining table regardless.
                    cat.metrics.incr("checkpointer.errors", sweep.errors.len() as u64);
                    cat.metrics
                        .incr("checkpointer.skipped_clean", sweep.skipped_clean.len() as u64);
                    acted += sweep.tables.len();
                }
                Err(e) => {
                    crate::log_warn!("checkpointer: {e}");
                    cat.metrics.incr("checkpointer.errors", 1);
                }
            }
        } else {
            let compacted = cat.compact_wals(self.compact_min_bytes);
            cat.metrics.incr("checkpointer.compactions", compacted.len() as u64);
            acted += compacted.len();
        }
        let evicted = cat.enforce_memory_budgets();
        cat.metrics.incr("checkpointer.evicted_shards", evicted as u64);
        acted + evicted
    }

    /// Tick at the faster of the two cadences; `tick` decides which
    /// work is due.
    fn interval_ms(&self) -> i64 {
        self.ckpt_interval_ms.min(self.compact_interval_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::common::clock::Clock;
    use crate::common::config::Config;
    use crate::core::Catalog;
    use crate::ftssim::FtsServer;
    use crate::mq::Broker;
    use crate::netsim::Network;
    use crate::storagesim::Fleet;
    use std::sync::Arc;

    fn ctx_with(cfg: Config) -> Ctx {
        let catalog = Arc::new(Catalog::new(Clock::sim_at(1_600_000_000_000), cfg));
        let fleet = Arc::new(Fleet::new());
        let net = Arc::new(Network::new());
        let broker = Broker::new();
        let fts = vec![Arc::new(FtsServer::new(
            "fts1",
            net.clone(),
            fleet.clone(),
            Some(broker.clone()),
        ))];
        Ctx::new(catalog, fleet, net, fts, broker)
    }

    fn durable_ctx(tag: &str) -> (Ctx, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("rucio-ckptd-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = Config::new();
        cfg.set("db", "wal_dir", dir.to_string_lossy().to_string());
        cfg.set("db", "checkpoint_interval", "5m");
        (ctx_with(cfg), dir)
    }

    #[test]
    fn noop_without_durability() {
        let mut d = Checkpointer::new(ctx_with(Config::new()));
        assert_eq!(d.tick(0), 0);
    }

    #[test]
    fn checkpoints_dirty_tables_when_durable() {
        let (ctx, dir) = durable_ctx("basic");
        ctx.catalog.add_scope("s", "root").unwrap();
        ctx.catalog.add_file("s", "f", "root", 1, "x", None).unwrap();
        let mut d = Checkpointer::new(ctx.clone());
        assert_eq!(d.interval_ms(), 5 * MINUTE_MS);
        let n = d.tick(0);
        assert!(n >= 3, "dirty tables (dids, scopes, accounts, ...) checkpointed: {n}");
        assert_eq!(ctx.catalog.metrics.counter("checkpointer.runs"), 1);
        // after a checkpoint, no table has uncheckpointed records
        for (name, s) in ctx.catalog.registry.wal_stats() {
            assert_eq!(s.records_since_checkpoint, 0, "table {name}");
        }
        assert!(dir.join("MANIFEST").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite regression: an interval with no new commits must not
    /// rewrite every multi-MB snapshot again — clean tables are skipped
    /// and counted, and their snapshot files keep their mtime/content.
    #[test]
    fn clean_tables_are_skipped_not_resnapshotted() {
        let (ctx, dir) = durable_ctx("skip");
        ctx.catalog.add_scope("s", "root").unwrap();
        ctx.catalog.add_file("s", "f", "root", 1, "x", None).unwrap();
        let mut d = Checkpointer::new(ctx.clone());
        let first = d.tick(0);
        assert!(first >= 3, "first sweep snapshots the dirty tables: {first}");
        let skipped_after_first = ctx.catalog.metrics.counter("checkpointer.skipped_clean");
        let dids_snap = dir.join("dids.snap");
        let before = std::fs::read(&dids_snap).unwrap();
        // Second sweep, nothing written in between: every table is clean.
        let second = d.tick(10 * MINUTE_MS);
        assert_eq!(second, 0, "no table snapshotted on a clean sweep");
        assert_eq!(ctx.catalog.metrics.counter("checkpointer.runs"), 2);
        let skipped = ctx.catalog.metrics.counter("checkpointer.skipped_clean");
        assert!(
            skipped >= skipped_after_first + 19,
            "all tables skipped clean on the second sweep: {skipped}"
        );
        assert_eq!(
            std::fs::read(&dids_snap).unwrap(),
            before,
            "clean table's snapshot untouched"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite regression: one failing table must not abort the sweep
    /// — every healthy table still checkpoints, and `checkpointer.errors`
    /// counts the failed *tables*, not the sweep.
    #[test]
    fn failing_table_does_not_abort_the_sweep() {
        use crate::common::error::{Result, RucioError};
        use crate::db::wal::{CheckpointStats, TablePersist, WalStats};

        struct FailingTable(&'static str);
        impl TablePersist for FailingTable {
            fn table_name(&self) -> &'static str {
                self.0
            }
            fn checkpoint(&self) -> Result<CheckpointStats> {
                Err(RucioError::DatabaseError("disk on fire".into()))
            }
            fn wal_stats(&self) -> Option<WalStats> {
                None
            }
            fn needs_checkpoint(&self) -> bool {
                true // always dirty, always fails
            }
        }

        let (ctx, dir) = durable_ctx("errs");
        ctx.catalog.add_scope("s", "root").unwrap();
        ctx.catalog.add_file("s", "f", "root", 1, "x", None).unwrap();
        // Names sort first and last, so failures bracket the real tables
        // — under the old first-`?`-aborts bug the "aaa" failure would
        // have stopped the whole sweep before any real table.
        ctx.catalog.registry.register_persist(Arc::new(FailingTable("aaa_failing")));
        ctx.catalog.registry.register_persist(Arc::new(FailingTable("zzz_failing")));
        let mut d = Checkpointer::new(ctx.clone());
        let n = d.tick(0);
        assert!(n >= 3, "healthy tables still checkpointed: {n}");
        assert_eq!(
            ctx.catalog.metrics.counter("checkpointer.errors"),
            2,
            "one error per failed table"
        );
        assert_eq!(ctx.catalog.metrics.counter("checkpointer.runs"), 1);
        for (name, s) in ctx.catalog.registry.wal_stats() {
            assert_eq!(s.records_since_checkpoint, 0, "table {name} still fenced");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Between checkpoints the daemon compacts oversized WALs: overwrite
    /// churn folds down to the last op per key.
    #[test]
    fn compacts_wals_between_checkpoints() {
        let (ctx, dir) = durable_ctx("compact");
        let now = ctx.catalog.now();
        ctx.catalog.add_rse(crate::core::rse::Rse::new("RSE1", now)).unwrap();
        let mut d = Checkpointer::new(ctx.clone());
        d.tick(0); // first tick checkpoints everything
        // Overwrite churn on one table, below the checkpoint cadence.
        for i in 0..50 {
            ctx.catalog.set_account_limit("root", "RSE1", 1000 + i).unwrap();
        }
        let before = ctx.catalog.registry.wal_stats()["account_limits"].records;
        assert!(before >= 50);
        // Next tick is before the 5m checkpoint interval → compaction
        // pass. Budget threshold: default 4MB is far above this log, so
        // use a Checkpointer with a tiny threshold.
        d.compact_min_bytes = 1;
        let n = d.tick(2 * MINUTE_MS);
        assert!(n >= 1, "at least the churned log compacted: {n}");
        let after = ctx.catalog.registry.wal_stats()["account_limits"].records;
        assert!(after < before, "WAL folded: {before} -> {after}");
        assert!(ctx.catalog.metrics.counter("checkpointer.compactions") >= 1);
        // The folded log still recovers to the final state.
        let cfg = {
            let mut c = Config::new();
            c.set("db", "wal_dir", dir.to_string_lossy().to_string());
            c
        };
        let r = Catalog::open_with(Clock::sim_at(ctx.catalog.now()), cfg).unwrap();
        assert_eq!(r.get_account_limit("root", "RSE1"), Some(1049));
        std::fs::remove_dir_all(&dir).ok();
    }
}
