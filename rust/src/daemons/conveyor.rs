//! The conveyor — transfer orchestration daemons (paper §4.2):
//! * [`Submitter`]: ranks sources, picks protocols, batches submissions
//!   to the transfer tool (FTS);
//! * [`Poller`]: actively polls FTS for terminal transfers;
//! * [`Receiver`]: passively consumes FTS completion events from the
//!   message queue ("most transfers are checked by the transfer-receiver,
//!   as its passive workflow decreases the load on the transfer tool");
//! * the *finisher* step — updating the associated rules — is the
//!   `Catalog::on_transfer_{done,failed}` logic both invoke.
//!
//! Multi-hop routing (transfer orchestration v2): when no ranked source
//! has a usable network link to the destination (offline, partitioned,
//! or catalog-unconnected), the submitter plans the cheapest 2–3-hop
//! route over the topology ([`plan_transfer_path`]), stages COPYING stub
//! replicas at the intermediate RSEs, and chains the per-hop FTS jobs;
//! intermediate arrivals re-queue the request for its next hop
//! (`Catalog::advance_hop`) and the final arrival tombstones the staging
//! copies for the reaper.

use crate::common::clock::EpochMs;
use crate::core::types::{DidKey, ReplicaState, RequestState, TransferRequest};
use crate::core::Catalog;
use crate::db::assigned_to;
use crate::ftssim::{TransferJob, TransferState};
use crate::mq::SubId;
use crate::netsim::Network;

use super::{Ctx, Daemon};

/// A planned transfer route: the RSE chain source→…→destination plus its
/// total distance cost (sum of per-hop catalog rankings).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedPath {
    pub rses: Vec<String>,
    pub cost: u32,
}

/// Is the network between two RSEs' sites usable right now (quality > 0)?
/// Shared with the throttler so admission and submission agree on what a
/// usable link is.
pub(crate) fn link_usable(cat: &Catalog, net: &Network, src_rse: &str, dst_rse: &str) -> bool {
    match (cat.get_rse(src_rse), cat.get_rse(dst_rse)) {
        (Ok(a), Ok(b)) => net.usable(a.site(), b.site()),
        _ => false,
    }
}

/// Cost of one hop when it is usable: requires a catalog connection
/// (distance ranking, `None` = unconnected), a live network link, a
/// readable source side and a writable destination side.
fn hop_cost(ctx: &Ctx, src: &crate::core::rse::Rse, dst: &crate::core::rse::Rse) -> Option<u32> {
    if !src.availability_read || !dst.availability_write {
        return None;
    }
    if !ctx.net.usable(src.site(), dst.site()) {
        return None;
    }
    ctx.catalog.distance(&src.name, &dst.name)
}

fn consider(best: &mut Option<PlannedPath>, rses: Vec<String>, cost: u32) {
    let better = match best {
        None => true,
        Some(b) => {
            cost < b.cost
                || (cost == b.cost && rses.len() < b.rses.len())
                || (cost == b.cost && rses.len() == b.rses.len() && rses < b.rses)
        }
    };
    if better {
        *best = Some(PlannedPath { rses, cost });
    }
}

/// Plan the cheapest route (up to 3 hops) from any available source
/// replica of `did` to `dst_rse`. Every hop must be live ([`hop_cost`]);
/// staging candidates are readable+writable non-tape RSEs. Paths are
/// acyclic by construction (source, intermediates, and destination are
/// pairwise distinct); ties break toward fewer hops, then
/// lexicographically, so planning is deterministic. Returns `None` when
/// no viable route exists.
pub fn plan_transfer_path(ctx: &Ctx, did: &DidKey, dst_rse: &str) -> Option<PlannedPath> {
    let cat = &ctx.catalog;
    let dst = cat.get_rse(dst_rse).ok()?;
    let sources: Vec<crate::core::rse::Rse> = cat
        .available_replicas(did)
        .into_iter()
        .filter(|r| r.rse != dst_rse)
        .filter_map(|r| cat.get_rse(&r.rse).ok())
        .filter(|r| r.availability_read)
        .collect();
    if sources.is_empty() {
        return None;
    }
    let source_names: std::collections::BTreeSet<&str> =
        sources.iter().map(|r| r.name.as_str()).collect();
    let mids: Vec<crate::core::rse::Rse> = cat
        .list_rses()
        .into_iter()
        .filter(|r| r.name != dst_rse && !source_names.contains(r.name.as_str()))
        .filter(|r| r.availability_read && r.availability_write && !r.is_tape && !r.deleted)
        .collect();

    let mut best: Option<PlannedPath> = None;
    // direct + 2-hop
    for s in &sources {
        if let Some(c) = hop_cost(ctx, s, &dst) {
            consider(&mut best, vec![s.name.clone(), dst.name.clone()], c);
        }
        for m in &mids {
            let Some(c1) = hop_cost(ctx, s, m) else { continue };
            if let Some(c2) = hop_cost(ctx, m, &dst) {
                consider(
                    &mut best,
                    vec![s.name.clone(), m.name.clone(), dst.name.clone()],
                    c1 + c2,
                );
            }
        }
    }
    // 3-hop only when it could still beat the best (each hop costs ≥ 1,
    // so a 3-hop route costs ≥ 3)
    if best.as_ref().map(|b| b.cost > 3).unwrap_or(true) {
        for s in &sources {
            for m1 in &mids {
                let Some(c1) = hop_cost(ctx, s, m1) else { continue };
                for m2 in &mids {
                    if m2.name == m1.name {
                        continue;
                    }
                    let Some(c2) = hop_cost(ctx, m1, m2) else { continue };
                    let Some(c3) = hop_cost(ctx, m2, &dst) else { continue };
                    consider(
                        &mut best,
                        vec![
                            s.name.clone(),
                            m1.name.clone(),
                            m2.name.clone(),
                            dst.name.clone(),
                        ],
                        c1 + c2 + c3,
                    );
                }
            }
        }
    }
    best
}

/// Ranks sources and submits queued transfer requests to FTS in bunches.
pub struct Submitter {
    pub ctx: Ctx,
    pub instance: String,
    /// Submission batch size ("submits transfers in bunches").
    pub bulk: usize,
}

impl Submitter {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("conveyor", "bulk", 200) as usize;
        Submitter { ctx, instance: instance.to_string(), bulk }
    }

    /// Pick the FTS server for a request ("if there are multiple FTS
    /// servers available, Rucio is able to orchestrate transfers among
    /// them", §1.3) — stable hash over the destination, restricted to the
    /// servers currently reachable. `None` during a full FTS blackout:
    /// the request stays queued and is submitted once a server returns.
    fn fts_for(&self, req: &TransferRequest) -> Option<usize> {
        let online: Vec<usize> = self
            .ctx
            .fts
            .iter()
            .enumerate()
            .filter(|(_, f)| f.is_online())
            .map(|(i, _)| i)
            .collect();
        match online.len() {
            0 => None,
            1 => Some(online[0]),
            n => Some(online[(crate::db::shard_hash(req.dst_rse.as_bytes()) % n as u64) as usize]),
        }
    }
}

impl Daemon for Submitter {
    fn name(&self) -> &'static str {
        "conveyor-submitter"
    }

    fn interval_ms(&self) -> i64 {
        5_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let (worker, n_workers) = self.ctx.heartbeats.beat("submitter", &self.instance, now);

        // Promote due retries back to the queue in one batched commit
        // (index-driven: O(retries), not O(all requests)).
        cat.promote_due_retries(now);

        // Our shard of the queue.
        let queued: Vec<TransferRequest> = cat
            .requests_by_state
            .get_limit(&RequestState::Queued, self.bulk * n_workers)
            .into_iter()
            .filter(|id| assigned_to(*id, worker, n_workers))
            .take(self.bulk)
            .filter_map(|id| cat.requests.get(&id))
            .collect();

        let mut jobs_per_fts: Vec<Vec<(u64, TransferJob)>> =
            vec![Vec::new(); self.ctx.fts.len().max(1)];
        // (request id, source RSE, fts index) picks, flipped to SUBMITTED
        // in one batched commit after the selection loop.
        let mut picks: Vec<(u64, String, usize)> = Vec::new();
        let mut processed = 0;

        for req in queued {
            processed += 1;
            // Resolve this submission's (source replica, hop destination):
            // an in-progress chain pins both; otherwise rank sources by
            // distance and require a usable network link, falling back to
            // the cheapest multi-hop route when no direct source works.
            let picked = if let Some((hop_src, hop_dst)) =
                req.current_hop().map(|(a, b)| (a.to_string(), b.to_string()))
            {
                match cat.get_replica(&hop_src, &req.did) {
                    Ok(rep) => Some((rep, hop_dst)),
                    Err(_) => {
                        // the landed intermediate vanished (reaper raced
                        // us): abandon the chain, retry re-plans
                        let _ = cat.on_transfer_failed(req.id, "chain source vanished");
                        continue;
                    }
                }
            } else {
                // Source ranking by distance (§4.2 step 2), partition-
                // aware: a ranked source whose link is dead is unusable.
                let direct = cat
                    .ranked_sources(&req.did, &req.dst_rse)
                    .into_iter()
                    .find(|(r, _)| link_usable(cat, &self.ctx.net, &r.rse, &req.dst_rse));
                match direct {
                    Some((rep, _dist)) => Some((rep, req.dst_rse.clone())),
                    None => match plan_transfer_path(&self.ctx, &req.did, &req.dst_rse) {
                        Some(plan) if plan.rses.len() > 2 => {
                            // Record the chain BEFORE staging: if a stub
                            // fails half-way, the failure path sees the
                            // path and winds the created stubs down
                            // instead of leaking them.
                            cat.set_request_path(req.id, plan.rses.clone());
                            let staged = plan.rses[1..plan.rses.len() - 1]
                                .iter()
                                .all(|mid| cat.ensure_staging_stub(mid, &req.did).is_ok());
                            if !staged {
                                let _ = cat.on_transfer_failed(req.id, "staging stub failed");
                                continue;
                            }
                            cat.get_replica(&plan.rses[0], &req.did)
                                .ok()
                                .map(|rep| (rep, plan.rses[1].clone()))
                        }
                        _ => None,
                    },
                }
            };
            let Some((src, hop_dst)) = picked else {
                // No available source and no viable route — count a
                // failure attempt so it retries (the topology may heal)
                // and eventually sticks.
                let _ = cat.on_transfer_failed(req.id, "no source replica available");
                continue;
            };
            let src = &src;
            // Tape sources must be staged first (§1.3: "clients will have
            // to wait for the tape robot").
            if let Ok(src_rse) = cat.get_rse(&src.rse) {
                if src_rse.is_tape {
                    if let Some(sys) = self.ctx.fleet.get(&src.rse) {
                        match sys.stat(&src.pfn) {
                            Ok(f) if !f.staged => {
                                let _ = sys.stage(&src.pfn, now);
                                continue; // stays Queued; submit once staged
                            }
                            Ok(_) => {}
                            Err(_) => {
                                // transient stat error while waiting for the
                                // robot: stay Queued, re-check next tick
                                continue;
                            }
                        }
                    }
                }
            }
            // Protocol matching (§4.2: "selects the matching protocols of
            // source and destination storage based on protocol priorities").
            let (src_site, dst_site) = {
                let s = cat.get_rse(&src.rse).map(|r| r.site().to_string());
                let d = cat.get_rse(&hop_dst).map(|r| r.site().to_string());
                match (s, d) {
                    (Ok(a), Ok(b)) => (a, b),
                    _ => {
                        let _ = cat.on_transfer_failed(req.id, "rse vanished");
                        continue;
                    }
                }
            };
            let dst_pfn = cat
                .get_replica(&hop_dst, &req.did)
                .map(|r| r.pfn)
                .unwrap_or_else(|_| format!("/lost/{}", req.did));
            let Some(fts_idx) = self.fts_for(&req) else {
                continue; // all FTS servers down: stay Queued (backlog)
            };
            jobs_per_fts[fts_idx].push((
                req.id,
                TransferJob {
                    request_id: req.id,
                    src_rse: src.rse.clone(),
                    dst_rse: hop_dst.clone(),
                    src_site,
                    dst_site,
                    src_pfn: src.pfn.clone(),
                    dst_pfn,
                    bytes: req.bytes,
                    adler32: req.adler32.clone(),
                    activity: req.activity.clone(),
                    priority: req.priority,
                },
            ));
            picks.push((req.id, src.rse.clone(), fts_idx));
        }

        // One batched commit flips the whole picked set to SUBMITTED.
        cat.mark_requests_submitted(&picks, now);

        // Bulk submission per FTS server; external ids land in one
        // batched commit per server.
        for (fts_idx, batch) in jobs_per_fts.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let (req_ids, jobs): (Vec<u64>, Vec<TransferJob>) = batch.into_iter().unzip();
            let external = self.ctx.fts[fts_idx].submit(jobs, now);
            let pairs: Vec<(u64, u64)> =
                req_ids.iter().copied().zip(external.iter().copied()).collect();
            cat.record_external_ids(&pairs, now);
            cat.metrics.incr("conveyor.submitted", req_ids.len() as u64);
        }
        processed
    }
}

/// Actively polls FTS for terminal transfers (§4.2 step 3).
pub struct Poller {
    pub ctx: Ctx,
    pub instance: String,
}

impl Poller {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        Poller { ctx, instance: instance.to_string() }
    }
}

impl Daemon for Poller {
    fn name(&self) -> &'static str {
        "conveyor-poller"
    }

    fn interval_ms(&self) -> i64 {
        10_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let (worker, n_workers) = self.ctx.heartbeats.beat("poller", &self.instance, now);
        let submitted: Vec<TransferRequest> = cat
            .requests_by_state
            .get(&RequestState::Submitted)
            .into_iter()
            .filter(|id| assigned_to(*id, worker, n_workers))
            .filter_map(|id| cat.requests.get(&id))
            .collect();
        let mut processed = 0;
        // Group by FTS server for bulk polling.
        for (fts_idx, fts) in self.ctx.fts.iter().enumerate() {
            let ids: Vec<u64> = submitted
                .iter()
                .filter(|r| r.fts_server == Some(fts_idx))
                .filter_map(|r| r.external_id)
                .collect();
            if ids.is_empty() {
                continue;
            }
            for t in fts.poll(&ids) {
                match t.state {
                    TransferState::Done => {
                        // Intermediate hop of a chain → advance it; the
                        // final hop runs the transfer-finisher.
                        let final_hop = cat
                            .requests
                            .get(&t.job.request_id)
                            .map(|r| r.on_final_hop())
                            .unwrap_or(true);
                        if final_hop {
                            let _ = cat.on_transfer_done(t.job.request_id);
                        } else {
                            let _ = cat.advance_hop(t.job.request_id);
                        }
                        processed += 1;
                    }
                    TransferState::Failed => {
                        let reason = t.reason.unwrap_or_else(|| "unknown".into());
                        let _ = cat.on_transfer_failed(t.job.request_id, &reason);
                        processed += 1;
                    }
                    _ => {}
                }
            }
        }
        cat.metrics.gauge_set(
            "conveyor.submitted_queue",
            cat.requests_by_state.count(&RequestState::Submitted) as u64,
        );
        processed
    }
}

/// Passively consumes FTS completion events from the broker (§4.2:
/// preferred over polling).
pub struct Receiver {
    pub ctx: Ctx,
    sub: SubId,
}

impl Receiver {
    pub fn new(ctx: Ctx) -> Self {
        let sub = ctx.broker.subscribe("transfer.fts", None);
        Receiver { ctx, sub }
    }
}

impl Daemon for Receiver {
    fn name(&self) -> &'static str {
        "conveyor-receiver"
    }

    fn interval_ms(&self) -> i64 {
        2_000
    }

    fn tick(&mut self, _now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let mut processed = 0;
        loop {
            let msgs = self.ctx.broker.poll("transfer.fts", self.sub, 500);
            if msgs.is_empty() {
                break;
            }
            for m in &msgs {
                let Some(request_id) = m.payload.opt_u64("request_id") else { continue };
                // Dedup vs poller: only act on still-Submitted requests.
                let Some(req) = cat.requests.get(&request_id) else { continue };
                if req.state != RequestState::Submitted {
                    continue;
                }
                // Stale-event guard: a multi-hop request re-submits with a
                // fresh FTS transfer per hop — an event for an earlier
                // hop's transfer must not finish the current one.
                if m.payload.opt_u64("transfer_id") != req.external_id {
                    continue;
                }
                match m.event_type.as_str() {
                    "transfer-done" => {
                        if req.on_final_hop() {
                            let _ = cat.on_transfer_done(request_id);
                        } else {
                            let _ = cat.advance_hop(request_id);
                        }
                        processed += 1;
                    }
                    "transfer-failed" => {
                        let reason = m.payload.opt_str("reason").unwrap_or("unknown");
                        let _ = cat.on_transfer_failed(request_id, reason);
                        processed += 1;
                    }
                    _ => {}
                }
            }
        }
        processed
    }
}

/// Advance replicas whose destination write happened through FTS into the
/// catalog-visible Available state is handled by on_transfer_done; this
/// helper re-drives any Copying replica whose file actually exists on
/// storage (crash recovery sweep, run rarely).
pub fn reconcile_copying(ctx: &Ctx, limit: usize) -> usize {
    let cat = &ctx.catalog;
    let copying = cat.replicas.scan_limit(limit, |r| r.state == ReplicaState::Copying);
    let mut fixed = 0;
    for rep in copying {
        if let Some(sys) = ctx.fleet.get(&rep.rse) {
            if sys.stat(&rep.pfn).is_ok() && cat.replica_available(&rep.rse, &rep.did).is_ok() {
                fixed += 1;
            }
        }
    }
    fixed
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::core::rse::Rse;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::{DidKey, RuleState};
    use crate::core::Catalog;
    use crate::ftssim::FtsServer;
    use crate::mq::Broker;
    use crate::netsim::{Link, Network};
    use crate::storagesim::{Fleet, StorageKind, StorageSystem};
    use std::sync::Arc;

    /// Full conveyor test rig: catalog + 3 RSEs + network + FTS + broker.
    pub(crate) fn rig() -> (Ctx, Arc<Catalog>) {
        let catalog = Arc::new(Catalog::new_for_tests());
        let now = catalog.now();
        catalog.add_scope("data18", "root").unwrap();
        let fleet = Arc::new(Fleet::new());
        let net = Arc::new(Network::new());
        for name in ["SRC-DISK", "DST-A", "DST-B"] {
            catalog
                .add_rse(Rse::new(name, now).with_attr("site", name).with_attr("type", "disk"))
                .unwrap();
            fleet.add(StorageSystem::new(name, StorageKind::Disk, u64::MAX));
        }
        for a in ["SRC-DISK", "DST-A", "DST-B"] {
            for b in ["SRC-DISK", "DST-A", "DST-B"] {
                if a != b {
                    net.set_link(a, b, Link::new(100_000_000, 5, 1.0));
                }
            }
        }
        let broker = Broker::new();
        let fts = vec![Arc::new(FtsServer::new(
            "fts1",
            net.clone(),
            fleet.clone(),
            Some(broker.clone()),
        ))];
        let ctx = Ctx::new(catalog.clone(), fleet, net, fts, broker);
        (ctx, catalog)
    }

    /// Register a file + physical source replica.
    pub(crate) fn seed_file(ctx: &Ctx, name: &str, bytes: u64) -> DidKey {
        let cat = &ctx.catalog;
        let adler = crate::storagesim::synthetic_adler32_for(name, bytes);
        cat.add_file("data18", name, "root", bytes, &adler, None).unwrap();
        let key = DidKey::new("data18", name);
        let rep = cat
            .add_replica("SRC-DISK", &key, ReplicaState::Available, None)
            .unwrap();
        ctx.fleet
            .get("SRC-DISK")
            .unwrap()
            .put(&rep.pfn, bytes, cat.now())
            .unwrap();
        key
    }

    fn advance(ctx: &Ctx, ms: i64) -> EpochMs {
        // start anything queued at the current instant...
        for fts in &ctx.fts {
            fts.advance(ctx.catalog.now());
        }
        if let crate::common::clock::Clock::Sim(s) = &ctx.catalog.clock {
            s.advance(ms);
        }
        // ...then integrate progress over the window
        let now = ctx.catalog.now();
        for fts in &ctx.fts {
            fts.advance(now);
        }
        now
    }

    #[test]
    fn end_to_end_rule_to_replica_via_poller() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1_000_000);
        let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        let mut poller = Poller::new(ctx.clone(), "poll-1");

        let now = ctx.catalog.now();
        assert_eq!(submitter.tick(now), 1);
        let req = cat.requests.scan(|_| true)[0].clone();
        assert_eq!(req.state, RequestState::Submitted);
        assert_eq!(req.src_rse.as_deref(), Some("SRC-DISK"));
        assert!(req.external_id.is_some());

        // let FTS move the bytes (100 MB/s, 1 MB file)
        let now = advance(&ctx, 5_000);
        poller.tick(now);
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
        // physical file landed
        let dst_pfn = cat.get_replica("DST-A", &f).unwrap().pfn;
        assert!(ctx.fleet.get("DST-A").unwrap().stat(&dst_pfn).is_ok());
    }

    #[test]
    fn receiver_consumes_broker_events() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f2", 1_000_000);
        let rid = cat.add_rule(RuleSpec::new("root", f, "DST-B", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        let mut receiver = Receiver::new(ctx.clone());
        submitter.tick(ctx.catalog.now());
        let now = advance(&ctx, 5_000);
        let n = receiver.tick(now);
        assert_eq!(n, 1);
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
    }

    #[test]
    fn no_source_fails_towards_stuck() {
        let (ctx, cat) = rig();
        // file with no replica anywhere
        cat.add_file("data18", "ghost", "root", 10, "x", None).unwrap();
        let f = DidKey::new("data18", "ghost");
        let rid = cat.add_rule(RuleSpec::new("root", f, "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        for i in 0..5 {
            let now = ctx.catalog.now() + i;
            // clear retry delay quickly
            for req in cat.requests.scan(|_| true) {
                cat.requests.update(&req.id, now, |r| {
                    if r.state == RequestState::Retry {
                        r.retry_after = Some(now);
                    }
                });
            }
            submitter.tick(now);
        }
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Stuck);
    }

    #[test]
    fn tape_source_staged_before_submission() {
        let (ctx, cat) = rig();
        let now = cat.now();
        cat.add_rse(Rse::new("SRC-TAPE", now).with_attr("site", "SRC-TAPE").with_tape())
            .unwrap();
        ctx.fleet
            .add(StorageSystem::new("SRC-TAPE", StorageKind::Tape, u64::MAX));
        let adler = crate::storagesim::synthetic_adler32_for("cold", 1000);
        cat.add_file("data18", "cold", "root", 1000, &adler, None).unwrap();
        let f = DidKey::new("data18", "cold");
        let rep = cat.add_replica("SRC-TAPE", &f, ReplicaState::Available, None).unwrap();
        ctx.fleet.get("SRC-TAPE").unwrap().put(&rep.pfn, 1000, now).unwrap();

        cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        submitter.tick(cat.now());
        // still queued: staging requested, not submitted yet
        let req = cat.requests.scan(|_| true)[0].clone();
        assert_eq!(req.state, RequestState::Queued);
        // let the robot stage (4 min default), tick storages
        if let crate::common::clock::Clock::Sim(s) = &cat.clock {
            s.advance(5 * 60 * 1000);
        }
        ctx.fleet.tick(cat.now());
        submitter.tick(cat.now());
        let req = cat.requests.scan(|_| true)[0].clone();
        assert_eq!(req.state, RequestState::Submitted, "staged tape submits");
    }

    #[test]
    fn no_direct_link_routes_via_staging_hop() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "mh1", 1_000_000);
        // sever SRC-DISK → DST-A in the catalog (ranking 0 = unconnected);
        // SRC-DISK → DST-B → DST-A stays alive
        cat.set_distance("SRC-DISK", "DST-A", 0).unwrap();
        let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        let mut poller = Poller::new(ctx.clone(), "poll-1");

        submitter.tick(cat.now());
        let req = cat.requests.scan(|_| true)[0].clone();
        assert_eq!(req.state, RequestState::Submitted);
        assert_eq!(
            req.path,
            Some(vec!["SRC-DISK".into(), "DST-B".into(), "DST-A".into()]),
            "cheapest viable chain planned"
        );
        // staging stub created at the intermediate
        assert_eq!(cat.get_replica("DST-B", &f).unwrap().state, ReplicaState::Copying);

        // hop 1 lands: the intermediate becomes available, the request
        // re-queues for hop 2 (no re-admission)
        let now = advance(&ctx, 5_000);
        poller.tick(now);
        let mid = cat.requests.get(&req.id).unwrap();
        assert_eq!(mid.state, RequestState::Queued);
        assert_eq!(mid.hop, 1);
        assert_eq!(cat.get_replica("DST-B", &f).unwrap().state, ReplicaState::Available);
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Replicating);

        // hop 2 completes the rule; the intermediate is tombstoned
        submitter.tick(now);
        let now = advance(&ctx, 5_000);
        poller.tick(now);
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
        assert_eq!(cat.get_replica("DST-A", &f).unwrap().state, ReplicaState::Available);
        let staged = cat.get_replica("DST-B", &f).unwrap();
        assert!(staged.tombstone.is_some(), "intermediate is reaper-collectable");
        assert_eq!(staged.lock_count, 0);
        // physical file landed at the destination
        let dst_pfn = cat.get_replica("DST-A", &f).unwrap().pfn;
        assert!(ctx.fleet.get("DST-A").unwrap().stat(&dst_pfn).is_ok());
        // nothing structurally broken
        assert_eq!(crate::sim::invariants::check(&cat), Vec::new());
    }

    #[test]
    fn netsim_partition_triggers_multihop() {
        // The catalog says SRC→DST-A is connected, but the network is
        // partitioned: the submitter must not burn retries on the dead
        // link and instead route via DST-B.
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "mh2", 1_000);
        ctx.net
            .set_fault_bidir("SRC-DISK", "DST-A", crate::netsim::LinkFault::partition());
        cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        submitter.tick(cat.now());
        let req = cat.requests.scan(|_| true)[0].clone();
        assert_eq!(req.state, RequestState::Submitted);
        assert_eq!(req.src_rse.as_deref(), Some("SRC-DISK"));
        assert_eq!(
            req.path,
            Some(vec!["SRC-DISK".into(), "DST-B".into(), "DST-A".into()])
        );
    }

    #[test]
    fn deleted_rule_cancels_inflight_chain() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "mh3", 1_000_000);
        cat.set_distance("SRC-DISK", "DST-A", 0).unwrap();
        let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let mut submitter = Submitter::new(ctx.clone(), "sub-1");
        submitter.tick(cat.now());
        let req = cat.requests.scan(|_| true)[0].clone();
        assert!(req.path.is_some());
        // rule removed while hop 1 is in flight: request canceled and the
        // never-landed staging stub dropped
        cat.delete_rule(rid).unwrap();
        let req = cat.requests.get(&req.id).unwrap();
        assert_eq!(req.state, RequestState::Failed);
        assert!(req.path.is_none());
        assert!(cat.get_replica("DST-B", &f).is_err(), "stub dropped");
        assert_eq!(crate::sim::invariants::check(&cat), Vec::new());
    }

    /// Planner properties over random topologies: paths are acyclic,
    /// every hop is live (catalog-connected, network-usable, readable
    /// source / writable destination), and the planned cost never
    /// exceeds any viable direct alternative.
    #[test]
    fn prop_planned_paths_are_acyclic_live_and_no_worse_than_direct() {
        use crate::common::proptest::forall;
        use crate::core::rse::Rse;
        forall(40, |g| {
            let (ctx, cat) = rig();
            let now = cat.now();
            // a handful of extra RSEs beyond the rig's three
            let extra = g.usize(1, 5);
            let mut all: Vec<String> =
                vec!["SRC-DISK".into(), "DST-A".into(), "DST-B".into()];
            for i in 0..extra {
                let name = format!("X{i}");
                cat.add_rse(Rse::new(&name, now).with_attr("site", &name)).unwrap();
                ctx.fleet.add(crate::storagesim::StorageSystem::new(
                    &name,
                    crate::storagesim::StorageKind::Disk,
                    u64::MAX,
                ));
                all.push(name);
            }
            // random connectivity: catalog rankings 0–3, some partitions
            for a in all.clone() {
                for b in all.clone() {
                    if a == b {
                        continue;
                    }
                    cat.set_distance(&a, &b, g.u64(0, 4) as u32).unwrap();
                    if g.chance(0.2) {
                        ctx.net.set_fault(&a, &b, crate::netsim::LinkFault::partition());
                    }
                }
            }
            // random read/write availability on the extras
            for name in &all[3..] {
                let _ = cat.set_rse_availability(name, g.bool(), g.bool(), true);
            }
            // the file lives on 1–2 random RSEs (never the destination)
            let f = seed_file(&ctx, &format!("pp{}", g.case_index), 1_000);
            if g.bool() {
                let src2 = all[g.usize(0, all.len())].clone();
                if src2 != "DST-A" && src2 != "SRC-DISK" {
                    let _ = cat.add_replica(&src2, &f, ReplicaState::Available, None);
                }
            }
            let Some(plan) = plan_transfer_path(&ctx, &f, "DST-A") else { return };

            // acyclic: all RSEs on the path are distinct
            let mut seen = std::collections::BTreeSet::new();
            assert!(
                plan.rses.iter().all(|r| seen.insert(r.clone())),
                "cycle in {:?}",
                plan.rses
            );
            assert!(plan.rses.len() >= 2 && plan.rses.len() <= 4);
            assert_eq!(plan.rses.last().unwrap(), "DST-A");

            // every hop is live, and the summed cost matches
            let mut total = 0;
            for w in plan.rses.windows(2) {
                let a = cat.get_rse(&w[0]).unwrap();
                let b = cat.get_rse(&w[1]).unwrap();
                let c = super::hop_cost(&ctx, &a, &b);
                assert!(c.is_some(), "dead hop {:?} in {:?}", w, plan.rses);
                assert!(a.availability_read && b.availability_write);
                assert!(ctx.net.usable(a.site(), b.site()));
                total += c.unwrap();
            }
            assert_eq!(total, plan.cost);

            // cost ≤ every viable direct alternative
            let dst = cat.get_rse("DST-A").unwrap();
            for rep in cat.available_replicas(&f) {
                if rep.rse == "DST-A" {
                    continue;
                }
                let Ok(src) = cat.get_rse(&rep.rse) else { continue };
                if let Some(direct) = super::hop_cost(&ctx, &src, &dst) {
                    assert!(
                        plan.cost <= direct,
                        "plan {:?} (cost {}) beats direct {} (cost {direct})",
                        plan.rses,
                        plan.cost,
                        rep.rse
                    );
                }
            }
        });
    }

    #[test]
    fn sharding_splits_queue_between_instances() {
        let (ctx, cat) = rig();
        for i in 0..20 {
            let f = seed_file(&ctx, &format!("s{i}"), 1000);
            cat.add_rule(RuleSpec::new("root", f, "DST-A", 1)).unwrap();
        }
        let mut sub_a = Submitter::new(ctx.clone(), "a");
        let mut sub_b = Submitter::new(ctx.clone(), "b");
        let now = cat.now();
        // register both heartbeats first so they see each other
        ctx.heartbeats.beat("submitter", "a", now);
        ctx.heartbeats.beat("submitter", "b", now);
        let a = sub_a.tick(now);
        let b = sub_b.tick(now);
        assert_eq!(a + b, 20, "all requests handled once: {a}+{b}");
        assert!(a > 0 && b > 0, "both shards get work: {a}/{b}");
    }
}
