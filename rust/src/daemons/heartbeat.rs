//! Heartbeat-based work partitioning (paper §3.4: "the daemons use a
//! heartbeat system for workload partitioning and automatic failover ...
//! automatic redistribution of the workload in case of a daemon crashing
//! resulting in a lost heartbeat, but also ... when more daemons are
//! started").

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::common::clock::EpochMs;

/// Default heartbeat expiry: instances silent longer than this are
/// considered dead and their shard is redistributed.
pub const DEFAULT_TTL_MS: i64 = 60_000;

#[derive(Default)]
struct Inner {
    /// (daemon_type, instance) → last beat.
    beats: BTreeMap<(String, String), EpochMs>,
}

/// The heartbeat registry (one per deployment; in the upstream system
/// this is a database table).
#[derive(Default)]
pub struct Heartbeats {
    inner: Mutex<Inner>,
    ttl_ms: i64,
}

impl Heartbeats {
    pub fn new() -> Self {
        Heartbeats { inner: Mutex::new(Inner::default()), ttl_ms: DEFAULT_TTL_MS }
    }

    pub fn with_ttl(ttl_ms: i64) -> Self {
        Heartbeats { inner: Mutex::new(Inner::default()), ttl_ms }
    }

    /// Record a beat and return this instance's `(index, live_count)`
    /// assignment among live instances of its type. Index assignment is
    /// by sorted instance name, so all instances agree without
    /// coordination (§3.6: "all daemons of the same type select on the
    /// hashes to guarantee among each other not to work on the same
    /// requests").
    pub fn beat(&self, daemon_type: &str, instance: &str, now: EpochMs) -> (usize, usize) {
        let mut inner = self.inner.lock().unwrap();
        inner
            .beats
            .insert((daemon_type.to_string(), instance.to_string()), now);
        // Expire the dead.
        let ttl = self.ttl_ms;
        inner.beats.retain(|_, last| now - *last <= ttl);
        let live: Vec<&String> = inner
            .beats
            .keys()
            .filter(|(t, _)| t == daemon_type)
            .map(|(_, i)| i)
            .collect();
        let idx = live.iter().position(|i| *i == instance).unwrap_or(0);
        (idx, live.len().max(1))
    }

    /// Drop every beat older than the TTL (the driver's housekeeping
    /// tick calls this; [`Heartbeats::beat`] also prunes lazily, so this
    /// only matters for daemon types whose every instance went silent).
    pub fn expire_dead(&self, now: EpochMs) {
        let ttl = self.ttl_ms;
        self.inner
            .lock()
            .unwrap()
            .beats
            .retain(|_, last| now - *last <= ttl);
    }

    /// Live instances of a type.
    pub fn live(&self, daemon_type: &str, now: EpochMs) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .beats
            .iter()
            .filter(|((t, _), last)| t == daemon_type && now - **last <= self.ttl_ms)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::assigned_to;

    #[test]
    fn single_instance_owns_all() {
        let h = Heartbeats::new();
        let (idx, n) = h.beat("reaper", "reaper-1", 0);
        assert_eq!((idx, n), (0, 1));
    }

    #[test]
    fn instances_split_work_disjointly() {
        let h = Heartbeats::new();
        let (i1, n1) = h.beat("conveyor", "a", 0);
        let (i2, n2) = h.beat("conveyor", "b", 0);
        let (i1b, n1b) = h.beat("conveyor", "a", 1);
        assert_eq!(n2, 2);
        assert_eq!(n1b, 2);
        assert_ne!(i1b, i2);
        let _ = (i1, n1);
        // all keys are covered exactly once between the two
        for key in 0..500u64 {
            let owners = [i1b, i2]
                .iter()
                .filter(|&&w| assigned_to(key, w, 2))
                .count();
            assert_eq!(owners, 1);
        }
    }

    #[test]
    fn dead_instance_failover() {
        let h = Heartbeats::with_ttl(1000);
        h.beat("judge", "a", 0);
        h.beat("judge", "b", 0);
        assert_eq!(h.live("judge", 500), 2);
        // "a" stops beating; after TTL the survivor owns everything.
        let (_, n) = h.beat("judge", "b", 2000);
        assert_eq!(n, 1);
        assert_eq!(h.live("judge", 2000), 1);
    }

    #[test]
    fn expire_dead_prunes_silent_instances() {
        let h = Heartbeats::with_ttl(1000);
        h.beat("reaper", "a", 0);
        h.beat("judge", "b", 0);
        h.expire_dead(500);
        assert_eq!(h.live("reaper", 500), 1);
        h.expire_dead(2000);
        assert_eq!(h.live("reaper", 2000), 0);
        assert_eq!(h.live("judge", 2000), 0);
    }

    #[test]
    fn types_are_independent() {
        let h = Heartbeats::new();
        h.beat("reaper", "x", 0);
        let (_, n) = h.beat("judge", "y", 0);
        assert_eq!(n, 1);
    }
}
