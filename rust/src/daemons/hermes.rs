//! Hermes — the messaging daemon (paper §4.5): drains the outbox table
//! and delivers events to the STOMP-compatible broker topic
//! `rucio.events`, plus an email sink for messages addressed to users.

use crate::common::clock::EpochMs;
use crate::mq::Message;

use super::{Ctx, Daemon};

pub struct Hermes {
    pub ctx: Ctx,
    pub bulk: usize,
    /// "Emails" delivered (necromancer lost-data notifications etc.).
    pub emails_sent: u64,
}

impl Hermes {
    pub fn new(ctx: Ctx) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("hermes", "bulk", 1000) as usize;
        Hermes { ctx, bulk, emails_sent: 0 }
    }
}

impl Daemon for Hermes {
    fn name(&self) -> &'static str {
        "hermes"
    }

    fn interval_ms(&self) -> i64 {
        5_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let batch = cat.outbox.scan_limit(self.bulk, |_| true);
        let n = batch.len();
        for msg in &batch {
            // Email events go to the mail sink, everything to the broker.
            if msg.event_type.starts_with("email-") {
                self.emails_sent += 1;
            }
            self.ctx.broker.publish(
                "rucio.events",
                Message::new(&msg.event_type, msg.payload.clone(), now),
            );
        }
        // Drain the delivered slice of the outbox in one batched commit.
        let ids: Vec<u64> = batch.iter().map(|m| m.id).collect();
        cat.outbox.remove_bulk(&ids, now);
        cat.metrics.incr("hermes.delivered", n as u64);
        cat.metrics.gauge_set("hermes.outbox_depth", cat.outbox.len() as u64);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemons::conveyor::tests::rig;
    use crate::jsonx::Json;

    #[test]
    fn outbox_drained_to_broker() {
        let (ctx, cat) = rig();
        let sub = ctx.broker.subscribe("rucio.events", None);
        cat.notify("rule-ok", Json::obj().with("rule_id", 1));
        cat.notify("email-lost-data", Json::obj().with("account", "alice"));
        let mut hermes = Hermes::new(ctx.clone());
        let n = hermes.tick(cat.now());
        assert_eq!(n, 2);
        assert_eq!(cat.outbox.len(), 0);
        assert_eq!(hermes.emails_sent, 1);
        let msgs = ctx.broker.poll("rucio.events", sub, 10);
        assert_eq!(msgs.len(), 2);
    }

    #[test]
    fn event_type_filtering_for_listeners() {
        let (ctx, cat) = rig();
        // §4.5: "the event-type can be used by queue listeners to filter"
        let only_deletions = ctx.broker.subscribe("rucio.events", Some("deletion-done"));
        cat.notify("rule-ok", Json::obj());
        cat.notify("deletion-done", Json::obj().with("rse", "X"));
        Hermes::new(ctx.clone()).tick(cat.now());
        let msgs = ctx.broker.poll("rucio.events", only_deletions, 10);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].event_type, "deletion-done");
    }
}
