//! The judge family (paper §3.4/§4.2): rule lifecycle daemons.
//! * [`Cleaner`] — removes expired rules;
//! * [`Repairer`] — re-evaluates STUCK rules ("rule evaluators, which
//!   automatically re-evaluate replication rules which are stuck due to
//!   repeated transfer errors");
//! * [`Undertaker`] — removes expired DIDs.
//!
//! Subscription matching (the upstream transmogrifier) lives in
//! [`crate::daemons::transmogrifier`] — it drains `did-created` events in
//! batches through the metadata query engine.

use crate::common::clock::EpochMs;
use crate::core::types::RuleState;
use crate::db::assigned_to;

use super::{Ctx, Daemon};

/// Removes rules whose lifetime expired (§4.3).
pub struct Cleaner {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
}

impl Cleaner {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("judge", "bulk", 500) as usize;
        Cleaner { ctx, instance: instance.to_string(), bulk }
    }
}

impl Daemon for Cleaner {
    fn name(&self) -> &'static str {
        "judge-cleaner"
    }

    fn interval_ms(&self) -> i64 {
        30_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let _ = self.ctx.heartbeats.beat("judge-cleaner", &self.instance, now);
        // Work queue comes off the expiry index; each rule's locks are
        // released through the batched delete path (one commit per rule).
        self.ctx.catalog.process_expired_rules(self.bulk)
    }
}

/// Repairs STUCK rules after a cool-down (§4.2: "stuck rules are
/// continuously read by the rule-repairer").
pub struct Repairer {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
}

impl Repairer {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("judge", "bulk", 500) as usize;
        Repairer { ctx, instance: instance.to_string(), bulk }
    }
}

impl Daemon for Repairer {
    fn name(&self) -> &'static str {
        "judge-repairer"
    }

    fn interval_ms(&self) -> i64 {
        60_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let (worker, n_workers) = self.ctx.heartbeats.beat("judge-repairer", &self.instance, now);
        let cooldown = cat.cfg.get_duration_ms("judge", "repair_cooldown", 120_000);
        let stuck = cat.rules_by_state.get_limit(&RuleState::Stuck, self.bulk);
        let mut repaired = 0;
        for rule_id in stuck {
            if !assigned_to(rule_id, worker, n_workers) {
                continue;
            }
            let Some(rule) = cat.rules.get(&rule_id) else { continue };
            if rule.stuck_at.map(|t| now - t < cooldown).unwrap_or(false) {
                continue;
            }
            if cat.repair_rule(rule_id).is_ok() {
                repaired += 1;
            }
        }
        cat.metrics
            .gauge_set("judge.stuck_rules", cat.rules_by_state.count(&RuleState::Stuck) as u64);
        repaired
    }
}

/// Removes expired DIDs: their rules are deleted, then the DID is erased
/// (the upstream undertaker).
pub struct Undertaker {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
}

impl Undertaker {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("undertaker", "bulk", 200) as usize;
        Undertaker { ctx, instance: instance.to_string(), bulk }
    }
}

impl Daemon for Undertaker {
    fn name(&self) -> &'static str {
        "undertaker"
    }

    fn interval_ms(&self) -> i64 {
        60_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let _ = self.ctx.heartbeats.beat("undertaker", &self.instance, now);
        let expired = cat.dids_by_expiry.range_limit(&i64::MIN, &now, self.bulk);
        let mut erased = 0;
        for key in expired {
            // Remove covering rules first, then the DID itself.
            for rule in cat.list_rules_for_did(&key) {
                let _ = cat.delete_rule(rule.id);
            }
            if cat.erase_did(&key).is_ok() {
                erased += 1;
            }
        }
        erased
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::{DidKey, ReplicaState, RequestState};
    use crate::daemons::conveyor::tests::{rig, seed_file};

    fn advance(ctx: &Ctx, ms: i64) -> EpochMs {
        if let crate::common::clock::Clock::Sim(s) = &ctx.catalog.clock {
            s.advance(ms);
        }
        ctx.catalog.now()
    }

    #[test]
    fn cleaner_removes_expired_rules() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        cat.add_rule(RuleSpec::new("root", f, "SRC-DISK", 1).with_lifetime(10_000)).unwrap();
        let mut cleaner = Cleaner::new(ctx.clone(), "c1");
        assert_eq!(cleaner.tick(cat.now()), 0);
        let now = advance(&ctx, 20_000);
        assert_eq!(cleaner.tick(now), 1);
        assert_eq!(cat.rules.len(), 0);
    }

    #[test]
    fn repairer_honors_cooldown_then_fixes() {
        let (ctx, cat) = rig();
        cat.add_file("data18", "ghost", "root", 10, "x", None).unwrap();
        let f = DidKey::new("data18", "ghost");
        let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        // force stuck
        let req = cat.requests.scan(|_| true)[0].clone();
        for _ in 0..3 {
            cat.on_transfer_failed(req.id, "x").unwrap();
        }
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Stuck);
        let mut repairer = Repairer::new(ctx.clone(), "r1");
        // within cooldown: nothing happens
        assert_eq!(repairer.tick(cat.now()), 0);
        let now = advance(&ctx, 300_000);
        assert_eq!(repairer.tick(now), 1);
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Replicating);
        // repair created a fresh queued request
        assert_eq!(cat.requests_by_state.count(&RequestState::Queued), 1);
    }

    #[test]
    fn undertaker_erases_expired_dids() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        cat.add_rule(RuleSpec::new("root", f.clone(), "SRC-DISK", 1)).unwrap();
        cat.set_did_expiry(&f, Some(cat.now() + 1000)).unwrap();
        let mut undertaker = Undertaker::new(ctx.clone(), "u1");
        assert_eq!(undertaker.tick(cat.now()), 0);
        let now = advance(&ctx, 2_000);
        assert_eq!(undertaker.tick(now), 1);
        assert!(cat.get_did(&f).is_err());
        assert_eq!(cat.rules.len(), 0);
        // replica left unprotected for the reaper
        let rep = cat.get_replica("SRC-DISK", &f).unwrap();
        assert!(rep.tombstone.is_some());
        let _ = ReplicaState::Available;
    }
}
