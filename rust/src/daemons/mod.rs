//! The daemons layer (paper §3.4): "continuously running active
//! components that asynchronously orchestrate the collaborative work of
//! the entire system".
//!
//! Every daemon implements [`Daemon::tick`] — one bounded work cycle — so
//! the same code runs both ways:
//! * **production mode**: [`run_threaded`] spawns one thread per daemon
//!   instance, ticking at its interval;
//! * **simulation mode**: the discrete-event driver
//!   ([`crate::sim::driver`]) calls ticks in virtual-time order.
//!
//! Work partitioning follows the paper's heartbeat + hash scheme
//! ([`heartbeat::Heartbeats`], §3.4/§3.6): instances of the same daemon
//! type register heartbeats and shard rows by `hash(key) mod n_live`,
//! giving lock-free parallelism and automatic failover.

pub mod auditor;
pub mod bb8;
pub mod c3po;
pub mod checkpointer;
pub mod conveyor;
pub mod heartbeat;
pub mod hermes;
pub mod judge;
pub mod necromancer;
pub mod reaper;
pub mod throttler;
pub mod tracer;
pub mod transmogrifier;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::common::clock::EpochMs;
use crate::core::Catalog;
use crate::ftssim::FtsServer;
use crate::mq::Broker;
use crate::netsim::Network;
use crate::storagesim::Fleet;

/// Shared handles every daemon gets.
#[derive(Clone)]
pub struct Ctx {
    pub catalog: Arc<Catalog>,
    pub fleet: Arc<Fleet>,
    pub net: Arc<Network>,
    pub fts: Vec<Arc<FtsServer>>,
    pub broker: Broker,
    pub heartbeats: Arc<heartbeat::Heartbeats>,
}

impl Ctx {
    pub fn new(
        catalog: Arc<Catalog>,
        fleet: Arc<Fleet>,
        net: Arc<Network>,
        fts: Vec<Arc<FtsServer>>,
        broker: Broker,
    ) -> Self {
        // `[heartbeat] ttl` tunes the failover horizon; simulations with
        // coarse virtual-time ticks raise it so live instances are not
        // mistaken for dead between ticks.
        let ttl = catalog
            .cfg
            .get_duration_ms("heartbeat", "ttl", heartbeat::DEFAULT_TTL_MS);
        Ctx {
            catalog,
            fleet,
            net,
            fts,
            broker,
            heartbeats: Arc::new(heartbeat::Heartbeats::with_ttl(ttl)),
        }
    }
}

/// A daemon: one bounded unit of asynchronous work per tick.
pub trait Daemon: Send {
    fn name(&self) -> &'static str;
    /// Run one work cycle; returns the number of items processed.
    fn tick(&mut self, now: EpochMs) -> usize;
    /// Preferred interval between ticks (production mode; the sim driver
    /// uses the same value in virtual time).
    fn interval_ms(&self) -> i64 {
        10_000
    }
}

/// Sleep `ms` in small slices, returning early when `stop` is set, so
/// shutdown stays responsive however long the daemon interval is.
fn sliced_sleep(ms: u64, stop: &AtomicBool) {
    let mut remaining = ms;
    while remaining > 0 && !stop.load(Ordering::Relaxed) {
        let slice = remaining.min(50);
        std::thread::sleep(std::time::Duration::from_millis(slice));
        remaining -= slice;
    }
}

/// Run daemons on real threads until `stop` is set (production mode,
/// paper §5.2: "each daemon can be instantiated multiple times in
/// parallel"). Each daemon's first tick is staggered by a deterministic
/// per-name offset inside its interval, so a fleet started together does
/// not thundering-herd the catalog at every interval boundary.
pub fn run_threaded(
    daemons: Vec<Box<dyn Daemon>>,
    stop: Arc<AtomicBool>,
) -> Vec<JoinHandle<()>> {
    daemons
        .into_iter()
        .map(|mut d| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let interval = d.interval_ms().max(10) as u64;
                let stagger = crate::db::shard_hash(d.name().as_bytes()) % interval;
                sliced_sleep(stagger, &stop);
                while !stop.load(Ordering::Relaxed) {
                    let now = crate::common::clock::Clock::Real.now_ms();
                    let _ = d.tick(now);
                    sliced_sleep(interval, &stop);
                }
            })
        })
        .collect()
}

/// A running daemon fleet: the stop flag plus the thread handles
/// [`run_threaded`] returned, joined on [`FleetHandle::shutdown`] (or
/// drop). What production callers and the threaded soak test hold.
pub struct FleetHandle {
    stop: Arc<AtomicBool>,
    handles: Vec<JoinHandle<()>>,
}

impl FleetHandle {
    /// Spawn `daemons` with [`run_threaded`] under a fresh stop flag.
    pub fn spawn(daemons: Vec<Box<dyn Daemon>>) -> FleetHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let handles = run_threaded(daemons, stop.clone());
        FleetHandle { stop, handles }
    }

    pub fn len(&self) -> usize {
        self.handles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Signal every daemon thread to stop and join them all.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for FleetHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Override a daemon's tick interval without touching the daemon — the
/// threaded soak test runs the standard fleet (whose production
/// intervals are seconds to hours) at a pace that fits a wall-clock
/// test window.
pub struct Paced {
    inner: Box<dyn Daemon>,
    interval_ms: i64,
}

impl Paced {
    pub fn new(inner: Box<dyn Daemon>, interval_ms: i64) -> Paced {
        Paced { inner, interval_ms }
    }

    /// Wrap a whole fleet at one interval.
    pub fn fleet(daemons: Vec<Box<dyn Daemon>>, interval_ms: i64) -> Vec<Box<dyn Daemon>> {
        daemons
            .into_iter()
            .map(|d| Box::new(Paced::new(d, interval_ms)) as Box<dyn Daemon>)
            .collect()
    }
}

impl Daemon for Paced {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        self.inner.tick(now)
    }

    fn interval_ms(&self) -> i64 {
        self.interval_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountingDaemon {
        count: Arc<std::sync::atomic::AtomicUsize>,
    }

    impl Daemon for CountingDaemon {
        fn name(&self) -> &'static str {
            "counter"
        }
        fn tick(&mut self, _now: EpochMs) -> usize {
            self.count.fetch_add(1, Ordering::Relaxed);
            1
        }
        fn interval_ms(&self) -> i64 {
            10
        }
    }

    #[test]
    fn threaded_runner_ticks_and_stops() {
        let count = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let handles = run_threaded(
            vec![Box::new(CountingDaemon { count: count.clone() })],
            stop.clone(),
        );
        std::thread::sleep(std::time::Duration::from_millis(120));
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        assert!(count.load(Ordering::Relaxed) >= 2);
    }

    struct SlowDaemon;

    impl Daemon for SlowDaemon {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn tick(&mut self, _now: EpochMs) -> usize {
            0
        }
        fn interval_ms(&self) -> i64 {
            3_600_000
        }
    }

    #[test]
    fn paced_fleet_reticks_fast_and_shuts_down() {
        let count = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let daemons: Vec<Box<dyn Daemon>> = vec![
            Box::new(CountingDaemon { count: count.clone() }),
            Box::new(SlowDaemon),
        ];
        // Paced overrides even the hour-scale interval, and the stagger
        // (bounded by the overridden interval) cannot exceed 10 ms.
        let mut fleet = FleetHandle::spawn(Paced::fleet(daemons, 10));
        assert_eq!(fleet.len(), 2);
        std::thread::sleep(std::time::Duration::from_millis(120));
        let t0 = std::time::Instant::now();
        fleet.shutdown();
        assert!(t0.elapsed() < std::time::Duration::from_secs(2), "join stalled");
        assert!(count.load(Ordering::Relaxed) >= 2);
    }
}
