//! The necromancer — bad-replica recovery daemon (paper §4.4): "a daemon
//! identifies all bad replicas and recovers the data from another copy by
//! injecting a transfer request if possible. In the case of the corrupted
//! or lost replica being the last available copy of the file, the daemon
//! takes care of removing the file from the dataset, updating the
//! metadata, notifying external services, and informing the owner of the
//! dataset about the lost data."

use crate::common::clock::EpochMs;
#[cfg(test)]
use crate::core::types::ReplicaState;
use crate::db::assigned_to;
use crate::jsonx::Json;

use super::{Ctx, Daemon};

pub struct Necromancer {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
}

impl Necromancer {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("necromancer", "bulk", 200) as usize;
        Necromancer { ctx, instance: instance.to_string(), bulk }
    }
}

impl Daemon for Necromancer {
    fn name(&self) -> &'static str {
        "necromancer"
    }

    fn interval_ms(&self) -> i64 {
        60_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let (worker, n_workers) = self.ctx.heartbeats.beat("necromancer", &self.instance, now);
        let bad = cat.bad_replicas.scan_limit(self.bulk, |b| !b.resolved);
        let mut handled = 0;

        for entry in bad {
            let shard_key = crate::db::shard_hash(format!("{}{}", entry.rse, entry.did).as_bytes());
            if !assigned_to(shard_key, worker, n_workers) {
                continue;
            }
            handled += 1;
            let replica_key = (entry.rse.clone(), entry.did.clone());

            // Rules whose locks sat on the bad replica.
            let affected_rules: Vec<u64> = cat
                .locks_by_replica
                .get(&replica_key)
                .into_iter()
                .filter_map(|k| cat.locks.get(&k))
                .map(|l| l.rule_id)
                .collect();

            // Mark those locks stuck so the repair logic can relocate them
            // (a no-op when declare_bad already flipped them).
            cat.stick_locks_on_replica(&entry.rse, &entry.did, now);

            let other_copies = cat
                .available_replicas(&entry.did)
                .into_iter()
                .filter(|r| r.rse != entry.rse)
                .count();

            // Physically drop the bad file + catalog row.
            if let Some(sys) = self.ctx.fleet.get(&entry.rse) {
                if let Ok(rep) = cat.get_replica(&entry.rse, &entry.did) {
                    let _ = sys.delete(&rep.pfn);
                }
            }
            let _ = cat.remove_replica(&entry.rse, &entry.did);

            if other_copies > 0 {
                // Recovery: repair affected rules — their stuck locks get
                // relocated / re-queued, injecting transfer requests from
                // the surviving copies.
                for rule_id in &affected_rules {
                    let _ = cat.repair_rule(*rule_id);
                }
                cat.metrics.incr("necromancer.recovered", 1);
            } else {
                // Last copy lost: strip the file from its datasets, notify
                // the owners.
                let owner = cat.get_did(&entry.did).map(|d| d.account).unwrap_or_default();
                for parent in cat.list_parents(&entry.did) {
                    // force-detach regardless of open/monotonic: data is gone
                    let _ = cat
                        .attachments
                        .remove(&(parent.clone(), entry.did.clone()), now);
                }
                // Remove remaining rules+locks directly on the lost file,
                // then shed the locks ancestor (dataset/container) rules
                // still hold on it — their data is gone; the rules shrink
                // exactly as if the file had been detached.
                for rule in cat.list_rules_for_did(&entry.did) {
                    let _ = cat.delete_rule(rule.id);
                }
                cat.release_locks_on_lost_file(&entry.did);
                cat.refresh_availability(&entry.did);
                cat.notify(
                    "email-lost-data",
                    Json::obj()
                        .with("account", owner.as_str())
                        .with("scope", entry.did.scope.as_str())
                        .with("name", entry.did.name.as_str())
                        .with("rse", entry.rse.as_str()),
                );
                cat.notify(
                    "lost-file",
                    Json::obj()
                        .with("scope", entry.did.scope.as_str())
                        .with("name", entry.did.name.as_str()),
                );
                cat.metrics.incr("necromancer.lost", 1);
            }
            cat.bad_replicas
                .update(&replica_key, now, |b| b.resolved = true);
        }
        handled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::{Availability, DidKey, RequestState, RuleState};
    use crate::daemons::conveyor::tests::{rig, seed_file};

    #[test]
    fn recovers_from_surviving_copy() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        // second copy on DST-A via rule + manual completion
        let rid = cat.add_rule(RuleSpec::new("root", f.clone(), "DST-A", 1)).unwrap();
        let req = cat.requests.scan(|_| true)[0].clone();
        cat.on_transfer_done(req.id).unwrap();
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);

        // DST-A copy goes bad
        cat.declare_bad("DST-A", &f, "checksum", "ops").unwrap();
        let mut necro = Necromancer::new(ctx.clone(), "n1");
        assert_eq!(necro.tick(cat.now()), 1);
        // bad replica removed; rule back to replicating with a fresh
        // request sourced from the survivor
        assert!(cat.get_replica("DST-A", &f).is_err() || {
            // repair may have recreated a Copying stub at DST-A
            cat.get_replica("DST-A", &f).unwrap().state == ReplicaState::Copying
        });
        let rule = cat.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Replicating);
        assert_eq!(cat.requests_by_state.count(&RequestState::Queued), 1);
        assert_eq!(cat.metrics.counter("necromancer.recovered"), 1);
    }

    #[test]
    fn last_copy_lost_strips_file_and_notifies_owner() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        cat.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        cat.attach(&ds, &f).unwrap();
        cat.add_rule(RuleSpec::new("root", f.clone(), "SRC-DISK", 1)).unwrap();

        cat.declare_bad("SRC-DISK", &f, "bit rot", "ops").unwrap();
        let mut necro = Necromancer::new(ctx.clone(), "n1");
        assert_eq!(necro.tick(cat.now()), 1);

        // file detached from the dataset (§4.4 "removing the file from
        // the dataset"), marked not-available, owner notified by email
        assert!(cat.list_content(&ds, true).is_empty());
        assert_ne!(cat.get_did(&f).unwrap().availability, Availability::Available);
        let events: Vec<String> =
            cat.outbox.scan(|_| true).into_iter().map(|m| m.event_type).collect();
        assert!(events.contains(&"email-lost-data".to_string()), "{events:?}");
        assert!(events.contains(&"lost-file".to_string()));
        assert_eq!(cat.metrics.counter("necromancer.lost"), 1);
    }

    #[test]
    fn lost_file_sheds_dataset_rule_locks() {
        let (ctx, cat) = rig();
        let f1 = seed_file(&ctx, "a1", 100);
        let f2 = seed_file(&ctx, "a2", 100);
        cat.add_dataset("data18", "ds", "root").unwrap();
        let ds = DidKey::new("data18", "ds");
        cat.attach(&ds, &f1).unwrap();
        cat.attach(&ds, &f2).unwrap();
        let rid = cat.add_rule(RuleSpec::new("root", ds.clone(), "SRC-DISK", 1)).unwrap();
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Ok);
        assert_eq!(cat.get_account_usage("root", "SRC-DISK").bytes, 200);
        // a1's only copy is lost; the dataset rule must not stay stuck on
        // data that no longer exists anywhere
        cat.declare_bad("SRC-DISK", &f1, "gone", "ops").unwrap();
        assert_eq!(cat.get_rule(rid).unwrap().state, RuleState::Stuck);
        let mut necro = Necromancer::new(ctx.clone(), "n1");
        necro.tick(cat.now());
        let rule = cat.get_rule(rid).unwrap();
        assert_eq!(rule.state, RuleState::Ok, "{rule:?}");
        assert_eq!(cat.locks_by_rule.get(&rid).len(), 1, "only a2's lock remains");
        assert_eq!(cat.get_account_usage("root", "SRC-DISK").bytes, 100);
        assert_eq!(cat.metrics.counter("necromancer.lost"), 1);
    }

    #[test]
    fn resolved_entries_not_reprocessed() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        cat.declare_bad("SRC-DISK", &f, "x", "ops").unwrap();
        let mut necro = Necromancer::new(ctx.clone(), "n1");
        assert_eq!(necro.tick(cat.now()), 1);
        assert_eq!(necro.tick(cat.now()), 0, "idempotent");
    }
}
