//! The reaper — data deletion daemon (paper §4.3).
//!
//! Two modes per RSE:
//! * **greedy** — "removes data as soon as it is marked, which maximizes
//!   the free space on storage";
//! * **non-greedy** — "deletes the minimum amount of data required to
//!   fulfill new rules entering the system, and keeps the existing data
//!   around for caching purposes": deletion only happens when free space
//!   falls below a per-RSE watermark, and evicts Least-Recently-Used
//!   first (access timestamps from traces).

use crate::common::clock::EpochMs;
use crate::core::types::Replica;
use crate::db::assigned_to;

use super::{Ctx, Daemon};

/// Deletion policy for one RSE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReaperMode {
    Greedy,
    /// Keep cached data until free space < `min_free_bytes`.
    NonGreedy { min_free_bytes: u64 },
}

pub struct Reaper {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
}

impl Reaper {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let bulk = ctx.catalog.cfg.get_i64("reaper", "bulk", 500) as usize;
        Reaper { ctx, instance: instance.to_string(), bulk }
    }

    /// Mode for an RSE: `reaper.greedy` config default, overridable per
    /// RSE via the `greedy` attribute and watermark via `min_free`.
    fn mode_for(&self, rse: &crate::core::rse::Rse) -> ReaperMode {
        let default_greedy = self.ctx.catalog.cfg.get_bool("reaper", "greedy", true);
        let greedy = rse
            .attr("greedy")
            .map(|v| v == "true" || v == "1")
            .unwrap_or(default_greedy);
        if greedy {
            ReaperMode::Greedy
        } else {
            let min_free = rse
                .attr("min_free")
                .and_then(crate::common::units::parse_bytes)
                .unwrap_or(0);
            ReaperMode::NonGreedy { min_free_bytes: min_free }
        }
    }

    /// Delete one replica's bytes from storage. Returns true when the
    /// catalog row may be removed; storage failures leave the replica for
    /// a later sweep (the paper's deletion error rate).
    fn storage_delete(&self, rep: &Replica) -> bool {
        if let Some(sys) = self.ctx.fleet.get(&rep.rse) {
            match sys.delete(&rep.pfn) {
                Ok(()) => {}
                Err(crate::common::error::RucioError::SourceNotFound(_)) => {
                    // already gone from storage: clean the catalog anyway
                }
                Err(_) => {
                    self.ctx.catalog.metrics.incr("reaper.errors", 1);
                    return false;
                }
            }
        }
        true
    }

    /// Remove the storage-deleted victims from the catalog in one batched
    /// commit and emit the per-deletion bookkeeping. Returns the number of
    /// rows actually removed.
    fn commit_deletions(&self, victims: &[Replica]) -> usize {
        if victims.is_empty() {
            return 0;
        }
        let cat = &self.ctx.catalog;
        let keys: Vec<(String, crate::core::types::DidKey)> =
            victims.iter().map(|r| (r.rse.clone(), r.did.clone())).collect();
        let removed = cat.remove_replicas_bulk(&keys);
        for rep in &removed {
            cat.metrics.incr("reaper.deleted", 1);
            cat.metrics.incr("reaper.deleted_bytes", rep.bytes);
            cat.notify(
                "deletion-done",
                crate::jsonx::Json::obj()
                    .with("rse", rep.rse.as_str())
                    .with("scope", rep.did.scope.as_str())
                    .with("name", rep.did.name.as_str())
                    .with("bytes", rep.bytes),
            );
        }
        removed.len()
    }
}

impl Daemon for Reaper {
    fn name(&self) -> &'static str {
        "reaper"
    }

    fn interval_ms(&self) -> i64 {
        30_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = &self.ctx.catalog;
        let (worker, n_workers) = self.ctx.heartbeats.beat("reaper", &self.instance, now);
        let mut deleted = 0;
        for rse in cat.list_rses() {
            // Shard whole RSEs across reaper instances (paper §3.6 hash
            // partitioning; per-RSE granularity keeps deletions batched).
            if !assigned_to(crate::db::shard_hash(rse.name.as_bytes()), worker, n_workers) {
                continue;
            }
            if !rse.availability_delete {
                continue; // §4.3: archival RSEs with deletion disabled
            }
            let eligible = cat.deletable_replicas(&rse.name, now, self.bulk);
            if eligible.is_empty() {
                continue;
            }
            cat.metrics.incr("reaper.sweeps", 1);
            // Storage deletes happen per file; the catalog rows for every
            // successful delete on this RSE land in ONE batched commit.
            let mut victims: Vec<Replica> = Vec::new();
            match self.mode_for(&rse) {
                ReaperMode::Greedy => {
                    for rep in eligible {
                        if self.storage_delete(&rep) {
                            victims.push(rep);
                        }
                    }
                }
                ReaperMode::NonGreedy { min_free_bytes } => {
                    let Some(sys) = self.ctx.fleet.get(&rse.name) else { continue };
                    let mut free = sys.free();
                    if free >= min_free_bytes {
                        // plenty of space: keep caches warm. Counted so
                        // mass-deletion campaigns can verify the
                        // watermark actually held mid-sweep.
                        cat.metrics.incr("reaper.watermark_holds", 1);
                        continue;
                    }
                    // LRU order (§4.3: "selection of files to remove is
                    // automatically derived from their popularity ...
                    // access timestamps").
                    let mut lru = eligible;
                    lru.sort_by_key(|r| r.accessed_at);
                    for rep in lru {
                        if free >= min_free_bytes {
                            break;
                        }
                        if self.storage_delete(&rep) {
                            free += rep.bytes;
                            victims.push(rep);
                            cat.metrics.incr("reaper.lru_evicted", 1);
                        }
                    }
                }
            }
            deleted += self.commit_deletions(&victims);
        }
        deleted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::{DidKey, ReplicaState};
    use crate::daemons::conveyor::tests::{rig, seed_file};
    use crate::storagesim::{FailurePolicy, StorageKind, StorageSystem};

    fn advance(ctx: &Ctx, ms: i64) -> EpochMs {
        if let crate::common::clock::Clock::Sim(s) = &ctx.catalog.clock {
            s.advance(ms);
        }
        ctx.catalog.now()
    }

    #[test]
    fn greedy_deletes_tombstoned_replicas() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000); // unprotected → tombstoned at birth
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        let now = advance(&ctx, 25 * 3_600_000); // past the birth grace
        let n = reaper.tick(now);
        assert_eq!(n, 1);
        assert!(cat.get_replica("SRC-DISK", &f).is_err());
        assert_eq!(ctx.fleet.get("SRC-DISK").unwrap().file_count(), 0);
        // deletion event queued
        let events: Vec<String> =
            cat.outbox.scan(|_| true).into_iter().map(|m| m.event_type).collect();
        assert!(events.contains(&"deletion-done".to_string()));
    }

    #[test]
    fn locked_replicas_survive() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        cat.add_rule(crate::core::rules_api::RuleSpec::new("root", f.clone(), "SRC-DISK", 1))
            .unwrap();
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        assert_eq!(reaper.tick(cat.now()), 0);
        assert!(cat.get_replica("SRC-DISK", &f).is_ok());
    }

    #[test]
    fn grace_period_respected_after_rule_removal() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        let rid = cat
            .add_rule(crate::core::rules_api::RuleSpec::new("root", f.clone(), "SRC-DISK", 1))
            .unwrap();
        cat.delete_rule(rid).unwrap();
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        // §4.3: 24h undo window
        assert_eq!(reaper.tick(cat.now()), 0, "still in grace");
        let now = advance(&ctx, 25 * 3_600_000);
        assert_eq!(reaper.tick(now), 1);
    }

    #[test]
    fn non_greedy_keeps_cache_until_watermark() {
        let (ctx, cat) = rig();
        // dedicated small cache RSE
        let now = cat.now();
        cat.add_rse(
            crate::core::rse::Rse::new("CACHE", now)
                .with_attr("greedy", "false")
                .with_attr("min_free", "3000"),
        )
        .unwrap();
        ctx.fleet.add(StorageSystem::new("CACHE", StorageKind::Disk, 10_000));
        // 3 unprotected files of 2500 → used 7500, free 2500 < 3000
        for i in 0..3 {
            let name = format!("c{i}");
            let adler = crate::storagesim::synthetic_adler32_for(&name, 2500);
            cat.add_file("data18", &name, "root", 2500, &adler, None).unwrap();
            let key = DidKey::new("data18", &name);
            let rep = cat.add_replica("CACHE", &key, ReplicaState::Available, None).unwrap();
            ctx.fleet.get("CACHE").unwrap().put(&rep.pfn, 2500, now).unwrap();
            // stagger access times for LRU: c0 oldest
            if let crate::common::clock::Clock::Sim(s) = &cat.clock {
                s.advance(1000);
            }
            cat.touch_replica("CACHE", &key);
        }
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        let now = advance(&ctx, 25 * 3_600_000); // past the birth grace
        let n = reaper.tick(now);
        // needs to free until >= 3000: delete exactly one (oldest)
        assert_eq!(n, 1);
        assert!(cat.get_replica("CACHE", &DidKey::new("data18", "c0")).is_err(), "LRU first");
        assert!(cat.get_replica("CACHE", &DidKey::new("data18", "c1")).is_ok());
    }

    #[test]
    fn deletion_disabled_rse_protected() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 1000);
        cat.set_rse_availability("SRC-DISK", true, true, false).unwrap();
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        assert_eq!(reaper.tick(cat.now()), 0);
        assert!(cat.get_replica("SRC-DISK", &f).is_ok());
    }

    #[test]
    fn storage_delete_failure_retries_later() {
        let (ctx, cat) = rig();
        let now = cat.now();
        cat.add_rse(crate::core::rse::Rse::new("FLAKY", now)).unwrap();
        ctx.fleet.add(
            StorageSystem::new("FLAKY", StorageKind::Disk, u64::MAX)
                .with_policy(FailurePolicy { delete_fail: 1.0, ..Default::default() }),
        );
        let adler = crate::storagesim::synthetic_adler32_for("f", 10);
        cat.add_file("data18", "f", "root", 10, &adler, None).unwrap();
        let key = DidKey::new("data18", "f");
        let rep = cat.add_replica("FLAKY", &key, ReplicaState::Available, None).unwrap();
        ctx.fleet.get("FLAKY").unwrap().put(&rep.pfn, 10, now).unwrap();
        let mut reaper = Reaper::new(ctx.clone(), "r1");
        let now = advance(&ctx, 25 * 3_600_000); // past the birth grace
        assert_eq!(reaper.tick(now), 0, "delete failed");
        assert!(cat.get_replica("FLAKY", &key).is_ok(), "replica stays for retry");
        assert!(cat.metrics.counter("reaper.errors") >= 1);
    }
}
