//! The throttler — transfer admission control (paper §3.4 / Fig 6: FTS
//! activity shares arbitrate competing activities over shared wide-area
//! links; Rucio submits through an admission-controlled pipeline).
//!
//! New transfer requests are created in [`RequestState::Waiting`] when
//! `[throttler] enabled` is set. Each tick the throttler groups the
//! waiting requests by their estimated `(src, dst)` link and releases
//! them — `Waiting → Queued`, one batched commit — against:
//!
//! * a **per-link cap** (`[throttler] max_per_link`): released-but-not-
//!   terminal requests on a link never exceed it, so a storm on one
//!   destination cannot bury FTS or starve other links;
//! * **two-level weighted fair shares** arbitrated by **deficit round
//!   robin**. The outer level splits each link's free slots across
//!   **VOs** (`[throttler] vo_share.<vo>`, default weight 1.0) so one
//!   tenant's backlog cannot crowd out another's; the inner level splits
//!   each VO's allocation across **activities**
//!   (`[throttler] share.<activity>`, default weight 1.0). At both
//!   levels every waiting party accrues credit proportional to its
//!   weight and the highest-credit party releases first: a nonzero-share
//!   VO or activity can be outpaced but never starved — its deficit
//!   grows every tick until it wins a slot (bounded wait;
//!   property-tested below). Zero-share entries are administratively
//!   blocked. A request's VO is that of its DID's scope.
//!
//! The source of a waiting request is not yet assigned (the submitter
//! ranks sources at submission time), so the link is *estimated* from
//! the best ranked source — the same choice the submitter will make.
//! Requests with no rankable source are released immediately so the
//! submitter can fail them toward retry/stuck without admission delay.
//! Caps are enforced exactly at the FTS layer (`max_active_per_link`);
//! the throttler's job is to keep the queue *shaped* before submission.

use std::collections::{BTreeMap, VecDeque};

use crate::common::clock::EpochMs;
use crate::core::types::{DidKey, RequestState, TransferRequest};
use crate::db::assigned_to;

use super::{Ctx, Daemon};

/// A directed link key: (estimated source RSE, destination RSE).
type LinkKey = (String, String);

pub struct Throttler {
    pub ctx: Ctx,
    pub instance: String,
    pub bulk: usize,
    /// Released-but-unfinished cap per (src, dst) link.
    pub max_per_link: usize,
    /// Inner-level DRR credit per (src, dst, vo, activity); persists
    /// across ticks so a low-share activity's claim grows until served.
    act_deficits: BTreeMap<(String, String, String, String), f64>,
    /// Outer-level DRR credit per (src, dst, vo): the VO fair share is
    /// settled before any activity inside the VO is considered.
    vo_deficits: BTreeMap<(String, String, String), f64>,
}

impl Throttler {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let cfg = &ctx.catalog.cfg;
        let bulk = cfg.get_i64("throttler", "bulk", 2000) as usize;
        let max_per_link = cfg.get_i64("throttler", "max_per_link", 8).max(1) as usize;
        Throttler {
            ctx,
            instance: instance.to_string(),
            bulk,
            max_per_link,
            act_deficits: BTreeMap::new(),
            vo_deficits: BTreeMap::new(),
        }
    }

    /// Configured weight of an activity (`[throttler] share.<activity>`);
    /// unknown activities weigh 1.0, negative configs clamp to 0.
    fn share(&self, activity: &str) -> f64 {
        self.ctx
            .catalog
            .cfg
            .get_f64("throttler", &format!("share.{activity}"), 1.0)
            .max(0.0)
    }

    /// Configured weight of a VO (`[throttler] vo_share.<vo>`); unknown
    /// VOs weigh 1.0, negative configs clamp to 0.
    fn vo_share(&self, vo: &str) -> f64 {
        self.ctx
            .catalog
            .cfg
            .get_f64("throttler", &format!("vo_share.{vo}"), 1.0)
            .max(0.0)
    }

    /// Estimated source RSE for a not-yet-submitted request: the same
    /// pick the submitter will make — the first ranked source with a
    /// usable network link to the destination (the shared
    /// [`super::conveyor::link_usable`] definition, so admission and
    /// submission cannot drift). When no link is usable (the submitter
    /// will plan a multi-hop chain), fall back to the top-ranked source:
    /// a chain's first hop leaves one of the ranked sources, so the cap
    /// still charges the loaded side. `None` when no source is rankable
    /// at all.
    fn estimate_src(&self, req: &TransferRequest) -> Option<String> {
        let cat = &self.ctx.catalog;
        let ranked = cat.ranked_sources(&req.did, &req.dst_rse);
        ranked
            .iter()
            .find(|(r, _)| {
                super::conveyor::link_usable(cat, &self.ctx.net, &r.rse, &req.dst_rse)
            })
            .or_else(|| ranked.first())
            .map(|(r, _)| r.rse.clone())
    }

    /// Two-level weighted deficit-round-robin release for one link: up to
    /// `free` requests come off the per-activity FIFOs. The outer level
    /// picks the VO with the highest accumulated credit, the inner level
    /// the highest-credit activity inside it — so tenants are isolated
    /// from each other's activity mix, and the split is work-conserving
    /// (a VO that drains hands its unused slots to the others).
    fn drr_release(
        &mut self,
        link: &LinkKey,
        queues: &mut BTreeMap<String, BTreeMap<String, VecDeque<u64>>>,
        mut free: usize,
        released: &mut Vec<(u64, Option<String>)>,
    ) {
        // One quantum per accrual at both levels for every waiting party,
        // scaled so an uncontended link drains in a single round. The
        // activity quantum is scaled against the VO's expected cut of the
        // free slots, not the whole link.
        #[allow(clippy::too_many_arguments)]
        fn accrue(
            vo_deficits: &mut BTreeMap<(String, String, String), f64>,
            act_deficits: &mut BTreeMap<(String, String, String, String), f64>,
            link: &LinkKey,
            queues: &BTreeMap<String, BTreeMap<String, VecDeque<u64>>>,
            vo_weights: &BTreeMap<String, f64>,
            act_weights: &BTreeMap<(String, String), f64>,
            free: usize,
            total_vo_w: f64,
        ) {
            let vo_scale = (free as f64 / total_vo_w).max(1.0);
            for (vo, acts) in queues {
                if acts.values().all(|q| q.is_empty()) {
                    continue;
                }
                let vw = vo_weights[vo];
                if vw <= 0.0 {
                    continue;
                }
                *vo_deficits
                    .entry((link.0.clone(), link.1.clone(), vo.clone()))
                    .or_insert(0.0) += vw * vo_scale;
                let free_vo = (free as f64 * vw / total_vo_w).max(1.0);
                let total_act_w: f64 = acts
                    .iter()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(a, _)| act_weights[&(vo.clone(), a.clone())])
                    .sum();
                if total_act_w <= 0.0 {
                    continue;
                }
                let act_scale = (free_vo / total_act_w).max(1.0);
                for (act, q) in acts {
                    if q.is_empty() {
                        continue;
                    }
                    let w = act_weights[&(vo.clone(), act.clone())];
                    if w > 0.0 {
                        *act_deficits
                            .entry((link.0.clone(), link.1.clone(), vo.clone(), act.clone()))
                            .or_insert(0.0) += w * act_scale;
                    }
                }
            }
        }

        let mut vo_weights: BTreeMap<String, f64> = BTreeMap::new();
        let mut act_weights: BTreeMap<(String, String), f64> = BTreeMap::new();
        for (vo, acts) in queues.iter() {
            vo_weights.insert(vo.clone(), self.vo_share(vo));
            for act in acts.keys() {
                act_weights.insert((vo.clone(), act.clone()), self.share(act));
            }
        }
        let total_vo_w: f64 = queues
            .iter()
            .filter(|(_, acts)| acts.values().any(|q| !q.is_empty()))
            .map(|(vo, _)| vo_weights[vo])
            .sum();
        if total_vo_w <= 0.0 {
            return; // every waiting VO is administratively blocked
        }
        accrue(
            &mut self.vo_deficits,
            &mut self.act_deficits,
            link,
            queues,
            &vo_weights,
            &act_weights,
            free,
            total_vo_w,
        );
        let mut topups = 0;
        while free > 0 {
            // the claimable (vo, activity) pair: VO credit decides first,
            // activity credit second, both must be ≥ 1; exact ties break
            // toward the lexicographically smaller name
            let mut best: Option<(f64, f64, String, String)> = None;
            for (vo, acts) in queues.iter() {
                let vd = self
                    .vo_deficits
                    .get(&(link.0.clone(), link.1.clone(), vo.clone()))
                    .copied()
                    .unwrap_or(0.0);
                if vd < 1.0 {
                    continue;
                }
                for (act, q) in acts {
                    if q.is_empty() {
                        continue;
                    }
                    let ad = self
                        .act_deficits
                        .get(&(link.0.clone(), link.1.clone(), vo.clone(), act.clone()))
                        .copied()
                        .unwrap_or(0.0);
                    if ad < 1.0 {
                        continue;
                    }
                    let cand = (vd, ad, vo.clone(), act.clone());
                    best = Some(match best.take() {
                        None => cand,
                        Some(cur) => {
                            let ord = cand
                                .0
                                .total_cmp(&cur.0)
                                .then(cand.1.total_cmp(&cur.1))
                                .then(cur.2.cmp(&cand.2))
                                .then(cur.3.cmp(&cand.3));
                            if ord == std::cmp::Ordering::Greater {
                                cand
                            } else {
                                cur
                            }
                        }
                    });
                }
            }
            match best {
                Some((_, _, vo, act)) => {
                    if let Some(id) = queues
                        .get_mut(&vo)
                        .and_then(|m| m.get_mut(&act))
                        .and_then(|q| q.pop_front())
                    {
                        released.push((id, Some(link.0.clone())));
                        free -= 1;
                    }
                    let vkey = (link.0.clone(), link.1.clone(), vo.clone());
                    let akey = (link.0.clone(), link.1.clone(), vo.clone(), act.clone());
                    if let Some(d) = self.vo_deficits.get_mut(&vkey) {
                        *d -= 1.0;
                    }
                    if let Some(d) = self.act_deficits.get_mut(&akey) {
                        *d -= 1.0;
                    }
                    // classic DRR: an emptied queue forfeits leftover credit
                    let acts = queues.get(&vo);
                    if acts
                        .and_then(|m| m.get(&act))
                        .map(|q| q.is_empty())
                        .unwrap_or(true)
                    {
                        self.act_deficits.remove(&akey);
                    }
                    if acts
                        .map(|m| m.values().all(|q| q.is_empty()))
                        .unwrap_or(true)
                    {
                        self.vo_deficits.remove(&vkey);
                    }
                }
                None => {
                    // nothing claimable: stop when no waiting pair can
                    // ever accrue credit, otherwise top up (bounded — the
                    // deficits persist across ticks regardless)
                    let claimable = queues.iter().any(|(vo, acts)| {
                        vo_weights[vo] > 0.0
                            && acts.iter().any(|(a, q)| {
                                !q.is_empty() && act_weights[&(vo.clone(), a.clone())] > 0.0
                            })
                    });
                    topups += 1;
                    if !claimable || topups > 1024 {
                        break;
                    }
                    accrue(
                        &mut self.vo_deficits,
                        &mut self.act_deficits,
                        link,
                        queues,
                        &vo_weights,
                        &act_weights,
                        free,
                        total_vo_w,
                    );
                }
            }
        }
    }
}

impl Daemon for Throttler {
    fn name(&self) -> &'static str {
        "throttler"
    }

    fn interval_ms(&self) -> i64 {
        5_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        let cat = self.ctx.catalog.clone();
        let (worker, n_workers) = self.ctx.heartbeats.beat("throttler", &self.instance, now);

        // The whole admission queue of this shard, oldest first (FIFO
        // within an activity). Deliberately NOT truncated here: a window
        // sliced before grouping would let permanently unreleasable rows
        // (zero-share activity, saturated link) occupy it forever and
        // starve everything younger. `bulk` bounds the *releases* per
        // tick instead.
        let mut waiting: Vec<TransferRequest> = cat
            .requests_by_state
            .get(&RequestState::Waiting)
            .into_iter()
            .filter(|id| assigned_to(*id, worker, n_workers))
            .filter_map(|id| cat.requests.get(&id))
            .collect();
        if waiting.is_empty() {
            return 0;
        }
        waiting.sort_by_key(|r| (r.created_at, r.id));

        // Group by (estimated link, activity). The estimate is computed
        // once per request and persisted on the row (`src_rse` hint), so
        // a large backlog is not re-ranked on every tick — the cap
        // attribution tolerates a stale hint; the submitter re-derives
        // its actual source at submission time. Unrankable sources are
        // released unconditionally — the submitter owns that failure.
        let mut released: Vec<(u64, Option<String>)> = Vec::new();
        let mut per_link: BTreeMap<LinkKey, BTreeMap<String, BTreeMap<String, VecDeque<u64>>>> =
            BTreeMap::new();
        // per-tick scope → VO cache: a backlog touches few scopes, so the
        // VO attribution costs one point get per distinct scope
        let mut scope_vo: BTreeMap<String, String> = BTreeMap::new();
        for req in &waiting {
            if released.len() >= self.bulk {
                break; // release budget spent; the rest next tick
            }
            let est = match &req.src_rse {
                Some(s) => Some(s.clone()),
                None => {
                    let e = self.estimate_src(req);
                    if let Some(src) = &e {
                        let hint = src.clone();
                        cat.requests.update(&req.id, now, |r| {
                            if r.src_rse.is_none() {
                                r.src_rse = Some(hint);
                            }
                        });
                    }
                    e
                }
            };
            match est {
                Some(src) => {
                    let vo = scope_vo
                        .entry(req.did.scope.clone())
                        .or_insert_with(|| {
                            cat.scopes
                                .get(&req.did.scope)
                                .map(|s| s.vo)
                                .unwrap_or_else(|| {
                                    crate::core::types::DEFAULT_VO.to_string()
                                })
                        })
                        .clone();
                    per_link
                        .entry((src, req.dst_rse.clone()))
                        .or_default()
                        .entry(vo)
                        .or_default()
                        .entry(req.activity.clone())
                        .or_default()
                        .push_back(req.id)
                }
                None => released.push((req.id, None)),
            }
        }

        // Released-but-unfinished load per hot link, via the destination
        // index (O(requests on hot destinations), not O(all live rows)):
        // SUBMITTED requests carry their chosen source, QUEUED/RETRY
        // rows the hint recorded at their own admission (or the last
        // submission attempt) — re-ranking is only needed for rows with
        // no source on record.
        let hot_dsts: std::collections::BTreeSet<String> =
            per_link.keys().map(|(_, d)| d.clone()).collect();
        let mut inflight: BTreeMap<LinkKey, usize> = BTreeMap::new();
        for dst in &hot_dsts {
            let lo = (dst.clone(), DidKey::new("", ""));
            let hi = (format!("{dst}\u{0}"), DidKey::new("", ""));
            for id in cat.requests_by_dest.range(&lo, &hi) {
                let Some(req) = cat.requests.get(&id) else { continue };
                if req.state == RequestState::Waiting {
                    continue; // not yet released — it is what we meter
                }
                let src = match &req.src_rse {
                    Some(s) => Some(s.clone()),
                    None => self.estimate_src(&req),
                };
                if let Some(src) = src {
                    *inflight.entry((src, req.dst_rse.clone())).or_insert(0) += 1;
                }
            }
        }

        // DRR per link against the free budget (and the global per-tick
        // release budget).
        let links: Vec<LinkKey> = per_link.keys().cloned().collect();
        for link in links {
            let budget = self.bulk.saturating_sub(released.len());
            if budget == 0 {
                break;
            }
            let used = inflight.get(&link).copied().unwrap_or(0);
            let free = self.max_per_link.saturating_sub(used).min(budget);
            if free == 0 {
                continue;
            }
            let mut queues = per_link.remove(&link).unwrap();
            self.drr_release(&link, &mut queues, free, &mut released);
        }

        let n = cat.release_waiting_requests(&released, now);
        // Per-activity release accounting: campaign reports read these to
        // show how admission paced a flood (e.g. a tape-carousel's
        // "Staging" waves against the per-link caps).
        let mut by_activity: BTreeMap<String, u64> = BTreeMap::new();
        for (id, _) in &released {
            if let Some(req) = cat.requests.get(id) {
                *by_activity.entry(req.activity).or_insert(0) += 1;
            }
        }
        for (activity, count) in by_activity {
            cat.metrics.incr(&format!("throttler.released.{activity}"), count);
        }
        cat.metrics
            .gauge_set("throttler.waiting", cat.requests_by_state.count(&RequestState::Waiting) as u64);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rules_api::RuleSpec;
    use crate::core::types::{AccountType, DidKey, ReplicaState};
    use crate::core::Catalog;
    use crate::daemons::Ctx;
    use crate::ftssim::FtsServer;
    use crate::mq::Broker;
    use crate::netsim::{Link, Network};
    use crate::storagesim::{Fleet, StorageKind, StorageSystem};
    use std::sync::Arc;

    /// Throttler-enabled rig: SRC + two destinations, generous links.
    fn rig(cfg_extra: &[(&str, &str)]) -> (Ctx, Arc<Catalog>) {
        let mut cfg = crate::common::config::Config::new();
        cfg.set("throttler", "enabled", "true");
        for (k, v) in cfg_extra {
            cfg.set("throttler", k, *v);
        }
        let catalog = Arc::new(Catalog::new(
            crate::common::clock::Clock::sim_at(1_600_000_000_000),
            cfg,
        ));
        let now = catalog.now();
        catalog.add_scope("data18", "root").unwrap();
        let fleet = Arc::new(Fleet::new());
        let net = Arc::new(Network::new());
        for name in ["SRC", "DST-A", "DST-B"] {
            catalog
                .add_rse(crate::core::rse::Rse::new(name, now).with_attr("site", name))
                .unwrap();
            fleet.add(StorageSystem::new(name, StorageKind::Disk, u64::MAX));
        }
        for a in ["SRC", "DST-A", "DST-B"] {
            for b in ["SRC", "DST-A", "DST-B"] {
                if a != b {
                    net.set_link(a, b, Link::new(100_000_000, 5, 1.0));
                }
            }
        }
        let broker = Broker::new();
        let fts = vec![Arc::new(FtsServer::new(
            "fts1",
            net.clone(),
            fleet.clone(),
            Some(broker.clone()),
        ))];
        let ctx = Ctx::new(catalog.clone(), fleet, net, fts, broker);
        (ctx, catalog)
    }

    fn seed_request(ctx: &Ctx, name: &str, dst: &str, activity: &str) -> u64 {
        seed_request_in(ctx, "data18", name, dst, activity)
    }

    fn seed_request_in(ctx: &Ctx, scope: &str, name: &str, dst: &str, activity: &str) -> u64 {
        let cat = &ctx.catalog;
        let adler = crate::storagesim::synthetic_adler32_for(name, 100);
        cat.add_file(scope, name, "root", 100, &adler, None).unwrap();
        let key = DidKey::new(scope, name);
        let rep = cat.add_replica("SRC", &key, ReplicaState::Available, None).unwrap();
        ctx.fleet.get("SRC").unwrap().put(&rep.pfn, 100, cat.now()).unwrap();
        cat.add_rule(RuleSpec::new("root", key.clone(), dst, 1).with_activity(activity))
            .unwrap();
        let reqs = cat.requests.scan(|r| r.did == key);
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].state, RequestState::Waiting, "admission state");
        reqs[0].id
    }

    fn count_state(cat: &Catalog, s: RequestState) -> usize {
        cat.requests_by_state.count(&s)
    }

    #[test]
    fn releases_up_to_link_cap_only() {
        let (ctx, cat) = rig(&[("max_per_link", "3")]);
        for i in 0..10 {
            seed_request(&ctx, &format!("f{i}"), "DST-A", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 3, "cap bounds the first release");
        assert_eq!(count_state(&cat, RequestState::Queued), 3);
        assert_eq!(count_state(&cat, RequestState::Waiting), 7);
        // cap already full: nothing more until the released ones finish
        assert_eq!(t.tick(cat.now()), 0);
        // two finish → two more slots open
        for req in cat.requests.scan(|r| r.state == RequestState::Queued).iter().take(2) {
            cat.on_transfer_done(req.id).unwrap();
        }
        assert_eq!(t.tick(cat.now()), 2);
        assert_eq!(count_state(&cat, RequestState::Waiting), 5);
    }

    #[test]
    fn independent_links_get_independent_budgets() {
        let (ctx, cat) = rig(&[("max_per_link", "2")]);
        for i in 0..4 {
            seed_request(&ctx, &format!("a{i}"), "DST-A", "Production");
            seed_request(&ctx, &format!("b{i}"), "DST-B", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 4, "2 per link × 2 links");
        let queued = cat.requests.scan(|r| r.state == RequestState::Queued);
        assert_eq!(queued.iter().filter(|r| r.dst_rse == "DST-A").count(), 2);
        assert_eq!(queued.iter().filter(|r| r.dst_rse == "DST-B").count(), 2);
    }

    #[test]
    fn weighted_shares_split_the_link() {
        let (ctx, cat) = rig(&[
            ("max_per_link", "4"),
            ("share.Production", "3"),
            ("share.Analysis", "1"),
        ]);
        for i in 0..8 {
            seed_request(&ctx, &format!("p{i}"), "DST-A", "Production");
            seed_request(&ctx, &format!("u{i}"), "DST-A", "Analysis");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 4);
        let queued = cat.requests.scan(|r| r.state == RequestState::Queued);
        let prod = queued.iter().filter(|r| r.activity == "Production").count();
        let ana = queued.iter().filter(|r| r.activity == "Analysis").count();
        assert_eq!((prod, ana), (3, 1), "3:1 share split");
    }

    #[test]
    fn vo_shares_split_the_link_before_activities() {
        let (ctx, cat) = rig(&[
            ("max_per_link", "4"),
            ("vo_share.atlas", "3"),
            ("vo_share.cms", "1"),
        ]);
        cat.add_account_vo("at1", AccountType::User, "", "atlas").unwrap();
        cat.add_account_vo("cm1", AccountType::User, "", "cms").unwrap();
        cat.add_scope("s-atlas", "at1").unwrap();
        cat.add_scope("s-cms", "cm1").unwrap();
        for i in 0..8 {
            seed_request_in(&ctx, "s-atlas", &format!("a{i}"), "DST-A", "Production");
            seed_request_in(&ctx, "s-cms", &format!("c{i}"), "DST-A", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 4);
        let queued = cat.requests.scan(|r| r.state == RequestState::Queued);
        let atlas = queued.iter().filter(|r| r.did.scope == "s-atlas").count();
        let cms = queued.iter().filter(|r| r.did.scope == "s-cms").count();
        assert_eq!((atlas, cms), (3, 1), "3:1 VO share split");
    }

    #[test]
    fn zero_share_vo_is_blocked_nonzero_vo_proceeds() {
        let (ctx, cat) = rig(&[("max_per_link", "8"), ("vo_share.cms", "0")]);
        cat.add_account_vo("cm1", AccountType::User, "", "cms").unwrap();
        cat.add_scope("s-cms", "cm1").unwrap();
        for i in 0..3 {
            seed_request_in(&ctx, "s-cms", &format!("c{i}"), "DST-A", "Production");
            seed_request(&ctx, &format!("g{i}"), "DST-A", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 3, "only the active VO's requests");
        assert!(cat
            .requests
            .scan(|r| r.did.scope == "s-cms")
            .iter()
            .all(|r| r.state == RequestState::Waiting));
    }

    #[test]
    fn zero_share_activity_is_blocked_nonzero_proceeds() {
        let (ctx, cat) = rig(&[("max_per_link", "8"), ("share.Blocked", "0")]);
        for i in 0..3 {
            seed_request(&ctx, &format!("b{i}"), "DST-A", "Blocked");
            seed_request(&ctx, &format!("g{i}"), "DST-A", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 3, "only the nonzero-share activity");
        assert!(cat
            .requests
            .scan(|r| r.activity == "Blocked")
            .iter()
            .all(|r| r.state == RequestState::Waiting));
    }

    #[test]
    fn unrankable_source_released_immediately() {
        let (ctx, cat) = rig(&[("max_per_link", "1")]);
        // a file with no replica anywhere cannot be ranked — the request
        // must reach the submitter so the failure path runs
        cat.add_file("data18", "ghost", "root", 10, "x", None).unwrap();
        cat.add_rule(RuleSpec::new("root", DidKey::new("data18", "ghost"), "DST-A", 1))
            .unwrap();
        let mut t = Throttler::new(ctx.clone(), "t1");
        assert_eq!(t.tick(cat.now()), 1);
        assert_eq!(count_state(&cat, RequestState::Queued), 1);
    }

    #[test]
    fn boost_bypasses_admission() {
        let (ctx, cat) = rig(&[("max_per_link", "1")]);
        for i in 0..3 {
            seed_request(&ctx, &format!("f{i}"), "DST-A", "Production");
        }
        let mut t = Throttler::new(ctx.clone(), "t1");
        t.tick(cat.now());
        let waiting = cat.requests.scan(|r| r.state == RequestState::Waiting);
        assert_eq!(waiting.len(), 2);
        let boosted = cat.boost_request(waiting[0].id).unwrap();
        assert_eq!(boosted.state, RequestState::Queued, "boost skips the queue");
        assert_eq!(boosted.priority, crate::core::types::PRIORITY_BOOSTED);
    }

    /// Property: under random arrivals with random weights, (1) the
    /// number of released-but-unfinished requests per link never exceeds
    /// the cap after any tick, and (2) no nonzero-share activity is
    /// starved — all of its requests are released within a bounded number
    /// of ticks while completions keep draining the link.
    #[test]
    fn prop_caps_hold_and_nonzero_shares_never_starve() {
        use crate::common::proptest::forall;
        forall(15, |g| {
            let cap = g.usize(1, 5);
            let acts = ["Prod", "Ana", "Deb"];
            let w: Vec<String> =
                (0..3).map(|i| format!("{}", g.u64(1, 6 - i as u64))).collect();
            let shares: Vec<(String, String)> = acts
                .iter()
                .zip(&w)
                .map(|(a, w)| (format!("share.{a}"), w.clone()))
                .collect();
            let cap_s = cap.to_string();
            let mut cfg_extra: Vec<(&str, &str)> = vec![("max_per_link", cap_s.as_str())];
            for (k, v) in &shares {
                cfg_extra.push((k.as_str(), v.as_str()));
            }
            let (ctx, cat) = rig(&cfg_extra);
            let n = g.usize(4, 14);
            for i in 0..n {
                let act = *g.pick(&acts);
                seed_request(&ctx, &format!("r{i}"), "DST-A", act);
            }
            let mut t = Throttler::new(ctx.clone(), "t1");
            // drive: tick, then complete everything queued (frees slots)
            let mut ticks = 0;
            loop {
                t.tick(cat.now());
                // cap invariant: released-but-unfinished on the link
                let live = cat.requests.count_where(|r| {
                    matches!(r.state, RequestState::Queued | RequestState::Submitted)
                });
                assert!(live <= cap, "cap {cap} exceeded: {live} released");
                for req in cat.requests.scan(|r| r.state == RequestState::Queued) {
                    cat.on_transfer_done(req.id).unwrap();
                }
                if cat.requests_by_state.count(&RequestState::Waiting) == 0 {
                    break;
                }
                ticks += 1;
                assert!(
                    ticks <= 4 * n + 20,
                    "bounded wait violated: {} still waiting after {ticks} ticks",
                    cat.requests_by_state.count(&RequestState::Waiting)
                );
            }
            // every request of every (nonzero-share) activity was served
            assert_eq!(cat.requests.count_where(|r| r.state == RequestState::Done), n);
        });
    }
}
