//! The tracer — access-trace ingestion (paper §4.6): "every time a file
//! has been used as input for a job ... a trace is created that is then
//! sent to the central Rucio server"; the server forwards them to the
//! broker topic `traces`, and this daemon folds them into replica access
//! timestamps + DID popularity (LRU deletion §4.3, dynamic placement
//! §6.1).

use crate::common::clock::EpochMs;
use crate::core::types::DidKey;
use crate::jsonx::Json;
use crate::mq::{Message, SubId};

use super::{Ctx, Daemon};

/// Emit a trace to the broker (used by the server's /traces endpoint and
/// by the download/upload client helpers).
pub fn emit_trace(
    broker: &crate::mq::Broker,
    now: EpochMs,
    event: &str, // "download" | "upload" | "get" (job input) | "put" (job output)
    rse: &str,
    scope: &str,
    name: &str,
) {
    broker.publish(
        "traces",
        Message::new(
            event,
            Json::obj()
                .with("rse", rse)
                .with("scope", scope)
                .with("name", name),
            now,
        ),
    );
}

pub struct Tracer {
    pub ctx: Ctx,
    sub: SubId,
}

impl Tracer {
    pub fn new(ctx: Ctx) -> Self {
        let sub = ctx.broker.subscribe("traces", None);
        Tracer { ctx, sub }
    }
}

impl Daemon for Tracer {
    fn name(&self) -> &'static str {
        "tracer"
    }

    fn interval_ms(&self) -> i64 {
        5_000
    }

    fn tick(&mut self, _now: EpochMs) -> usize {
        let mut processed = 0;
        loop {
            let msgs = self.ctx.broker.poll("traces", self.sub, 1000);
            if msgs.is_empty() {
                break;
            }
            for m in msgs {
                let (Some(rse), Some(scope), Some(name)) = (
                    m.payload.opt_str("rse"),
                    m.payload.opt_str("scope"),
                    m.payload.opt_str("name"),
                ) else {
                    continue;
                };
                let did = DidKey::new(scope, name);
                // Popularity is a READ signal: only job-input / download
                // traces bump it. Write traces (upload/put) still refresh
                // the access timestamp so fresh data isn't an LRU victim,
                // but must not skew C3PO placement or reaper victim order.
                match m.event_type.as_str() {
                    "download" | "get" => self.ctx.catalog.touch_replica(rse, &did),
                    _ => self.ctx.catalog.touch_replica_access(rse, &did),
                }
                processed += 1;
            }
        }
        self.ctx.catalog.metrics.incr("traces.processed", processed as u64);
        processed
    }
}

/// Distance re-evaluation sweep (paper §2.4): folds the network's observed
/// throughput EWMA into the RSE distance table. Cheap enough to live in
/// the tracer family.
pub struct DistanceUpdater {
    pub ctx: Ctx,
}

impl Daemon for DistanceUpdater {
    fn name(&self) -> &'static str {
        "distance-updater"
    }

    fn interval_ms(&self) -> i64 {
        300_000
    }

    fn tick(&mut self, _now: EpochMs) -> usize {
        let samples = self.ctx.net.observed_pairs();
        self.ctx.catalog.update_distances_from_throughput(&samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::ReplicaState;
    use crate::daemons::conveyor::tests::{rig, seed_file};

    #[test]
    fn traces_update_popularity() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        let mut tracer = Tracer::new(ctx.clone());
        emit_trace(&ctx.broker, cat.now(), "download", "SRC-DISK", "data18", "f1");
        emit_trace(&ctx.broker, cat.now(), "get", "SRC-DISK", "data18", "f1");
        assert_eq!(tracer.tick(cat.now()), 2);
        assert_eq!(cat.popularity.get(&f).unwrap().accesses, 2);
        let _ = ReplicaState::Available;
    }

    #[test]
    fn write_traces_refresh_timestamp_without_popularity() {
        let (ctx, cat) = rig();
        let f = seed_file(&ctx, "f1", 100);
        let mut tracer = Tracer::new(ctx.clone());
        // establish a read-popularity baseline of 1
        emit_trace(&ctx.broker, cat.now(), "download", "SRC-DISK", "data18", "f1");
        assert_eq!(tracer.tick(cat.now()), 1);
        assert_eq!(cat.popularity.get(&f).unwrap().accesses, 1);
        let before = cat.get_replica("SRC-DISK", &f).unwrap().accessed_at;
        if let crate::common::clock::Clock::Sim(s) = &cat.clock {
            s.advance(60_000);
        }
        // a write trace must NOT look like a read
        emit_trace(&ctx.broker, cat.now(), "upload", "SRC-DISK", "data18", "f1");
        emit_trace(&ctx.broker, cat.now(), "put", "SRC-DISK", "data18", "f1");
        assert_eq!(tracer.tick(cat.now()), 2);
        assert_eq!(
            cat.popularity.get(&f).unwrap().accesses,
            1,
            "upload/put traces must not inflate read popularity"
        );
        let after = cat.get_replica("SRC-DISK", &f).unwrap().accessed_at;
        assert!(after > before, "write traces still refresh the access timestamp");
    }

    #[test]
    fn distance_updater_folds_network_ewma() {
        let (ctx, cat) = rig();
        ctx.net.record_throughput("SRC-DISK", "DST-A", 2e9);
        let mut du = DistanceUpdater { ctx: ctx.clone() };
        let n = du.tick(cat.now());
        assert!(n >= 1);
        assert_eq!(cat.distance("SRC-DISK", "DST-A"), Some(1));
    }

    #[test]
    fn malformed_traces_skipped() {
        let (ctx, cat) = rig();
        let mut tracer = Tracer::new(ctx.clone());
        ctx.broker
            .publish("traces", Message::new("download", Json::obj().with("junk", 1), 0));
        assert_eq!(tracer.tick(cat.now()), 0);
    }
}
