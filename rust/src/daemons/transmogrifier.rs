//! The transmogrifier (paper §2.5 / §3.4 — upstream's subscription
//! daemon): consumes `did-created` events from the broker in batches,
//! evaluates every enabled subscription's `meta-expr` filter against the
//! batch through the metadata query engine, and creates the subscribed
//! replication rules through the bulk rule path. The asynchronous half
//! of "after the creation of a DID its metadata is matched with the
//! filter of all subscriptions".

use crate::common::clock::EpochMs;
use crate::core::types::DidKey;
use crate::db::{assigned_to, shard_hash};
use crate::mq::SubId;

use super::{Ctx, Daemon};

pub struct Transmogrifier {
    pub ctx: Ctx,
    pub instance: String,
    sub: SubId,
    /// Events drained per broker poll — one catalog sweep per batch, so
    /// N new DIDs cost one subscription-table snapshot, not N.
    pub batch: usize,
    /// Events hash-assigned to *peer* instances, retained here because
    /// polling consumed them from this instance's subscription. If the
    /// peer dies before processing its own copy, its heartbeat expires
    /// within the TTL, the ring rebalances onto us, and we match these
    /// from the buffer — at-least-once across failover (the sweep is
    /// idempotent, so redundant processing is harmless). Entries older
    /// than [`Transmogrifier::defer_horizon_ms`] are dropped: by then a
    /// live peer has processed its copy, or we already took over.
    deferred: Vec<(EpochMs, DidKey)>,
    defer_horizon_ms: i64,
}

impl Transmogrifier {
    pub fn new(ctx: Ctx, instance: &str) -> Self {
        let batch = ctx.catalog.cfg.get_i64("transmogrifier", "batch", 500) as usize;
        let ttl = ctx
            .catalog
            .cfg
            .get_duration_ms("heartbeat", "ttl", crate::daemons::heartbeat::DEFAULT_TTL_MS);
        let sub = ctx.broker.subscribe("rucio.events", Some("did-created"));
        Transmogrifier {
            ctx,
            instance: instance.to_string(),
            sub,
            batch,
            deferred: Vec::new(),
            defer_horizon_ms: 2 * ttl,
        }
    }
}

impl Daemon for Transmogrifier {
    fn name(&self) -> &'static str {
        "transmogrifier"
    }

    fn interval_ms(&self) -> i64 {
        15_000
    }

    fn tick(&mut self, now: EpochMs) -> usize {
        // Every instance sees the whole event stream (each holds its own
        // broker subscription), so the §3.6 hash partition decides which
        // DIDs *this* instance matches — otherwise two instances would
        // race the idempotency check into duplicate subscription rules.
        let (worker, n_workers) =
            self.ctx.heartbeats.beat("transmogrifier", &self.instance, now);
        let mut pending = std::mem::take(&mut self.deferred);
        loop {
            let msgs = self.ctx.broker.poll("rucio.events", self.sub, self.batch.max(1));
            if msgs.is_empty() {
                break;
            }
            pending.extend(msgs.iter().filter_map(|m| {
                let scope = m.payload.opt_str("scope")?;
                let name = m.payload.opt_str("name")?;
                Some((now, DidKey::new(scope, name)))
            }));
        }
        // Split by ring assignment: ours is matched now, a live peer's
        // share goes back to the buffer (it owns its own copy) until the
        // ring rebalances onto us or the horizon proves it handled.
        let mut mine = Vec::new();
        for (seen_at, key) in pending {
            if assigned_to(shard_hash(key.to_string().as_bytes()), worker, n_workers) {
                mine.push(key);
            } else if now - seen_at < self.defer_horizon_ms {
                self.deferred.push((seen_at, key));
            }
        }
        // Sweep in bounded chunks so an outage backlog costs many small
        // catalog batches, not one unbounded stop-the-world sweep.
        let cat = &self.ctx.catalog;
        let mut created = 0;
        for chunk in mine.chunks(self.batch.max(1)) {
            created += cat.transmogrify_batch(chunk).len();
        }
        cat.metrics.incr("transmogrifier.rules_created", created as u64);
        created
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::metaexpr::parse;
    use crate::core::subscriptions::{SubscriptionFilter, SubscriptionRule};
    use crate::daemons::conveyor::tests::rig;
    use crate::daemons::hermes::Hermes;

    fn src_rule() -> SubscriptionRule {
        SubscriptionRule {
            rse_expression: "SRC-DISK".into(),
            copies: 1,
            lifetime_ms: None,
            activity: "T0 Export".into(),
        }
    }

    #[test]
    fn matches_new_datasets_via_events() {
        let (ctx, cat) = rig();
        cat.add_subscription(
            "all-datasets-to-src",
            "root",
            SubscriptionFilter { scopes: vec!["data18".into()], ..Default::default() },
            vec![src_rule()],
        )
        .unwrap();
        let mut hermes = Hermes::new(ctx.clone());
        let mut trans = Transmogrifier::new(ctx.clone(), "t1");
        // create a dataset → did-created event in outbox
        cat.add_dataset("data18", "raw.stream0", "root").unwrap();
        hermes.tick(cat.now()); // outbox → broker
        let n = trans.tick(cat.now());
        assert_eq!(n, 1, "one subscription rule created");
        assert_eq!(cat.rules.len(), 1);
        // re-ticking with no new events creates nothing
        assert_eq!(trans.tick(cat.now()), 0);
    }

    #[test]
    fn failover_replays_a_dead_peers_share_after_ttl() {
        let (ctx, cat) = rig();
        cat.add_subscription(
            "all-datasets-to-src",
            "root",
            SubscriptionFilter { scopes: vec!["data18".into()], ..Default::default() },
            vec![src_rule()],
        )
        .unwrap();
        let mut t1 = Transmogrifier::new(ctx.clone(), "t1");
        let mut t2 = Transmogrifier::new(ctx.clone(), "t2");
        t1.tick(cat.now());
        t2.tick(cat.now()); // ring of 2
        let mut hermes = Hermes::new(ctx.clone());
        for i in 0..12 {
            cat.add_dataset("data18", &format!("ds.{i:02}"), "root").unwrap();
        }
        hermes.tick(cat.now());
        // t2 crashes before processing: t1 matches only its own share and
        // defers the peer's (already consumed from t1's subscription)
        let c1 = t1.tick(cat.now());
        assert!(c1 > 0 && c1 < 12, "t1 owns a strict share: {c1}");
        assert_eq!(cat.rules.len(), c1);
        // t2's heartbeat expires → the ring rebalances onto t1, which
        // replays the deferred events: nothing is lost
        let now = if let crate::common::clock::Clock::Sim(s) = &cat.clock {
            s.advance(crate::daemons::heartbeat::DEFAULT_TTL_MS + 1_000);
            cat.now()
        } else {
            unreachable!("test rig uses a sim clock")
        };
        let c2 = t1.tick(now);
        assert_eq!(c1 + c2, 12, "the dead peer's share is replayed");
        assert_eq!(cat.rules.len(), 12);
    }

    #[test]
    fn two_instances_partition_the_stream_without_duplicates() {
        let (ctx, cat) = rig();
        cat.add_subscription(
            "all-datasets-to-src",
            "root",
            SubscriptionFilter { scopes: vec!["data18".into()], ..Default::default() },
            vec![src_rule()],
        )
        .unwrap();
        let mut t1 = Transmogrifier::new(ctx.clone(), "t1");
        let mut t2 = Transmogrifier::new(ctx.clone(), "t2");
        // both instances heartbeat before any events flow → 2-way ring
        t1.tick(cat.now());
        t2.tick(cat.now());
        let mut hermes = Hermes::new(ctx.clone());
        for i in 0..12 {
            cat.add_dataset("data18", &format!("ds.{i:02}"), "root").unwrap();
        }
        hermes.tick(cat.now());
        let c1 = t1.tick(cat.now());
        let c2 = t2.tick(cat.now());
        assert_eq!(c1 + c2, 12, "the hash partition covers every DID exactly once");
        assert_eq!(cat.rules.len(), 12, "no duplicate subscription rules");
        assert!(c1 > 0 && c2 > 0, "both instances own a share: {c1}/{c2}");
    }

    #[test]
    fn batch_of_events_matches_in_one_sweep() {
        let (ctx, cat) = rig();
        cat.add_subscription(
            "raw-to-src",
            "root",
            SubscriptionFilter {
                scopes: vec!["data18".into()],
                did_types: vec![],
                expr: Some(parse("datatype=RAW").unwrap()),
            },
            vec![src_rule()],
        )
        .unwrap();
        let mut hermes = Hermes::new(ctx.clone());
        let mut trans = Transmogrifier::new(ctx.clone(), "t1");
        for i in 0..10 {
            let name = format!("raw.{i:03}");
            cat.add_dataset("data18", &name, "root").unwrap();
            let key = crate::core::types::DidKey::new("data18", &name);
            if i < 7 {
                cat.set_metadata(&key, "datatype", "RAW").unwrap();
            }
        }
        hermes.tick(cat.now());
        // all 10 events drain in one tick; only the 7 RAW ones match
        assert_eq!(trans.tick(cat.now()), 7);
        assert_eq!(cat.rules.len(), 7);
        assert_eq!(cat.metrics.counter("transmogrifier.rules_created"), 7);
    }
}
