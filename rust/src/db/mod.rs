//! Embedded transactional table store — the persistence layer of paper §3.6.
//!
//! Upstream Rucio sits on Oracle/PostgreSQL through SQLAlchemy with >40
//! tables, targeted secondary indexes, history tables, and hash-sharded
//! lock-free work selection. This module provides the same primitives as an
//! in-process store:
//!
//! * [`Table`] — a typed, `RwLock`-protected ordered map of rows keyed by
//!   the row's primary key ([`Row::key`]).
//! * [`Index`] — secondary indexes kept consistent by the table through
//!   registered maintenance hooks (the "targeted indexes on most tables"
//!   of §3.6).
//! * history — optional append-only log of mutations per table (the
//!   "storing of deleted rows in historical tables" helper of §3.6).
//! * [`shard_hash`] / [`assigned_to`] — the hash-based work partitioning
//!   used by every daemon type for lock-free parallelism (§3.6: "selection
//!   of work per daemon is based on a hashing algorithm on a set of
//!   attributes").
//! * [`Registry`] — name → row-count introspection for monitoring and the
//!   analytics reports.

pub mod table;

pub use table::{Index, Op, Row, Table};

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// FNV-1a over a byte representation: stable across runs and platforms,
/// so work sharding is deterministic (important for the sim + tests).
pub fn shard_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The §3.6 work-partition predicate: does worker `worker_idx` (of
/// `n_workers` live instances) own the row identified by `key`?
/// All daemons of one type use this to "guarantee among each other not to
/// work on the same requests" without any locking.
pub fn assigned_to(key: u64, worker_idx: usize, n_workers: usize) -> bool {
    if n_workers <= 1 {
        return true;
    }
    // Re-mix: table keys are dense sequential ids, raw modulo would stripe.
    let mixed = shard_hash(&key.to_le_bytes());
    (mixed % n_workers as u64) as usize == worker_idx
}

/// Table introspection registry: table name → live row-count closure.
/// The monitoring probes (paper §4.6 "a probe regularly checks the
/// database") read queue sizes through this.
#[derive(Clone, Default)]
pub struct Registry {
    counts: Arc<Mutex<BTreeMap<String, Arc<dyn Fn() -> usize + Send + Sync>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, counter: Arc<dyn Fn() -> usize + Send + Sync>) {
        self.counts.lock().unwrap().insert(name.to_string(), counter);
    }

    /// Snapshot of all table sizes.
    pub fn snapshot(&self) -> BTreeMap<String, usize> {
        self.counts
            .lock()
            .unwrap()
            .iter()
            .map(|(k, f)| (k.clone(), f()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_hash_stable() {
        assert_eq!(shard_hash(b"rucio"), shard_hash(b"rucio"));
        assert_ne!(shard_hash(b"rucio"), shard_hash(b"rucia"));
    }

    #[test]
    fn assignment_partitions_completely_and_disjointly() {
        let n = 5;
        for key in 0..1000u64 {
            let owners: Vec<usize> = (0..n).filter(|&w| assigned_to(key, w, n)).collect();
            assert_eq!(owners.len(), 1, "key {key} owned by {owners:?}");
        }
    }

    #[test]
    fn assignment_is_balanced() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for key in 0..10_000u64 {
            for w in 0..n {
                if assigned_to(key, w, n) {
                    counts[w] += 1;
                }
            }
        }
        for &c in &counts {
            assert!((2000..3000).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_worker_owns_everything() {
        assert!(assigned_to(42, 0, 1));
        assert!(assigned_to(42, 0, 0));
    }

    #[test]
    fn registry_snapshots() {
        let r = Registry::new();
        r.register("rules", Arc::new(|| 7));
        r.register("locks", Arc::new(|| 3));
        let snap = r.snapshot();
        assert_eq!(snap["rules"], 7);
        assert_eq!(snap["locks"], 3);
    }
}
