//! Embedded transactional table store — the persistence layer of paper §3.6.
//!
//! Upstream Rucio sits on Oracle/PostgreSQL through SQLAlchemy with >40
//! tables, targeted secondary indexes, history tables, hash-sharded
//! lock-free work selection, and heavy use of bulk operations to sustain
//! production rates (§5: ~200 Hz of interactions, millions of transfers
//! and deletions per day). This module provides the same primitives as an
//! in-process store that is **durable and recoverable**: every table can
//! attach a write-ahead log ([`wal`]), checkpoint per-shard snapshots,
//! and cold-boot back from disk. A process crash loses at most the torn
//! final record of each table's log (detected by checksum and discarded
//! whole — under group commit, the default, a commit is applied all or
//! nothing; see the crash model in [`wal`] for the per-table atomicity
//! boundary):
//!
//! * [`Table`] — a typed ordered map of rows keyed by the row's primary
//!   key ([`Row::key`]), stored as **N-way hash-sharded** `RwLock`ed
//!   BTreeMaps. Single-row mutations lock exactly one shard (writers on
//!   different shards proceed in parallel); ordered reads merge the
//!   per-shard maps, so `scan`/`range`/pagination return rows in exactly
//!   the same global key order as a single map would.
//! * **Batches** — [`Batch`]/[`BatchOp`] plus `insert_bulk` /
//!   `upsert_bulk` / `remove_bulk` / `update_bulk` commit many mutations
//!   under one lock acquisition. Atomicity scope: one batch on one table
//!   (all shards of that table are locked for the commit, so readers see
//!   none or all of it); there are no cross-table transactions — callers
//!   sequence multi-table invariants exactly as the row-at-a-time code
//!   did. Index hooks and history logs are maintained per op inside the
//!   commit, so they stay consistent under batches.
//! * **Cursors** — [`Table::scan_page`] / [`Table::range_page`] provide
//!   resumable ordered pagination ([`Page`]) for daemon drains and the
//!   NDJSON list REST routes.
//! * [`Index`] — secondary indexes kept consistent by the table through
//!   registered maintenance hooks (the "targeted indexes on most tables"
//!   of §3.6). [`Table::add_index`] back-fills from live rows, so indexes
//!   may be attached to non-empty tables.
//! * [`MultiIndex`] — the inverted-index variant: one row posts under
//!   many index keys (a DID under each of its metadata `(key, value)`
//!   pairs), with ordered range lookups for the query planner's
//!   comparison predicates.
//! * history — optional append-only log of mutations per table (the
//!   "storing of deleted rows in historical tables" helper of §3.6).
//!   History is in-memory only; it does not survive a restart.
//! * **durability** — [`wal::Wal`] (length-prefixed, SHA-256-checksummed,
//!   group-committed write-ahead log), [`Table::checkpoint`] /
//!   [`Table::recover`] (per-shard snapshots fenced by WAL barrier
//!   records, replay of the post-barrier suffix with full index
//!   rebuild), and [`wal::TablePersist`] (the type-erased handle
//!   [`Registry::checkpoint_all`] drives). Rows opt in by implementing
//!   [`wal::Durable`] (all catalog rows do, in `core::persist`).
//! * [`shard_hash`] / [`assigned_to`] — the hash-based work partitioning
//!   used by every daemon type for lock-free parallelism (§3.6: "selection
//!   of work per daemon is based on a hashing algorithm on a set of
//!   attributes").
//! * [`Registry`] — name → row-count introspection for monitoring and the
//!   analytics reports. `Catalog` registers every table at construction.
//!
//! Configuration: the `[db] shards` key (default [`DEFAULT_SHARDS`])
//! sets the shard count for every catalog table. Shard placement uses a
//! deterministic FNV-1a over the key's `Hash` bytes, so layouts are
//! stable across runs; the shard count is invisible to all observable
//! behavior (ordering, history, indexes, recovery — snapshots carry rows,
//! not shard layout) — asserted by the shard-invariance property test in
//! [`table`]. Durability is configured by `[db] wal_dir` (enables the
//! WAL), `[db] fsync` and `[db] group_commit` (see [`wal::WalOptions`]),
//! and `[db] checkpoint_interval` (the checkpointer daemon's cadence).

pub mod table;
pub mod wal;

pub use table::{
    Batch, BatchOp, BatchSummary, ContentionStats, Index, MultiIndex, Op, Page, Row, Table,
    DEFAULT_SHARDS,
};
pub use wal::{
    CheckpointStats, CompactStats, Durable, RecoverStats, SpillStats, TablePersist, Wal,
    WalOptions, WalStats,
};

use crate::common::error::RucioError;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// FNV-1a over a byte representation: stable across runs and platforms,
/// so work sharding is deterministic (important for the sim + tests).
pub fn shard_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A [`std::hash::Hasher`] over the same FNV-1a as [`shard_hash`]:
/// deterministic (no per-process randomization like `DefaultHasher`), so
/// table shard placement is reproducible run to run.
pub struct FnvHasher(u64);

impl Default for FnvHasher {
    fn default() -> Self {
        FnvHasher(0xcbf29ce484222325)
    }
}

impl std::hash::Hasher for FnvHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

/// The §3.6 work-partition predicate: does worker `worker_idx` (of
/// `n_workers` live instances) own the row identified by `key`?
/// All daemons of one type use this to "guarantee among each other not to
/// work on the same requests" without any locking.
pub fn assigned_to(key: u64, worker_idx: usize, n_workers: usize) -> bool {
    if n_workers <= 1 {
        return true;
    }
    // Re-mix: table keys are dense sequential ids, raw modulo would stripe.
    let mixed = shard_hash(&key.to_le_bytes());
    (mixed % n_workers as u64) as usize == worker_idx
}

/// Outcome of one [`Registry::checkpoint_all`] sweep. The sweep visits
/// every registered table even when some fail: `tables` holds the stats
/// of tables actually checkpointed, `skipped_clean` the tables whose
/// on-disk snapshot was already current, and `errors` the per-table
/// failures (the checkpointer counts these individually).
#[derive(Default)]
pub struct CheckpointSweep {
    pub tables: BTreeMap<String, CheckpointStats>,
    pub skipped_clean: Vec<String>,
    pub errors: BTreeMap<String, RucioError>,
}

/// Table introspection registry: table name → live row-count closure,
/// plus (for durable tables) a type-erased persistence handle.
/// The monitoring probes (paper §4.6 "a probe regularly checks the
/// database") read queue sizes through this; `Catalog::new` wires every
/// table in at construction, and — when durability is enabled — also
/// registers each table's [`TablePersist`] handle so
/// [`Registry::checkpoint_all`] can fence and snapshot the whole store.
#[derive(Clone, Default)]
pub struct Registry {
    counts: Arc<Mutex<BTreeMap<String, Arc<dyn Fn() -> usize + Send + Sync>>>>,
    persist: Arc<Mutex<BTreeMap<String, Arc<dyn TablePersist>>>>,
    contention: Arc<Mutex<BTreeMap<String, Arc<dyn Fn() -> ContentionStats + Send + Sync>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, name: &str, counter: Arc<dyn Fn() -> usize + Send + Sync>) {
        self.counts.lock().unwrap().insert(name.to_string(), counter);
    }

    /// Register a durable table's persistence handle (checkpoint driver).
    pub fn register_persist(&self, table: Arc<dyn TablePersist>) {
        self.persist
            .lock()
            .unwrap()
            .insert(table.table_name().to_string(), table);
    }

    /// Snapshot of all table sizes.
    pub fn snapshot(&self) -> BTreeMap<String, usize> {
        self.counts
            .lock()
            .unwrap()
            .iter()
            .map(|(k, f)| (k.clone(), f()))
            .collect()
    }

    /// Checkpoint every registered durable table: per table, a WAL
    /// barrier record fences the log, dirty shards get their snapshot
    /// files rewritten, a manifest stitches the cut together, and the
    /// log is truncated back to the barrier. The sweep never aborts
    /// early: a failing table is recorded in [`CheckpointSweep::errors`]
    /// and the sweep moves on, so one bad table can't leave every later
    /// table's WAL growing unbounded. Tables whose WAL is already fenced
    /// and whose shards are all clean are skipped entirely (recorded in
    /// [`CheckpointSweep::skipped_clean`]) — their snapshot on disk is
    /// current. The registry lock is released before any IO happens.
    pub fn checkpoint_all(&self) -> CheckpointSweep {
        let tables: Vec<Arc<dyn TablePersist>> =
            self.persist.lock().unwrap().values().cloned().collect();
        let mut sweep = CheckpointSweep::default();
        for t in tables {
            let name = t.table_name().to_string();
            if !t.needs_checkpoint() {
                sweep.skipped_clean.push(name);
                continue;
            }
            match t.checkpoint() {
                Ok(stats) => {
                    sweep.tables.insert(name, stats);
                }
                Err(e) => {
                    crate::log_warn!("checkpoint of table {name} failed: {e}");
                    sweep.errors.insert(name, e);
                }
            }
        }
        sweep
    }

    /// Compact the WAL of every durable table whose log has grown past
    /// `min_bytes` (see [`table::Table::compact_wal`]): drop
    /// snapshot-covered records and fold the live suffix to the last op
    /// per key. Failures are logged and skipped — compaction is an
    /// optimization, never a correctness requirement.
    pub fn compact_wals(&self, min_bytes: u64) -> BTreeMap<String, CompactStats> {
        let tables: Vec<Arc<dyn TablePersist>> =
            self.persist.lock().unwrap().values().cloned().collect();
        let mut out = BTreeMap::new();
        for t in tables {
            let Some(ws) = t.wal_stats() else { continue };
            if ws.bytes < min_bytes {
                continue;
            }
            match t.compact_wal() {
                // Default stats mean the fold wouldn't have shrunk the
                // log and nothing was rewritten — not a compaction.
                Ok(stats) if stats.records_before > 0 => {
                    out.insert(t.table_name().to_string(), stats);
                }
                Ok(_) => {}
                Err(e) => crate::log_warn!("wal compaction of table {} failed: {e}", t.table_name()),
            }
        }
        out
    }

    /// Enforce each durable table's hot-row budget by evicting cold
    /// shards to disk (see [`table::Table::enforce_budget`]). Returns
    /// the total number of shards evicted; failures are logged and the
    /// sweep continues.
    pub fn enforce_budgets(&self) -> usize {
        let tables: Vec<Arc<dyn TablePersist>> =
            self.persist.lock().unwrap().values().cloned().collect();
        let mut evicted = 0usize;
        for t in tables {
            match t.enforce_budget() {
                Ok(n) => evicted += n,
                Err(e) => crate::log_warn!("eviction on table {} failed: {e}", t.table_name()),
            }
        }
        evicted
    }

    /// Paged-mode shape of every registered durable table.
    pub fn spill(&self) -> BTreeMap<String, SpillStats> {
        let tables: Vec<Arc<dyn TablePersist>> =
            self.persist.lock().unwrap().values().cloned().collect();
        tables
            .into_iter()
            .map(|t| (t.table_name().to_string(), t.spill_stats()))
            .collect()
    }

    /// Register a table's shard-lock contention probe
    /// ([`Table::contention_probe`]).
    pub fn register_contention(
        &self,
        name: &str,
        probe: Arc<dyn Fn() -> ContentionStats + Send + Sync>,
    ) {
        self.contention.lock().unwrap().insert(name.to_string(), probe);
    }

    /// Point-in-time shard-lock contention counters of every table with
    /// a registered probe.
    pub fn contention(&self) -> BTreeMap<String, ContentionStats> {
        self.contention
            .lock()
            .unwrap()
            .iter()
            .map(|(k, f)| (k.clone(), f()))
            .collect()
    }

    /// Live WAL shape of every registered durable table.
    pub fn wal_stats(&self) -> BTreeMap<String, WalStats> {
        let tables: Vec<Arc<dyn TablePersist>> =
            self.persist.lock().unwrap().values().cloned().collect();
        tables
            .into_iter()
            .filter_map(|t| t.wal_stats().map(|s| (t.table_name().to_string(), s)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_hash_stable() {
        assert_eq!(shard_hash(b"rucio"), shard_hash(b"rucio"));
        assert_ne!(shard_hash(b"rucio"), shard_hash(b"rucia"));
    }

    #[test]
    fn fnv_hasher_matches_shard_hash() {
        use std::hash::Hasher;
        let mut h = FnvHasher::default();
        h.write(b"rucio");
        assert_eq!(h.finish(), shard_hash(b"rucio"));
    }

    #[test]
    fn assignment_partitions_completely_and_disjointly() {
        let n = 5;
        for key in 0..1000u64 {
            let owners: Vec<usize> = (0..n).filter(|&w| assigned_to(key, w, n)).collect();
            assert_eq!(owners.len(), 1, "key {key} owned by {owners:?}");
        }
    }

    #[test]
    fn assignment_is_balanced() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for key in 0..10_000u64 {
            for w in 0..n {
                if assigned_to(key, w, n) {
                    counts[w] += 1;
                }
            }
        }
        for &c in &counts {
            assert!((2000..3000).contains(&c), "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn single_worker_owns_everything() {
        assert!(assigned_to(42, 0, 1));
        assert!(assigned_to(42, 0, 0));
    }

    #[test]
    fn registry_snapshots() {
        let r = Registry::new();
        r.register("rules", Arc::new(|| 7));
        r.register("locks", Arc::new(|| 3));
        let snap = r.snapshot();
        assert_eq!(snap["rules"], 7);
        assert_eq!(snap["locks"], 3);
    }
}
